"""Quickstart: compile a PICO deployment for InceptionV3 on a
heterogeneous cluster, execute it, verify it matches the monolithic
network, and round-trip the plan artifact through JSON.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

import repro
from repro.core import make_pi_cluster
from repro.models.cnn import zoo

# 1. A CNN with a non-trivial (block) structure, scaled for CPU demo
model = zoo.inceptionv3(input_size=(128, 128), scale=0.25)
print(f"model: {model.name}  vertices={len(model.graph.layers)} "
      f"width={model.graph.width()}")

# 2. A heterogeneous edge cluster: 4 Raspberry-Pis at mixed frequencies
cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])

# 3. One call owns the two-step PICO optimization: Alg.1 (graph ->
#    pieces), Alg.2+3 (pieces x devices -> pipeline stages)
dep = repro.compile(model, cluster)
print(f"pieces: {len(dep.partition.pieces)} "
      f"(worst piece redundancy {dep.partition.objective:.3g} FLOPs)")
print(dep.describe())

# 4. Execute the pipeline and check bit-exactness vs the monolithic net
x = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 128, 3))
ref = model.forward(dep.load_params().params, x)
out = dep.run(x)
for k in ref:
    np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                               rtol=1e-5, atol=1e-5)
print("pipelined execution matches monolithic forward exactly ✓")

# 5. Steady-state runtime metrics (paper Table 5 quantities)
rep = dep.simulate(frames=32)
print(f"simulated: throughput {rep.throughput_per_min:.1f}/min, "
      f"avg util {rep.avg_utilization:.2f}, "
      f"avg redundancy {rep.avg_redundancy:.3f}, "
      f"avg mem {rep.avg_memory/1e6:.1f} MB")

# 6. The plan is a durable artifact: save, reload (no re-planning, no
#    re-calibration), and get bit-identical behavior back
path = dep.save("/tmp/quickstart_plan.json")
dep2 = repro.Deployment.load(path)
out2 = dep2.run(x)
for k in out:
    np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(out2[k]))
print(f"artifact round-trip ({path}) is bit-identical ✓")
