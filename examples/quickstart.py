"""Quickstart: plan a PICO pipeline for InceptionV3 on a heterogeneous
cluster, execute it, and verify it matches the monolithic network.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import make_pi_cluster, plan, simulate
from repro.models.cnn import zoo
from repro.pipeline import PipelineRunner

# 1. A CNN with a non-trivial (block) structure, scaled for CPU demo
model = zoo.inceptionv3(input_size=(128, 128), scale=0.25)
print(f"model: {model.name}  vertices={len(model.graph.layers)} "
      f"width={model.graph.width()}")

# 2. A heterogeneous edge cluster: 4 Raspberry-Pis at mixed frequencies
cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])

# 3. Two-step PICO optimization: Alg.1 (graph -> pieces), Alg.2+3
#    (pieces x devices -> pipeline stages)
pico = plan(model.graph, cluster, model.input_size)
print(f"pieces: {len(pico.partition.pieces)} "
      f"(worst piece redundancy {pico.partition.objective:.3g} FLOPs)")
for st in pico.pipeline.stages:
    print(f"  stage pieces {st.first_piece}-{st.last_piece} on "
          f"{[d.name for d in st.devices]}  T={st.cost.total*1e3:.1f} ms "
          f"(comp {st.cost.t_comp*1e3:.1f} + comm {st.cost.t_comm*1e3:.1f})")
print(f"period {pico.period*1e3:.1f} ms -> "
      f"throughput {60/pico.period:.1f} frames/min; "
      f"latency {pico.latency*1e3:.1f} ms")

# 4. Execute the pipeline and check bit-exactness vs the monolithic net
params = model.init(jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 128, 3))
ref = model.forward(params, x)
out = PipelineRunner(model, pico.pipeline)(params, x)
for k in ref:
    np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                               rtol=1e-5, atol=1e-5)
print("pipelined execution matches monolithic forward exactly ✓")

# 5. Steady-state runtime metrics (paper Table 5 quantities)
rep = simulate(pico.pipeline, frames=32)
print(f"simulated: throughput {rep.throughput_per_min:.1f}/min, "
      f"avg util {rep.avg_utilization:.2f}, "
      f"avg redundancy {rep.avg_redundancy:.3f}, "
      f"avg mem {rep.avg_memory/1e6:.1f} MB")
