"""Serve a small LM with batched requests: prefill + KV-cache decode
across the assigned-architecture families (dense / MoE / SSM / hybrid).

    PYTHONPATH=src python examples/lm_generate.py
"""

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.transformer import model as M
from repro.serving.lm import generate

for name in ("llama3.2-1b", "mixtral-8x7b", "mamba2-370m", "zamba2-2.7b"):
    cfg = configs.get(name).reduced(n_layers=2, d_model=128)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 12), 0,
                                cfg.vocab_size)
    t0 = time.time()
    toks = generate(cfg, params, prompt, n_new=16)
    dt = time.time() - t0
    assert toks.shape == (4, 16)
    print(f"{name:22s} ({cfg.family:6s}) generated {toks.shape} in "
          f"{dt:.1f}s; sample: {toks[0, :8].tolist()}")
print("batched prefill+decode serving works across families ✓")
