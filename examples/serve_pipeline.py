"""End-to-end serving driver (the paper's deployment): a Poisson stream
of camera frames served by a PICO-planned pipeline over a heterogeneous
cluster, with real numerics and model-time statistics.

    PYTHONPATH=src python examples/serve_pipeline.py
"""

import numpy as np

from repro.core import make_pi_cluster
from repro.data.pipeline import RequestStream
from repro.models.cnn import zoo
from repro.serving import PipelineServer

model = zoo.resnet34(input_size=(128, 128), scale=0.25)
cluster = make_pi_cluster([1.5, 1.5, 1.2, 1.2, 0.8, 0.8])

server = PipelineServer(model, cluster).load()
plan = server.pico
print(f"pipeline: {len(plan.pipeline.stages)} stages, "
      f"period {plan.period*1e3:.1f} ms, latency {plan.latency*1e3:.1f} ms")

# Poisson arrivals at ~80% of pipeline capacity
rate = 0.8 / plan.period
H, W = model.input_size[1], model.input_size[0]


def payload(rng, i):
    return rng.standard_normal((1, H, W, 3)).astype(np.float32)


requests = RequestStream(rate_per_s=rate, seed=0).generate(24, payload)
outputs, stats = server.serve(requests)

print(f"served {stats.served} requests "
      f"(wall {stats.wall_s:.1f}s on this CPU)")
print(f"model-time throughput: {stats.model_throughput_per_min:.1f}/min")
lat = np.array(stats.per_request)
print(f"model-time latency: p50 {np.percentile(lat, 50)*1e3:.0f} ms, "
      f"p95 {np.percentile(lat, 95)*1e3:.0f} ms")
out0 = outputs[0]
print("first output:", {k: v.shape for k, v in out0.items()})
