"""Distributed pipeline execution: the shipped Deployment artifact
running on real workers, validated against the in-process oracle.

A SqueezeNet deployment on a 3-Pi cluster is launched as a chain of
persistent stage workers (threads + in-memory wire links here; flip
``DistSpec(transport="tcp", workers="process")`` for real OS processes
over sockets — same codec, same bytes).  Workers receive only the
versioned JSON artifact, rebuild weights deterministically, and stream
frames ``recv -> compiled stage -> send``.  The run ends with a churn
drill: one worker is killed mid-stream, its loss is accounted frame by
frame, and a re-plan on the surviving devices recovers every frame
bit-identically.

    PYTHONPATH=src python examples/dist_pipeline.py
"""

import numpy as np

import repro
from repro.core import make_pi_cluster
from repro.dist import make_frames, validate
from repro.dist.validate import reference_outputs
from repro.models.cnn import zoo


def main():
    # 1. Plan once, offline (paper Alg.1-3); the artifact is the hand-off
    model = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.2, 1.0], bandwidth_mbps=50.0)
    dep = repro.compile(model, cluster)
    print(dep.describe())

    # 2. Real distributed execution, validated against the simulator
    #    oracle: bit-identical outputs, zero dropped frames, observed
    #    per-stage compute within a sane band of the modeled cost
    v = validate(dep, repro.DistSpec(), frames=5)
    print(v.describe())
    assert v.ok, v.describe()

    # 3. Incremental use: start once, stream frames, clean drain
    launcher = dep.fleet(repro.DistSpec(transport="memory"))
    launcher.start()
    xs = make_frames(model, 6)
    for x in xs:
        launcher.submit(x)
    rep = launcher.shutdown()          # FIFO drain: nothing in flight lost
    assert rep.completed == rep.submitted and not rep.dropped
    print(f"streamed {rep.completed}/{rep.submitted} frames, "
          f"dropped={len(rep.dropped)}, "
          f"utilization={rep.utilization():.2f}, "
          f"stages={rep.n_stages} ({rep.workers_mode}/{rep.transport})")

    # 4. Churn drill: kill a worker mid-stream; the launcher surfaces
    #    DeviceLeave events and accounts every stranded frame
    drill = dep.fleet(repro.DistSpec(heartbeat_s=0.05, peer_timeout_s=0.6))
    drill.start()
    drill.kill_worker(1)
    rep = drill.run(xs)
    dead = {e.device_name for e in rep.churn_events}
    print(f"churn drill: lost {sorted(dead)}, completed {rep.completed}, "
          f"dropped {len(rep.dropped)} (reasons recorded per frame)")
    assert rep.completed + len(rep.dropped) == rep.submitted

    # 5. Drain-and-repartition: re-plan on the survivors, resubmit the
    #    stranded frames, and the merged stream is bit-identical to the
    #    single-process oracle
    alive = [d for d in cluster.devices if d.name not in dead]
    dep2 = dep.replan(cluster.restricted(alive))
    missing = sorted(set(range(len(xs))) - set(rep.outputs))
    rep2 = dep2.fleet(repro.DistSpec()).run([xs[i] for i in missing])
    merged = dict(rep.outputs)
    merged.update({fid: rep2.outputs[k] for k, fid in enumerate(missing)})
    ref = reference_outputs(dep, xs)
    assert all(np.array_equal(merged[i][s], ref[i][s])
               for i in range(len(xs)) for s in ref[i])
    print(f"recovered {len(missing)} stranded frame(s) on "
          f"{len(alive)} surviving devices — all outputs bit-identical ✓")


if __name__ == "__main__":             # required: spawn-safe entry point
    main()
