"""Tackling a wide NAS graph with divide-and-conquer (paper §6.2.3,
'NASNetL-P'): direct Algorithm 1 is intractable for w=8 graphs; the
chunked driver partitions it in seconds.

    PYTHONPATH=src python examples/nasnet_dnc.py
"""

import time

from repro.core import make_pi_cluster, partition_graph_dnc, plan
from repro.models.cnn import zoo

model = zoo.nasnet_cells(n_cells=6, input_size=(128, 128), scale=0.25,
                         width=6, name="nasnetl-p")
g = model.graph
D = 5
n, w = len(g.layers), g.width()
bound = w * D * (n * D / w) ** w
print(f"NASNet-style graph: n={n} vertices, width w={w}; "
      f"direct Alg.1 bound ~{bound:.2g} states -> divide & conquer")

t0 = time.time()
part = partition_graph_dnc(g, model.input_size, n_split=4, chunk=24)
print(f"D&C produced {len(part.pieces)} chain pieces in "
      f"{time.time()-t0:.1f}s (worst redundancy {part.objective:.3g})")

cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
pico = plan(g, cluster, model.input_size, pieces=part.pieces)
print(f"pipeline: {len(pico.pipeline.stages)} stages, "
      f"period {pico.period*1e3:.1f} ms, "
      f"throughput {60/pico.period:.1f} frames/min")
