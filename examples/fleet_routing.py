"""Fleet routing demo: two cells (clusters), tenants admitted through
the shared PlanRegistry, one device-churn event re-planned on the
incremental planner hot path, and a registry hit when a second,
identically-shaped cluster joins — plus a watermark autoscale pass.

    PYTHONPATH=src python examples/fleet_routing.py

Every plan carries honest provenance in ``plan.source``:
``scratch`` (full PICO optimization), ``incremental`` (the per-model
PlannerCache reused segment geometry), ``registry`` (no planning at
all — an identical cluster was planned before, anywhere in the fleet).
"""

import dataclasses

from repro.api import FleetSpec, PlanSpec
from repro.core import Cluster, make_pi_cluster
from repro.fleet import Autoscaler, FleetRouter, Tenant
from repro.models.cnn import zoo


def renamed(cluster: Cluster, prefix: str) -> Cluster:
    """Same hardware, fresh device names (a different physical pod)."""
    return Cluster([dataclasses.replace(d, name=f"{prefix}.{d.name}")
                    for d in cluster.devices], bandwidth=cluster.bandwidth)


# two cells: a strong 4-Pi pod and a weaker one
cells = {
    "pod-a": make_pi_cluster([1.5, 1.5, 1.2, 1.2]),
    "pod-b": renamed(make_pi_cluster([1.0, 1.0, 0.8, 0.8]), "b"),
}
router = FleetRouter(cells, spec=FleetSpec(routing="least_loaded",
                                           max_clusters=4))

# admit two tenants: both plans are built from scratch (cold fleet)
detector = Tenant("detector", zoo.squeezenet(input_size=(96, 96), scale=0.5),
                  weight=2.0, spec=PlanSpec())
classifier = Tenant("classifier",
                    zoo.mobilenetv3(input_size=(96, 96), scale=0.5))
for t in (detector, classifier):
    a = router.admit(t)
    print(f"admitted {a.tenant:10s} -> {a.cell}  "
          f"period={a.plan.period * 1e3:7.2f}ms  source={a.plan_source}")

# churn: pod-a loses a device; the re-plan runs on the incremental hot
# path (the per-model PlannerCache kept the chain's segment geometry)
pod_a = router.cells["pod-a"].cluster
smaller = pod_a.restricted(pod_a.devices[:-1])
for name, plan in router.churn("pod-a", smaller).items():
    print(f"churn    {name:10s} -> pod-a  "
          f"period={plan.period * 1e3:7.2f}ms  source={plan.source}")

# a second pod with pod-b's exact shape joins: admitting the classifier
# model there is a pure registry hit (name-insensitive cluster
# signature; the cached plan's devices are rebound onto the new names)
router.add_cell("pod-c", renamed(make_pi_cluster([1.0, 1.0, 0.8, 0.8]), "c"))
router.observe("pod-c", 0.0)          # brand new -> least loaded
twin = Tenant("classifier-2", zoo.mobilenetv3(input_size=(96, 96), scale=0.5))
a = router.admit(twin)
print(f"admitted {a.tenant:10s} -> {a.cell}  "
      f"period={a.plan.period * 1e3:7.2f}ms  source={a.plan_source}")
assert a.plan_source == "registry", a.plan_source
print(f"registry: {router.registry.hits} hits / {router.registry.misses} "
      f"misses ({router.registry.hit_rate:.0%} hit rate, "
      f"{len(router.registry)} entries)")

# autoscale: pod-a is hot, pod-b idle; provision clones the hot cell's
# shape, decommission approves draining (tenants re-route via registry)
router.observe("pod-a", 0.95)
router.observe("pod-b", 0.05)


def provision(rt, decision):
    shape = rt.cells[decision.cell].cluster
    return f"pod-{len(rt.cells)}", renamed(shape, f"x{len(rt.cells)}")


scaler = Autoscaler(router, provision=provision,
                    decommission=lambda rt, d: True)
for d in scaler.evaluate():
    print(f"autoscale {d.cell:6s} load={d.load:.2f} -> {d.action:10s} "
          f"applied={d.applied} {d.detail}")
