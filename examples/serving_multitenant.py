"""Multi-tenant serving demo: three CNN tenants share one heterogeneous
Pi cluster through the asynchronous ServingScheduler — weighted device
partitioning, admission control, SLO tracking, continuous micro-batching,
and a device dropping out mid-traffic.

    PYTHONPATH=src python examples/serving_multitenant.py
"""

from repro.api import ExecSpec, PlanSpec
from repro.core import make_pi_cluster
from repro.models.cnn import zoo
from repro.runtime import DeviceLeave
from repro.serving import (OpenLoopGenerator, SchedulerConfig,
                           ServingScheduler, TenantConfig, serve_time_sliced)

cluster = make_pi_cluster([1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8])

# three tenants: weight = device entitlement, slo_s = per-request
# deadline, max_queue = admission bound, max_batch = stage-0 coalescing;
# per-tenant planner knobs ride in a PlanSpec
tenants = [
    TenantConfig("detector", zoo.squeezenet(input_size=(96, 96), scale=0.5),
                 weight=2.0, slo_s=0.5, max_queue=64, max_batch=4,
                 plan_spec=PlanSpec()),
    TenantConfig("classifier", zoo.mobilenetv3(input_size=(96, 96),
                                               scale=0.5),
                 weight=1.0, slo_s=1.0, max_queue=64, max_batch=4),
    TenantConfig("embedder", zoo.resnet34(input_size=(96, 96), scale=0.25),
                 weight=1.0, slo_s=1.0, max_queue=64, max_batch=4),
]

# params are pre-staged on every device, so re-partitions pay a fast
# local reload instead of a WLAN push; the execution backend is one
# ExecSpec shared by every tenant pipeline
sched = ServingScheduler(tenants, cluster,
                         config=SchedulerConfig(seed=0,
                                                migration_bandwidth=1e9),
                         exec_spec=ExecSpec())
print("initial device split:")
for name, devs in {ts.cfg.name: [d.name for d in ts.share.cluster.devices]
                   for ts in sched._tenants.values()}.items():
    print(f"  {name:11s} -> {devs}")

# seeded open-loop traffic at ~70% of each tenant's capacity, bursty on
# the detector; all streams span the same window so they overlap
workload = {}
for i, ts in enumerate(sched._tenants.values()):
    rate = 0.7 / ts.share.pico.period
    gen = OpenLoopGenerator(rate_per_s=rate, seed=i,
                            burst_factor=3.0 if i == 0 else 1.0,
                            burst_period_s=1.0)
    workload[ts.cfg.name] = gen.generate(max(8, int(rate * 3.0)))

# churn during traffic: the weakest Pi drops out halfway through
horizon = max(r.arrival for rs in workload.values() for r in rs)
report = sched.serve(workload,
                     churn=[DeviceLeave(0.5 * horizon, "pi7@0.8GHz")])

print(f"\nserved {report.served} requests in {report.makespan:.2f}s "
      f"virtual ({report.throughput_per_min:.0f}/min aggregate), "
      f"{report.dropped_inflight} in-flight frames lost")
for name, s in report.tenants.items():
    print(f"  {name:11s} served={s.served:4d} rejected={s.rejected:3d} "
          f"expired={s.expired:3d} p50={s.p50_latency_s * 1e3:6.1f}ms "
          f"p95={s.p95_latency_s * 1e3:6.1f}ms "
          f"miss-rate={s.deadline_miss_rate:.1%}")
for r in report.repartitions:
    sizes = {n: len(d) for n, d in r.assignment.items()}
    print(f"  re-partition @{r.time:.2f}s ({r.reason}): {sizes}, "
          f"migration {r.migration_s * 1e3:.1f}ms")
print(f"  stage-executable cache: {report.cache.hits} hits / "
      f"{report.cache.misses} misses across re-plans")

# the naive alternative: each tenant gets the whole cluster in turn
base = serve_time_sliced(tenants, cluster, workload)
ratio = report.throughput_per_min / base.throughput_per_min
print(f"\ntime-sliced baseline: {base.throughput_per_min:.0f}/min "
      f"-> partitioned scheduler is {ratio:.2f}x faster")
