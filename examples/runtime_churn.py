"""Demo: an 8-device heterogeneous cluster surviving churn.

Streams 240 frames of a VGG16-class workload through the event-driven
runtime while the cluster degrades and recovers around it:

  * t = 60 periods   the fastest device drops out      (re-plan: leave)
  * t = 120 periods  a device throttles to half clock  (re-plan: drift,
                     detected by the monitor's EWMA — nobody tells the
                     runtime about the throttle)
  * t = 160 periods  the dropped device's replacement joins
  * t = 200 periods  the WLAN hop degrades 2x

Run:  PYTHONPATH=src python examples/runtime_churn.py
"""

import repro
from repro.core import Device, make_pi_cluster
from repro.models.cnn import zoo
from repro.runtime import (DeviceJoin, DeviceLeave, FreqScale, LinkDegrade,
                           validate)


def main():
    m = zoo.vgg16(input_size=(224, 224), scale=0.25)
    cluster = make_pi_cluster([1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8])
    dep = repro.compile(m, cluster)
    P = dep.period
    print(f"model {m.name}: {len(m.graph.layers)} layers, "
          f"{len(dep.pipeline.stages)} stages, period {P*1e3:.2f} ms, "
          f"{60/P:.0f} frames/min on {len(cluster)} devices")

    # sanity: the event runtime reproduces the closed-form simulator
    v = validate(m.graph, cluster, m.input_size, pico=dep.pico, frames=32)
    print(f"runtime vs simulator: {v}")

    fastest = max(cluster.devices, key=lambda d: d.capacity)
    throttled = cluster.devices[2]
    churn = [
        DeviceLeave(60 * P, fastest.name),
        FreqScale(120 * P, throttled.name, 0.5),
        DeviceJoin(160 * P, Device("pi-spare@1.5GHz", capacity=3e9,
                                   active_power=6.25, idle_power=1.6)),
        LinkDegrade(200 * P, 2.0),
    ]
    rt = dep.runtime(repro.DeploySpec(seed=0), churn=churn,
                     real_compute=False)
    rep = rt.run(240)

    print(f"\ncompleted {rep.completed}/{rep.frames} frames in "
          f"{rep.makespan:.2f}s virtual ({rep.throughput_per_min:.0f}/min "
          f"overall), {rep.restarts} frame restart(s)")
    print("\nre-plans:")
    for r in rep.replans:
        print(f"  t={r.time:7.3f}s  {r.reason:>6}: period "
              f"{r.old_period*1e3:6.2f} -> {r.new_period*1e3:6.2f} ms on "
              f"{r.n_devices} devices; migrated "
              f"{r.migration_bytes/1e6:.2f} MB in {r.migration_s*1e3:.1f} ms "
              f"(plan wall {r.wall_s*1e3:.0f} ms)")

    print("\nthroughput by phase (frames/min):")
    marks = [0.0] + [r.time for r in rep.replans] + [rep.makespan]
    for a, b in zip(marks, marks[1:]):
        if b > a:
            print(f"  [{a:7.3f}, {b:7.3f})  "
                  f"{rep.windowed_throughput(a, b) * 60:8.1f}")

    print("\nper-device (busiest first):")
    for d in sorted(rep.devices, key=lambda d: -d.busy_s)[:10]:
        print(f"  {d.device:>16}: util {d.utilization:5.1%}  "
              f"frames {d.frames:3d}  peak mem {d.memory_peak_bytes/1e6:6.1f} MB  "
              f"energy {d.energy_j:7.1f} J")


if __name__ == "__main__":
    main()
