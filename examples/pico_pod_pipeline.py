"""PICO at pod scale: plan a decoder's pipeline split with the PICO DP,
then EXECUTE it as a GPipe-style shard_map pipeline over a mesh axis —
the form the paper's technique takes on TPU pods, where stage-boundary
activations are the only cross-group traffic (DESIGN.md §5).

Runs on 8 host devices (set before jax import) and verifies the
pipelined result equals the monolithic forward bit-for-bit.

    PYTHONPATH=src python examples/pico_pod_pipeline.py
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import make_tpu_cluster, plan
from repro.models.graph_export import export_graph
from repro.models.transformer import model as M
from repro.models.transformer.layers import (attention_prefill, mlp,
                                             rms_norm)
from repro.pipeline.runner import microbatch_pipeline

N_STAGES = 4
cfg = configs.get("llama3.2-1b").reduced(n_layers=8, d_model=128)

# 1. PICO plans the stage split (graph export -> Alg.1 pieces -> Alg.2)
g = export_graph(cfg, seq_len=64)
pico = plan(g, make_tpu_cluster(N_STAGES), (64, 1), max_diameter=2)
print(f"PICO split {cfg.n_layers} layers into "
      f"{len(pico.pipeline.stages)} stages; period "
      f"{pico.period*1e6:.1f} us (modeled)")

# 2. materialize the split: this reduced config is uniform, so the DP's
#    balanced answer is contiguous equal layer ranges
assert cfg.n_layers % N_STAGES == 0
per_stage = cfg.n_layers // N_STAGES
params = M.init_params(cfg, jax.random.PRNGKey(0))
layers = params["layers"]
stage_params = jax.tree.map(
    lambda a: a.reshape(N_STAGES, per_stage, *a.shape[1:]), layers)


def stage_fn(sid, lp, x):
    """Apply this stage's `per_stage` transformer layers."""
    def body(x, one):
        h, _ = attention_prefill(
            one["attn"], rms_norm(x, one["ln1"], cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
            rope_theta=cfg.rope_theta, sliding_window=cfg.sliding_window)
        x = x + h
        x = x + mlp(one["mlp"], rms_norm(x, one["ln2"], cfg.norm_eps))
        return x, None
    x, _ = jax.lax.scan(body, x, lp)
    return x


# 3. run 6 microbatches through the 4-stage pipeline on the mesh
mesh = jax.make_mesh((N_STAGES,), ("stage",),
                     axis_types=(jax.sharding.AxisType.Auto,))
toks = jax.random.randint(jax.random.PRNGKey(1), (6, 2, 64), 0,
                          cfg.vocab_size)
xs = params["embed"][toks]                       # (6, 2, 64, d)
out = microbatch_pipeline(stage_fn, stage_params, xs, mesh, axis="stage")

# 4. reference: monolithic forward of the same stack
ref = xs
for s in range(N_STAGES):
    lp = jax.tree.map(lambda a: a[s], stage_params)
    ref = jax.vmap(lambda x: stage_fn(s, lp, x))(ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5,
                           atol=2e-5)
print(f"4-stage shard_map pipeline over {N_STAGES} devices matches the "
      f"monolithic forward ✓ (out {out.shape})")
print("cross-stage traffic per tick: one (2, 64, d) activation via "
      "ppermute — the paper's 'narrow waist' on the pod axis")
