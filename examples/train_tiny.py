"""Train a small decoder LM (reduced llama3.2 family config) for a few
hundred steps on the synthetic token stream — exercises the training
substrate end to end (data -> AdamW + cosine LR -> ckpt).

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""

import argparse

from repro import configs
from repro.training.loop import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
args = ap.parse_args()

cfg = configs.get("llama3.2-1b").reduced(n_layers=2, d_model=128)
print(f"arch: {cfg.name} ({cfg.param_count()/1e6:.1f} M params)")

rep = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
            lr=3e-3, log_every=25, ckpt_path="/tmp/repro_tiny_ckpt")
first = sum(rep.losses[:10]) / 10
last = sum(rep.losses[-10:]) / 10
print(f"loss {first:.3f} -> {last:.3f} over {rep.steps} steps "
      f"({rep.tokens/rep.wall_s:.0f} tok/s)")
assert last < first, "training failed to reduce loss"
print("checkpoint saved to /tmp/repro_tiny_ckpt.npz")
