"""Shared benchmark helpers: paper-scale models, clusters, reporting."""

from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (Cluster, Device, make_pi_cluster, plan,  # noqa: E402
                        partition_graph, simulate, stage_cost)
from repro.core.partition import Piece, chain_pieces  # noqa: E402
from repro.models.cnn import zoo  # noqa: E402


def paper_models():
    return {
        "vgg16": zoo.vgg16(input_size=(224, 224)),
        "yolov2": zoo.yolov2(input_size=(448, 448)),
    }


def paper_cluster(n: int, freq: float = 1.0) -> Cluster:
    """n Raspberry-Pis at `freq` GHz, 50 Mbps WLAN (paper testbed)."""
    return make_pi_cluster([freq] * n)


def hetero_cluster() -> Cluster:
    """Paper §6.1: 2x Nvidia TX2 NX @2.2 + Pis at 1.5/1.2/0.8 GHz."""
    c = make_pi_cluster([1.5, 1.5, 1.2, 1.2, 0.8, 0.8])
    nx = [Device(f"NX{i}@2.2GHz", capacity=2.2e9 * 2, active_power=10.0,
                 idle_power=2.5) for i in range(2)]
    return Cluster(nx + c.devices, bandwidth=c.bandwidth)


def single_device_latency(model, cluster) -> float:
    single = Cluster([max(cluster.devices, key=lambda d: d.capacity)],
                     bandwidth=cluster.bandwidth)
    full = model.graph.forward_sizes(model.input_size)
    sc = stage_cost(model.graph, frozenset(model.graph.layers), full,
                    model.input_size, single.devices, single)
    return sc.total


def csv_row(name: str, us_per_call: float, derived) -> str:
    row = f"{name},{us_per_call:.3f},{derived}"
    print(row, flush=True)
    return row


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
