"""Distributed pipeline execution vs the single-process compiled path.

One VGG16 deployment on a 4-device heterogeneous Pi cluster runs the
same frame stream two ways:

* **single** — the in-process compiled :class:`PipelineRunner`, one
  frame at a time (the oracle path);
* **pipeline** — a threads-mode :class:`~repro.dist.launcher.
  DistLauncher`: one real worker per planned stage, frames moving as
  length-prefixed wire messages over in-memory queue links (the same
  codec TCP uses), back-pressure and drain exactly as in production.

Reported alongside the two lanes: the **transport overhead fraction**
(wire encode + send wall over total run wall) and the two hard
correctness gates — distributed outputs **bit-identical** to the
single-process path, and **zero dropped** in-flight frames across the
clean shutdown.  Only those two (deterministic, self-normalized) rows
are gated in CI; the timing lanes vary with host hardware.

Rows::

    dist_exec.single         us per frame (in-process oracle)
    dist_exec.pipeline       us per frame, fps=<...>;workers=<n>;...
    dist_exec.transport      us per frame on the wire, overhead=<frac>
    dist_exec.bit_identical  compare us, <1.0|0.0>                 (gated)
    dist_exec.dropped        account us, <count>                   (gated)
"""

from __future__ import annotations

import numpy as np

from .common import Timer, csv_row, make_pi_cluster
from repro.api.deployment import compile as dep_compile
from repro.api.specs import DistSpec
from repro.dist import make_frames
from repro.dist.validate import reference_outputs
from repro.models.cnn import zoo

CAPS = [1.5, 1.2, 1.0, 0.8]              # 4 hetero Pi workers

SMOKE = dict(size=(96, 96), scale=0.5, frames=8)
FULL = dict(size=(224, 224), scale=1.0, frames=32)


def run(smoke: bool = False) -> list[str]:
    cfg = SMOKE if smoke else FULL
    rows: list[str] = []
    model = zoo.vgg16(input_size=cfg["size"], scale=cfg["scale"])
    dep = dep_compile(model, make_pi_cluster(CAPS))
    xs = make_frames(model, cfg["frames"])

    # ---- single-process oracle lane (also the reference outputs) -----
    ref = reference_outputs(dep, xs)          # first call pays compile
    with Timer() as t_single:
        ref = reference_outputs(dep, xs)
    single_us = 1e6 * t_single.s / len(xs)
    rows.append(csv_row("dist_exec.single", single_us,
                        f"fps={len(xs) / t_single.s:.2f}"))

    # ---- distributed lane: threads + in-memory wire links ------------
    launcher = dep.fleet(DistSpec(transport="memory", workers="thread"))
    launcher.start()                          # warmup probe compiles
    with Timer() as t_pipe:
        rep = launcher.run(xs)
    pipe_us = 1e6 * t_pipe.s / len(xs)
    rows.append(csv_row(
        "dist_exec.pipeline", pipe_us,
        f"fps={len(xs) / t_pipe.s:.2f};workers={rep.n_stages};"
        f"util={rep.utilization():.3f}"))

    # ---- transport overhead: wire send wall over run wall ------------
    send_s = sum(st.get("send_s", 0.0) for st in rep.worker_stats.values())
    send_s += sum(ls.get("send_s", 0.0) for ls in rep.link_stats.values())
    wire_bytes = sum(st.get("bytes_out", 0)
                     for st in rep.worker_stats.values())
    overhead = send_s / (max(rep.n_stages, 1) * t_pipe.s)
    rows.append(csv_row("dist_exec.transport", 1e6 * send_s / len(xs),
                        f"overhead={overhead:.4f};mb={wire_bytes / 1e6:.1f}"))

    # ---- hard gates: bit-identity + zero silent loss ------------------
    with Timer() as t_cmp:
        identical = (
            len(rep.outputs) == len(ref)
            and all(np.array_equal(rep.outputs[fid][sink], arr)
                    for fid, want in enumerate(ref)
                    for sink, arr in want.items()))
    rows.append(csv_row("dist_exec.bit_identical", 1e6 * t_cmp.s,
                        f"{1.0 if identical else 0.0}"))
    rows.append(csv_row("dist_exec.dropped", 0.0,
                        f"{float(len(rep.dropped))}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(smoke=args.smoke)
