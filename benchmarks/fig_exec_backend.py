"""Exec-backend microbenchmark: eager tile loop vs compiled whole-stage.

Runs the fig13 pipeline model (VGG16) on a paper-style Pi cluster in
two forms and times the seed's eager per-tile Python loop against the
``repro.exec`` compiled executables:

* ``stage_*`` — the whole network as ONE fused stage tile-split across
  every device (the paper's fused-layer scheme §2.4.2): the eager path
  re-interprets the DAG per tile, the compiled path is a single jitted
  program over all tiles.  This is the headline compiled/eager speedup
  (acceptance bar: >= 2x on CPU, where per-op dispatch dominates).
* ``pipeline_*`` — the full PICO plan executed stage by stage, plus
  the ``lax.scan`` micro-batched stream path.

The calibration row closes the loop: measured CostTable -> re-plan,
reporting how far the analytic period was from measured reality.

Rows::

    exec/<model>_stage_eager        us per frame
    exec/<model>_stage_compiled     us per frame, speedup vs eager
    exec/<model>_pipeline_eager     us per frame
    exec/<model>_pipeline_compiled  us per frame, speedup + cache stats
    exec/<model>_pipeline_scan      us per frame (micro-batched stream)
    exec/<model>_calibration        calibration wall us, ratio stats
"""

from __future__ import annotations

import time

import jax

from .common import csv_row, paper_cluster
from repro.core import plan, replan
from repro.exec import cache_stats, calibrate_plan, clear_cache
from repro.models.cnn import zoo
from repro.pipeline import PipelineRunner
from repro.pipeline.stage import StageExecutor

# the fig13 pipeline model (VGG16), scaled so both paths run in seconds
# on CPU while the eager loop still pays its per-tile dispatch tax
FULL = dict(model=dict(input_size=(112, 112), scale=0.2, head=False),
            n_devices=8, n_frames=6)
SMOKE = dict(model=dict(input_size=(64, 64), scale=0.1, head=False),
             n_devices=4, n_frames=4)


def _time_per_frame(fn, frames, warmup: int = 1, iters: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(frames[0]))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        for f in frames:
            jax.block_until_ready(fn(f))
        best = min(best, (time.perf_counter() - t0) / len(frames))
    return best


def run(smoke: bool = False) -> list[str]:
    rows = []
    cfg = SMOKE if smoke else FULL
    m = zoo.vgg16(**cfg["model"])
    cluster = paper_cluster(cfg["n_devices"])
    params = m.init(jax.random.PRNGKey(0))
    w, h = m.input_size
    frames = [jax.random.normal(jax.random.PRNGKey(i), (1, h, w, 3))
              for i in range(cfg["n_frames"])]
    clear_cache()

    # ---- whole network as one fused, tile-split stage ----------------
    nodes = frozenset(m.graph.layers)
    fracs = [d.capacity / cluster.total_capacity for d in cluster.devices]
    eager_st = StageExecutor(m, nodes, fracs, mode="eager")
    comp_st = StageExecutor(m, nodes, fracs)
    t_e = _time_per_frame(lambda f: eager_st(params, {}, f), frames)
    rows.append(csv_row(f"exec/{m.name}_stage_eager", t_e * 1e6,
                        f"tiles={cfg['n_devices']}"))
    t_c = _time_per_frame(lambda f: comp_st(params, {}, f), frames)
    rows.append(csv_row(f"exec/{m.name}_stage_compiled", t_c * 1e6,
                        f"speedup={t_e / t_c:.2f}"))

    # ---- full PICO plan, stage by stage ------------------------------
    clear_cache()            # report this section's cache behavior alone
    pico = plan(m.graph, cluster, m.input_size)
    eager_pl = PipelineRunner(m, pico.pipeline, mode="eager")
    comp_pl = PipelineRunner(m, pico.pipeline)
    t_pe = _time_per_frame(lambda f: eager_pl(params, f), frames)
    rows.append(csv_row(f"exec/{m.name}_pipeline_eager", t_pe * 1e6,
                        f"stages={len(pico.pipeline.stages)}"))
    t_pc = _time_per_frame(lambda f: comp_pl(params, f), frames)
    st = cache_stats()
    rows.append(csv_row(f"exec/{m.name}_pipeline_compiled", t_pc * 1e6,
                        f"speedup={t_pe / t_pc:.2f};cache_hits={st.hits};"
                        f"cache_misses={st.misses}"))

    # micro-batched stream: one lax.scan dispatch per stage for the
    # whole frame stack
    stack = jax.numpy.stack(frames)
    jax.block_until_ready(comp_pl.run_frames(params, stack))   # compile
    t0 = time.perf_counter()
    jax.block_until_ready(comp_pl.run_frames(params, stack))
    t_scan = (time.perf_counter() - t0) / len(frames)
    rows.append(csv_row(f"exec/{m.name}_pipeline_scan", t_scan * 1e6,
                        f"speedup={t_pe / t_scan:.2f};"
                        f"frames={cfg['n_frames']}"))

    # ---- calibration round-trip: measured CostTable -> re-plan -------
    t0 = time.perf_counter()
    rep = calibrate_plan(m, params, pico.pipeline.stages, iters=1)
    calib_wall = time.perf_counter() - t0
    pico2 = replan(m.graph, cluster, m.input_size, prev=pico,
                   cost_table=rep.table())
    ratios = [s.ratio for s in rep.stages]
    rows.append(csv_row(
        f"exec/{m.name}_calibration", calib_wall * 1e6,
        f"ratio_min={min(ratios):.2f};ratio_max={max(ratios):.2f};"
        f"analytic_period_s={pico.period:.4f};"
        f"measured_period_s={pico2.period:.4f}"))
    return rows


if __name__ == "__main__":
    run()
