"""Paper Table 5: per-device utilization, redundancy ratio and memory
footprint on the heterogeneous cluster (2x NX + 6x Pi) for CE / EFL /
OFL / PICO on VGG16 and YOLOv2."""

from __future__ import annotations

from .common import csv_row, hetero_cluster
from repro.core import baselines as B
from repro.core import partition_graph, simulate
from repro.models.cnn import zoo


def run() -> list[str]:
    rows = []
    cluster = hetero_cluster()
    for name, m in (("vgg16", zoo.vgg16(input_size=(224, 224))),
                    ("yolov2", zoo.yolov2(input_size=(448, 448)))):
        part = partition_graph(m.graph, m.input_size, n_split=8)
        schemes = {
            "CE": B.coedge(m.graph, cluster, m.input_size),
            "EFL": B.early_fused(m.graph, cluster, m.input_size),
            "OFL": B.optimal_fused(m.graph, cluster, m.input_size,
                                   part.pieces),
            "PICO": B.pico_scheme(m.graph, part.pieces, cluster,
                                  m.input_size),
        }
        for sname, res in schemes.items():
            if sname == "PICO":
                rep = simulate(res.extra["plan"], frames=32)
                for d in rep.devices:
                    rows.append(csv_row(
                        f"table5/{name}_{sname}_{d.device}",
                        res.period * 1e6,
                        f"util={d.utilization:.3f};redu={d.redundancy:.3f};"
                        f"mem_mb={d.memory_bytes/1e6:.1f}"))
                rows.append(csv_row(
                    f"table5/{name}_{sname}_avg", res.period * 1e6,
                    f"util={rep.avg_utilization:.3f};"
                    f"redu={rep.avg_redundancy:.3f};"
                    f"mem_mb={rep.avg_memory/1e6:.1f}"))
            else:
                busy = res.per_device_busy
                period = res.period
                for d in cluster.devices:
                    util = busy.get(d.name, 0.0) / period if period else 0
                    rows.append(csv_row(
                        f"table5/{name}_{sname}_{d.name}",
                        res.period * 1e6,
                        f"util={util:.3f};"
                        f"redu={res.redundancy_ratio:.3f};"
                        f"mem_mb={res.memory_bytes.get(d.name, 0)/1e6:.1f}"))
                rows.append(csv_row(
                    f"table5/{name}_{sname}_avg", res.period * 1e6,
                    f"util={sum(busy.values())/period/len(cluster):.3f};"
                    f"redu={res.redundancy_ratio:.3f};"
                    f"mem_mb={sum(res.memory_bytes.values())/len(cluster)/1e6:.1f}"))
    return rows


if __name__ == "__main__":
    run()
