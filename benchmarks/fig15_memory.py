"""Paper Fig. 15 (+ Fig. 16): memory footprint and energy vs #devices.

LW/EFL/OFL replicate the whole model on every device; PICO distributes
both model and features — average per-device memory drops with devices.
Energy comes from the simulator's active/idle power model.
"""

from __future__ import annotations

from .common import csv_row, paper_cluster
from repro.core import baselines as B
from repro.core import partition_graph, simulate
from repro.models.cnn import zoo


def run() -> list[str]:
    rows = []
    m = zoo.vgg16(input_size=(224, 224))
    part = partition_graph(m.graph, m.input_size, n_split=8)
    for n_dev in (2, 4, 6, 8):
        cluster = paper_cluster(n_dev, 1.0)
        schemes = {
            "LW": B.layer_wise(m.graph, cluster, m.input_size),
            "EFL": B.early_fused(m.graph, cluster, m.input_size),
            "OFL": B.optimal_fused(m.graph, cluster, m.input_size,
                                   part.pieces),
            "PICO": B.pico_scheme(m.graph, part.pieces, cluster,
                                  m.input_size),
        }
        for sname, res in schemes.items():
            if sname == "PICO":
                rep = simulate(res.extra["plan"], frames=32)
                mem = rep.avg_memory
                energy = rep.total_energy_j / rep.frames
            else:
                mem = (sum(res.memory_bytes.values())
                       / max(len(res.memory_bytes), 1))
                # all devices busy-or-idle for the whole period
                busy = sum(res.per_device_busy.values())
                idle = res.period * n_dev - busy
                energy = busy * 5.0 + idle * 1.6
            rows.append(csv_row(
                f"fig15/vgg16_{sname}_d{n_dev}", res.period * 1e6,
                f"avg_mem_mb={mem/1e6:.1f};energy_j_per_frame={energy:.2f}"))
    return rows


if __name__ == "__main__":
    run()
