"""Multi-tenant serving benchmark: partitioned scheduler vs time-sliced.

Three CNN tenants share an 8-device heterogeneous Pi cluster.  The
:class:`~repro.serving.ServingScheduler` splits the devices across
tenants (weighted by load) and runs the three pipelines concurrently
with continuous micro-batching; the baseline serves the same workload
by giving each tenant the whole cluster in turn (weighted round-robin
time slices, paying parameter re-upload + pipeline refill per switch).
Pipeline scaling is sublinear over the WLAN, so right-sized sub-clusters
win — the acceptance bar is **>= 1.5x** aggregate throughput.

The churn scenario streams moderate (65% capacity) load and kills one
device mid-traffic: the scheduler drains in-flight batches (zero
dropped frames), re-splits the surviving devices, re-plans each tenant
(piece chains + executable cache reused), and must recover **>= 95%**
of pre-churn throughput.

Rows::

    serving_mt.multitenant       us per request, tput=<req/min>
    serving_mt.timesliced        us per request, tput=<req/min>
    serving_mt.throughput_ratio  multitenant us, <ratio>        (gated)
    serving_mt.churn_recovery    replan wall us, <post/pre>     (gated)
    serving_mt.dropped_inflight  migration us, <count>          (gated)
"""

from __future__ import annotations

from .common import csv_row
from repro.core import make_pi_cluster
from repro.models.cnn import zoo
from repro.runtime import DeviceLeave
from repro.serving import (OpenLoopGenerator, SchedulerConfig,
                           ServingScheduler, TenantConfig, serve_time_sliced)

SMOKE = dict(size=(96, 96), duration_s=1.5, churn_duration_s=3.0)
FULL = dict(size=(128, 128), duration_s=4.0, churn_duration_s=8.0)


def _tenants(size) -> list[TenantConfig]:
    return [
        TenantConfig("squeezenet", zoo.squeezenet(input_size=size, scale=0.5),
                     max_batch=4),
        TenantConfig("mobilenetv3", zoo.mobilenetv3(input_size=size,
                                                    scale=0.5), max_batch=4),
        TenantConfig("resnet34", zoo.resnet34(input_size=size, scale=0.25),
                     max_batch=4),
    ]


def _cluster():
    return make_pi_cluster([1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8])


def _workload(sched: ServingScheduler, duration_s: float,
              load: float) -> dict:
    """Open-loop Poisson streams at ``load`` x each tenant's planned
    sub-pipeline capacity (load > 1 saturates), all spanning the same
    ``duration_s`` so the tenants' traffic actually overlaps."""
    out = {}
    for i, ts in enumerate(sched._tenants.values()):
        rate = load / ts.share.pico.period
        gen = OpenLoopGenerator(rate_per_s=rate, seed=17 + i)
        out[ts.cfg.name] = gen.generate(max(8, int(rate * duration_s)))
    return out


def run(smoke: bool = False) -> list[str]:
    rows = []
    cfg = SMOKE if smoke else FULL

    # ---- saturated throughput: partitioned vs time-sliced ------------
    tenants = _tenants(cfg["size"])
    cluster = _cluster()
    sched = ServingScheduler(tenants, cluster)
    workload = _workload(sched, cfg["duration_s"], load=2.0)
    rep = sched.serve(workload)
    base = serve_time_sliced(tenants, cluster, workload)
    mt_tput = rep.throughput_per_min
    sl_tput = base.throughput_per_min
    mt_us = 1e6 * rep.makespan / max(rep.served, 1)
    sl_us = 1e6 * base.makespan / max(base.served, 1)
    rows.append(csv_row("serving_mt.multitenant", mt_us,
                        f"tput={mt_tput:.1f}"))
    rows.append(csv_row("serving_mt.timesliced", sl_us,
                        f"tput={sl_tput:.1f}"))
    ratio = mt_tput / sl_tput if sl_tput > 0 else 0.0
    rows.append(csv_row("serving_mt.throughput_ratio", mt_us,
                        f"{ratio:.3f}"))

    # ---- churn during traffic: drop a device mid-stream --------------
    # parameters are pre-staged on every device (the usual multi-tenant
    # deployment: models cached on local flash), so a re-partition pays
    # a fast local reload instead of a WLAN push
    tenants = _tenants(cfg["size"])
    cluster = _cluster()
    sched = ServingScheduler(tenants, cluster,
                             config=SchedulerConfig(
                                 seed=3, migration_bandwidth=1e9))
    workload = _workload(sched, cfg["churn_duration_s"], load=0.65)
    horizon = max(r.arrival for reqs in workload.values() for r in reqs)
    drop_t = 0.5 * horizon
    weakest = min(cluster.devices, key=lambda d: d.capacity)
    rep = sched.serve(workload, churn=[DeviceLeave(drop_t, weakest.name)])
    mig_end = max((r.time + r.migration_s for r in rep.repartitions
                   if r.reason == "leave"), default=drop_t)
    # recovery = served/offered in the post-migration window relative to
    # served/offered pre-churn — normalizing by the Poisson realization
    # so window-to-window arrival noise doesn't masquerade as capacity
    reqs = [r for rs in workload.values() for r in rs]
    pre = rep.windowed_throughput(0.0, drop_t)
    post = rep.windowed_throughput(mig_end, max(horizon, mig_end + 1e-9))
    off_pre = sum(1 for r in reqs if r.arrival < drop_t) / drop_t
    off_post = (sum(1 for r in reqs if mig_end <= r.arrival < horizon)
                / max(horizon - mig_end, 1e-9))
    recovery = ((post / off_post) / (pre / off_pre)
                if min(pre, off_pre, off_post) > 0 else 0.0)
    replan_wall = sum(r.wall_s for r in rep.repartitions)
    mig_s = sum(r.migration_s for r in rep.repartitions)
    rows.append(csv_row("serving_mt.churn_recovery", replan_wall * 1e6,
                        f"{recovery:.3f}"))
    rows.append(csv_row("serving_mt.dropped_inflight", mig_s * 1e6,
                        f"{rep.dropped_inflight}"))
    return rows


if __name__ == "__main__":
    run()
