"""Paper Tables 6-7 + Figs. 17-18: PICO vs exhaustive BFS optimum.

Table 6: graph-structured CNN x homogeneous devices.
Table 7: chain CNN x heterogeneous devices.
Reports optimization wall time for both and the period ratio
(PICO period / BFS period) where BFS finished within budget.
"""

from __future__ import annotations

from .common import csv_row, paper_cluster, Timer
from repro.core import (Cluster, make_pi_cluster, partition_graph)
from repro.core import baselines as B
from repro.core.partition import Piece, chain_pieces
from repro.models.cnn import zoo
from repro.models.cnn.builder import GB

BFS_BUDGET_S = 60.0


def synthetic_graph_cnn(branches: int, layers: int):
    """Paper Table 6 graphs: `branches` parallel paths, `layers` convs."""
    b = GB(f"g{branches}x{layers}", (64, 64))
    stem = b.conv(None, 16, 3, p=1)
    per = max(1, (layers - 2) // branches)
    outs = []
    for br in range(branches):
        x = stem
        for i in range(per):
            x = b.conv(x, 16, 3, p=1)
        outs.append(x)
    x = b.concat(outs) if len(outs) > 1 else outs[0]
    x = b.conv(x, 16, 1)
    return b.done()


def synthetic_chain_cnn(layers: int):
    b = GB(f"chain{layers}", (64, 64))
    x = None
    for i in range(layers):
        x = b.conv(x, 16, 3, p=1)
    return b.done()


def run(fast: bool = True) -> list[str]:
    rows = []
    # --- Table 6: graph CNN, homogeneous devices
    cases6 = [(2, 8, 4), (3, 12, 4)] + ([] if fast else [(3, 12, 6)])
    for br, ly, nd in cases6:
        m = synthetic_graph_cnn(br, ly)
        cluster = paper_cluster(nd, 1.0)
        with Timer() as tp:
            part = partition_graph(m.graph, m.input_size, n_split=nd)
            pico = B.pico_scheme(m.graph, part.pieces, cluster,
                                 m.input_size)
        bfs = B.bfs_optimal(m.graph, part.pieces, cluster, m.input_size,
                            budget_s=BFS_BUDGET_S)
        ratio = pico.period / bfs.period if bfs.extra["complete"] else None
        rows.append(csv_row(
            f"table6/branches{br}_layers{ly}_dev{nd}", tp.s * 1e6,
            f"pico_s={tp.s:.3f};bfs_s={bfs.wall_time_s:.3f};"
            f"bfs_complete={bfs.extra['complete']};"
            f"configs={bfs.extra.get('configs_evaluated')};"
            f"period_ratio={ratio if ratio is None else round(ratio,3)}"))
    # --- Table 7: chain CNN, heterogeneous devices
    cases7 = [(4, 4), (8, 4)] + ([] if fast else [(12, 4), (8, 6)])
    for ly, nd in cases7:
        m = synthetic_chain_cnn(ly)
        freqs = [1.5, 1.2, 1.0, 0.8, 0.7, 0.6][:nd]
        cluster = make_pi_cluster(freqs)
        pieces = [Piece(ns, 0.0, i)
                  for i, ns in enumerate(chain_pieces(m.graph))]
        with Timer() as tp:
            pico = B.pico_scheme(m.graph, pieces, cluster, m.input_size)
        bfs = B.bfs_optimal(m.graph, pieces, cluster, m.input_size,
                            budget_s=BFS_BUDGET_S)
        ratio = pico.period / bfs.period if bfs.extra["complete"] else None
        rows.append(csv_row(
            f"table7/layers{ly}_dev{nd}", tp.s * 1e6,
            f"pico_s={tp.s:.3f};bfs_s={bfs.wall_time_s:.3f};"
            f"bfs_complete={bfs.extra['complete']};"
            f"configs={bfs.extra.get('configs_evaluated')};"
            f"period_ratio={ratio if ratio is None else round(ratio,3)}"))
    return rows


if __name__ == "__main__":
    run(fast=False)
