"""Paper Figs. 13-14: cluster capacity executing VGG16 / YOLOv2 under
LW / EFL / OFL / CE / PICO with 2-8 devices and several CPU frequencies.

Reports the pipeline period (s) and throughput (tasks/min), plus the
speedup over one device — the paper's headline 1.8-6.8x range.
"""

from __future__ import annotations

from .common import csv_row, paper_cluster, single_device_latency
from repro.core import baselines as B
from repro.core import partition_graph, plan
from repro.models.cnn import zoo

FREQS = (0.6, 1.0, 1.5)
DEVICES = (2, 4, 6, 8)


def run(models=("vgg16", "yolov2")) -> list[str]:
    rows = []
    builders = {"vgg16": lambda: zoo.vgg16(input_size=(224, 224)),
                "yolov2": lambda: zoo.yolov2(input_size=(448, 448))}
    for name in models:
        m = builders[name]()
        part = partition_graph(m.graph, m.input_size, n_split=8)
        for freq in FREQS:
            for n_dev in DEVICES:
                cluster = paper_cluster(n_dev, freq)
                single = single_device_latency(m, cluster)
                results = {
                    "LW": B.layer_wise(m.graph, cluster, m.input_size),
                    "EFL": B.early_fused(m.graph, cluster, m.input_size),
                    "OFL": B.optimal_fused(m.graph, cluster, m.input_size,
                                           part.pieces),
                    "CE": B.coedge(m.graph, cluster, m.input_size),
                    "PICO": B.pico_scheme(m.graph, part.pieces, cluster,
                                          m.input_size),
                }
                for sname, res in results.items():
                    rows.append(csv_row(
                        f"fig13/{name}_{sname}_f{freq}_d{n_dev}",
                        res.period * 1e6,
                        f"throughput_per_min={60/res.period:.2f};"
                        f"speedup={single/res.period:.2f};"
                        f"latency_s={res.latency:.3f}"))
    return rows


if __name__ == "__main__":
    run()
