"""Multi-objective Pareto front benchmark: ``repro.plan_front`` sweep.

For each model, sweep the configuration space (device subsets x latency
budgets, one shared :class:`~repro.core.pipeline_dp.PlannerCache`) and
check the front's contract against the single-objective planner:

* **non-dominated** — every pair of front points is mutually
  non-dominated over (period, latency, energy, memory);
* **contains the optimum** — some front point is at least as good as
  the pure-throughput plan on *every* axis (the plan itself, or, on
  comm-bound models where extra devices only add idle energy, one that
  strictly dominates it);
* **wins** — front points that beat the throughput-only plan on energy
  or peak memory at equal-or-better latency: the trade-off the sweep
  exists to surface.

Rows::

    pareto.<model>    sweep us, points=N;nondominated=<1|0>;
                      contains_opt=<1|0>;wins=K
    pareto.summary    total us, front_ok=<1|0>;wins=<total>      (gated)

``front_ok`` is 1.0 only when every model's front is mutually
non-dominated AND contains the optimum; ``wins`` sums over models and
must stay >= 1 (the acceptance bar: at least one front point dominates
the throughput-only plan on energy or memory at no latency cost).
"""

from __future__ import annotations

from .common import Timer, csv_row, make_pi_cluster
from repro.core import plan_front, plan_metrics
from repro.core.pareto import dominates
from repro.core.planner import plan_with_spec
from repro.models.cnn import zoo

CAPS = [1.5, 1.2, 1.0, 0.8]            # 4-device hetero Pi cluster

SMOKE = dict(size=(64, 64), scale=0.25,
             models=("vgg16", "squeezenet", "resnet34"))
FULL = dict(size=(224, 224), scale=1.0,
            models=("vgg16", "squeezenet", "resnet34"))


def _wins(front, base_metrics) -> int:
    """Front points beating the throughput plan on energy or memory at
    equal-or-better latency (strictly better somewhere, never worse on
    latency)."""
    n = 0
    for p in front.points:
        if p.latency <= base_metrics.latency and (
                p.energy_j < base_metrics.energy_j
                or p.memory_bytes < base_metrics.memory_bytes):
            n += 1
    return n


def run(smoke: bool = False) -> list[str]:
    rows = []
    cfg = SMOKE if smoke else FULL
    cluster = make_pi_cluster(CAPS)
    all_ok = True
    total_wins = 0
    total_us = 0.0
    for name in cfg["models"]:
        scale = cfg["scale"] * (0.4 if name == "resnet34" else 1.0)
        model = zoo.build(name, scale=scale, input_size=cfg["size"])
        with Timer() as t:
            front = plan_front(model, cluster)
        us = 1e6 * t.s
        total_us += us
        base = plan_with_spec(model.graph, cluster, model.input_size)
        bm = plan_metrics(base.pipeline)
        nondom = all(not dominates(p.metrics, q.metrics)
                     for p in front.points for q in front.points
                     if p is not q)
        contains = any(
            all(x <= y for x, y in zip(p.metrics.as_tuple(), bm.as_tuple()))
            for p in front.points)
        wins = _wins(front, bm)
        all_ok = all_ok and nondom and contains and len(front) >= 2
        total_wins += wins
        rows.append(csv_row(
            f"pareto.{name}", us,
            f"points={len(front)};nondominated={1 if nondom else 0};"
            f"contains_opt={1 if contains else 0};wins={wins}"))
    rows.append(csv_row(
        "pareto.summary", total_us,
        f"front_ok={1.0 if all_ok else 0.0};wins={total_wins}"))
    return rows


def main(argv: list[str] | None = None) -> None:
    """Standalone entry point mirroring ``benchmarks.run``'s JSON shape
    so ``tools/bench_gate.py`` can gate it:
    ``python -m benchmarks.fig_pareto --smoke --out X.json``."""
    import argparse
    import json
    import time

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    from .run import parse_metrics
    t0 = time.time()
    print("name,us_per_call,derived")
    rows = run(smoke=args.smoke)
    wall = time.time() - t0
    if args.out:
        with open(args.out, "w") as fh:
            json.dump({"rows": rows, "metrics": parse_metrics(rows),
                       "wall_s": wall,
                       "mode": "smoke" if args.smoke else "full"},
                      fh, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
