"""Paper Table 4: Algorithm 1 performance on various CNNs.

Columns: model, n (conv/pool vertices), width w, theoretical bound
w*d*(n*d/w)^w, execution time, #pieces.  NASNet runs via the
divide-and-conquer strategy (paper §6.2.3, 'NASNetL-P').
"""

from __future__ import annotations

from .common import csv_row, Timer
from repro.core import partition_graph, partition_graph_dnc
from repro.models.cnn import zoo

D = 5  # diameter bound (paper §4.3)

CASES = [
    ("vgg16", dict(input_size=(224, 224)), False),
    ("squeezenet", dict(input_size=(224, 224)), False),
    ("resnet34", dict(input_size=(224, 224)), False),
    ("mobilenetv3", dict(input_size=(224, 224)), False),
    ("inceptionv3", dict(input_size=(299, 299)), False),
    ("nasnet", dict(n_cells=8, input_size=(224, 224), width=6), True),
]


def run() -> list[str]:
    rows = []
    for name, kw, use_dnc in CASES:
        m = zoo.build(name, **kw)
        g = m.graph
        n, w = len(g.layers), g.width()
        bound = w * D * (n * D / max(w, 1)) ** w
        with Timer() as t:
            if use_dnc:
                res = partition_graph_dnc(g, m.input_size, n_split=4,
                                          max_diameter=D, chunk=24)
            else:
                res = partition_graph(g, m.input_size, n_split=4,
                                      max_diameter=D)
        rows.append(csv_row(
            f"table4/{name}", t.s * 1e6,
            f"n={n};w={w};bound={bound:.2g};pieces={len(res.pieces)};"
            f"states={res.states_explored};dnc={use_dnc}"))
    return rows


if __name__ == "__main__":
    run()
