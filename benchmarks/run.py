"""Run every paper-artifact benchmark.  Prints ``name,us_per_call,derived``
CSV rows (one per measurement), mirroring the paper's tables/figures:

  table4   Algorithm 1 runtime/pieces per CNN         (paper Table 4)
  fig5     FLOPs vs fused layers x devices            (paper Fig. 5)
  fig12    piece- vs block-granularity speedup        (paper Fig. 12)
  fig13    throughput: LW/EFL/OFL/CE/PICO             (paper Figs. 13-14)
  table5   heterogeneous utilization/redundancy/mem   (paper Table 5)
  fig15    memory + energy vs devices                 (paper Figs. 15-16)
  table67  PICO vs BFS-optimal                        (paper Tables 6-7)
  runtime  event-runtime churn adaptivity             (new subsystem)
  exec     eager tile loop vs compiled stage path     (repro.exec)

Use --fast to trim the slowest sweeps (full mode is the default for
``python -m benchmarks.run``).  --smoke runs a tiny-config subset for
CI: the exec-backend microbenchmark plus the cheapest paper artifacts.
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config CI subset (implies --fast configs)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()

    from . import (table4_partition, fig5_redundancy, fig12_piece_vs_block,
                   fig13_throughput, table5_hetero, fig15_memory,
                   table67_optimal, fig_runtime_adapt, fig_exec_backend)
    benches = {
        "table4": lambda: table4_partition.run(),
        "fig5": lambda: fig5_redundancy.run(),
        "fig13": lambda: fig13_throughput.run(
            models=("vgg16",) if args.fast else ("vgg16", "yolov2")),
        "fig12": lambda: fig12_piece_vs_block.run(),
        "table5": lambda: table5_hetero.run(),
        "fig15": lambda: fig15_memory.run(),
        "table67": lambda: table67_optimal.run(fast=args.fast),
        "runtime": lambda: fig_runtime_adapt.run(
            models=("squeezenet",) if args.fast else ("vgg16", "squeezenet"),
            frames=120 if args.fast else fig_runtime_adapt.FRAMES),
        "exec": lambda: fig_exec_backend.run(smoke=args.smoke or args.fast),
    }
    if args.smoke:
        # CI smoke: the exec-backend microbenchmark + the cheapest paper
        # artifacts, all in tiny configs
        smoke = {
            "exec": benches["exec"],
            "table4": benches["table4"],
            "fig5": benches["fig5"],
            # >= 2x DROP_AFTER frames so the churn event actually fires
            "runtime": lambda: fig_runtime_adapt.run(
                models=("squeezenet",), frames=2 * fig_runtime_adapt.DROP_AFTER),
        }
        benches = smoke
    only = args.only.split(",") if args.only else list(benches)
    unknown = [n for n in only if n not in benches]
    if unknown:
        sys.exit(f"unknown benchmark(s) {unknown}; available"
                 f"{' in --smoke mode' if args.smoke else ''}: "
                 f"{sorted(benches)}")
    t0 = time.time()
    n = 0
    print("name,us_per_call,derived")
    for name in only:
        rows = benches[name]()
        n += len(rows)
    print(f"# {n} rows in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
