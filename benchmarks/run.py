"""Run every paper-artifact benchmark.  Prints ``name,us_per_call,derived``
CSV rows (one per measurement), mirroring the paper's tables/figures:

  table4     Algorithm 1 runtime/pieces per CNN         (paper Table 4)
  fig5       FLOPs vs fused layers x devices            (paper Fig. 5)
  fig12      piece- vs block-granularity speedup        (paper Fig. 12)
  fig13      throughput: LW/EFL/OFL/CE/PICO             (paper Figs. 13-14)
  table5     heterogeneous utilization/redundancy/mem   (paper Table 5)
  fig15      memory + energy vs devices                 (paper Figs. 15-16)
  table67    PICO vs BFS-optimal                        (paper Tables 6-7)
  runtime    event-runtime churn adaptivity             (repro.runtime)
  exec       eager tile loop vs compiled stage path     (repro.exec)
  serving    multi-tenant scheduler vs time-sliced      (repro.serving)
  fleet      planner throughput + plan registry         (repro.fleet)
  pareto     multi-objective Pareto front sweep         (repro.plan_front)

Use --fast to trim the slowest sweeps (full mode is the default for
``python -m benchmarks.run``).  --smoke runs a tiny-config subset for
CI.  --out <path> additionally writes the rows, a flattened ``metrics``
dict, and a versioned ``repro.obs`` metrics snapshot (the same envelope
``Deployment.metrics_snapshot()`` emits, carrying the run's executable
-cache and conv-fallback counters) as JSON — the one code path CI's
bench-regression gate (``tools/bench_gate.py``) and local runs share.
--trace-out <path> additionally runs a small traced VGG16 pipeline and
writes its Perfetto trace (validated in CI by
``python -m repro.tools.trace --validate``).
"""

import argparse
import json
import sys
import time


def write_trace(path: str, frames: int = 16) -> str:
    """Run the fig13 VGG16 pipeline (tiny config, virtual time) with
    tracing on and save the Perfetto trace to ``path``."""
    import repro
    from repro.core import make_pi_cluster
    from repro.models.cnn import zoo
    model = zoo.build("vgg16", scale=0.25, input_size=(64, 64))
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8], bandwidth_mbps=50.0)
    dep = repro.compile(model, cluster)
    rt = dep.runtime(repro.DeploySpec(trace=True), real_compute=False)
    rt.run(n_frames=frames)
    return dep.save_trace(path)


def parse_metrics(rows: list[str]) -> dict[str, float]:
    """Flatten CSV rows into gateable metrics.

    ``name,us,derived`` becomes ``{name}.us -> us`` plus, when
    ``derived`` is a bare number, ``{name} -> value``, or, when it is
    ``k=v[;k=v...]``, ``{name}.{k} -> v`` for every numeric ``v``.
    """
    metrics: dict[str, float] = {}
    for row in rows:
        name, us, derived = row.split(",", 2)
        metrics[f"{name}.us"] = float(us)
        try:
            metrics[name] = float(derived)
            continue
        except ValueError:
            pass
        for part in derived.split(";"):
            if "=" not in part:
                continue
            k, v = part.split("=", 1)
            try:
                metrics[f"{name}.{k}"] = float(v)
            except ValueError:
                pass
    return metrics


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-config CI subset (implies --fast configs)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="also write rows + flattened metrics as JSON")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="also run a small traced VGG16 pipeline and "
                         "write its Perfetto trace JSON")
    ap.add_argument("--autotune-out", default=None, metavar="PATH",
                    help="also write the kernel-autotune winners "
                         "accumulated by the kernel bench as a versioned "
                         "CostTable artifact JSON")
    args = ap.parse_args()

    from . import (table4_partition, fig5_redundancy, fig12_piece_vs_block,
                   fig13_throughput, table5_hetero, fig15_memory,
                   table67_optimal, fig_runtime_adapt, fig_exec_backend,
                   fig_serving_mt, fig_kernel_conv, fig_fleet_planner,
                   fig_pareto, fig_dist_exec)
    benches = {
        "table4": lambda: table4_partition.run(),
        "fig5": lambda: fig5_redundancy.run(),
        "fig13": lambda: fig13_throughput.run(
            models=("vgg16",) if args.fast else ("vgg16", "yolov2")),
        "fig12": lambda: fig12_piece_vs_block.run(),
        "table5": lambda: table5_hetero.run(),
        "fig15": lambda: fig15_memory.run(),
        "table67": lambda: table67_optimal.run(fast=args.fast),
        "runtime": lambda: fig_runtime_adapt.run(
            models=("squeezenet",) if args.fast else ("vgg16", "squeezenet"),
            frames=120 if args.fast else fig_runtime_adapt.FRAMES),
        "exec": lambda: fig_exec_backend.run(smoke=args.smoke or args.fast),
        "serving": lambda: fig_serving_mt.run(smoke=args.smoke or args.fast),
        "kernel": lambda: fig_kernel_conv.run(smoke=args.smoke or args.fast),
        "fleet": lambda: fig_fleet_planner.run(smoke=args.smoke or args.fast),
        "pareto": lambda: fig_pareto.run(smoke=args.smoke or args.fast),
        "dist": lambda: fig_dist_exec.run(smoke=args.smoke or args.fast),
    }
    if args.smoke:
        # CI smoke: the exec-backend microbenchmark, the conv-kernel
        # autotune microbenchmark, the multi-tenant serving comparison,
        # the fleet planner-throughput check, the multi-objective
        # Pareto-front contract, and the cheapest paper artifacts, all
        # in tiny configs
        smoke = {
            "exec": benches["exec"],
            "kernel": benches["kernel"],
            "serving": benches["serving"],
            "fleet": benches["fleet"],
            "pareto": benches["pareto"],
            "dist": benches["dist"],
            "table4": benches["table4"],
            "fig5": benches["fig5"],
            # >= 2x DROP_AFTER frames so the churn event actually fires
            "runtime": lambda: fig_runtime_adapt.run(
                models=("squeezenet",), frames=2 * fig_runtime_adapt.DROP_AFTER),
        }
        benches = smoke
    only = args.only.split(",") if args.only else list(benches)
    unknown = [n for n in only if n not in benches]
    if unknown:
        sys.exit(f"unknown benchmark(s) {unknown}; available"
                 f"{' in --smoke mode' if args.smoke else ''}: "
                 f"{sorted(benches)}")
    t0 = time.time()
    all_rows: list[str] = []
    print("name,us_per_call,derived")
    for name in only:
        all_rows.extend(benches[name]())
    wall = time.time() - t0
    print(f"# {len(all_rows)} rows in {wall:.1f}s", file=sys.stderr)
    mode = "smoke" if args.smoke else "fast" if args.fast else "full"
    if args.out:
        # embed the versioned repro.obs snapshot next to the legacy
        # flat-metrics dict: the bench run's process-global counters
        # (executable-cache hits, conv fallbacks, compile times) ride
        # along, and tools/bench_gate.py can gate on either form
        from repro.obs.metrics import registry_from_values, default_registry
        metrics = parse_metrics(all_rows)
        reg = registry_from_values(metrics)
        reg.merge(default_registry())
        snapshot = reg.snapshot(meta={"mode": mode, "wall_s": wall,
                                      "source": "benchmarks.run"})
        with open(args.out, "w") as fh:
            json.dump({"rows": all_rows,
                       "metrics": metrics,
                       "snapshot": snapshot,
                       "wall_s": wall,
                       "mode": mode},
                      fh, indent=2, sort_keys=True)
        print(f"# wrote {args.out}", file=sys.stderr)
    if args.trace_out:
        write_trace(args.trace_out)
        print(f"# wrote {args.trace_out}", file=sys.stderr)
    if args.autotune_out:
        fig_kernel_conv.export_autotune(args.autotune_out)
        print(f"# wrote {args.autotune_out}", file=sys.stderr)


if __name__ == "__main__":
    main()
