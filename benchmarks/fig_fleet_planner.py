"""Fleet planner-throughput benchmark: incremental PipelineDP + registry.

Two phases, both on the paper's 8-device heterogeneous Pi cluster:

**Churn replans.**  A device drops out (every device takes a turn,
``rounds`` times over).  The *scratch* lane re-runs Algorithm 2 cold
for each event; the *incremental* lane re-plans through one shared
:class:`~repro.core.pipeline_dp.PlannerCache` — segment geometry is
chain-keyed, so only the device-dependent DP re-runs.  The acceptance
bar is **>= 10x** replans/sec, and every incremental plan must be
**bit-identical** to its from-scratch twin (period, latency, stage
assignment, fractions — exact float equality, no tolerance).

**Registry admissions.**  ``cells`` identically-shaped clusters (fresh
device names each) admit the same model through one
:class:`~repro.fleet.registry.PlanRegistry`: the first is a miss, the
rest are hits with the plan's devices rebound onto each cell — a
deterministic hit rate of ``(cells - 1) / cells``.

Rows::

    fleet_planner.scratch        us per replan, rate=<replans/s>
    fleet_planner.incremental    us per replan, rate=<...>;speedup=<x>  (gated)
    fleet_planner.bit_identical  compare us, <1.0|0.0>                  (gated)
    fleet_planner.registry       us per admission, hit_rate=<r>;...     (gated)
"""

from __future__ import annotations

import dataclasses

from .common import Timer, csv_row, make_pi_cluster
from repro.api.specs import PlanSpec
from repro.core import Cluster
from repro.core.pipeline_dp import PlannerCache
from repro.core.planner import PicoPlan, plan_with_spec
from repro.fleet import PlanRegistry
from repro.models.cnn import zoo

CAPS = [1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8]   # 8-device hetero Pis

SMOKE = dict(size=(96, 96), scale=0.5, rounds=1, cells=8)
FULL = dict(size=(224, 224), scale=1.0, rounds=3, cells=32)


def _churn_clusters(base: Cluster) -> list[Cluster]:
    """One cluster per churn event: each device takes a turn leaving."""
    out = []
    for d in base.devices:
        out.append(base.restricted(
            [x for x in base.devices if x.name != d.name]))
    return out


def _plan_sig(p: PicoPlan) -> tuple:
    """Exact (bitwise) plan identity: costs, assignment, fractions."""
    return (p.period, p.latency, p.pipeline.feasible,
            tuple((st.first_piece, st.last_piece,
                   tuple(d.name for d in st.devices),
                   tuple(st.fractions), st.cost.total, st.cost.t_comp,
                   st.cost.t_comm) for st in p.pipeline.stages))


def run(smoke: bool = False) -> list[str]:
    rows = []
    cfg = SMOKE if smoke else FULL
    model = zoo.vgg16(input_size=cfg["size"], scale=cfg["scale"])
    base = make_pi_cluster(CAPS)
    spec = PlanSpec()
    events = _churn_clusters(base) * cfg["rounds"]

    # ---- scratch lane: cold Algorithm 2 per churn event --------------
    seed = plan_with_spec(model.graph, base, model.input_size, spec)
    scratch_plans = []
    with Timer() as t_scr:
        for c in events:
            scratch_plans.append(plan_with_spec(
                model.graph, c, model.input_size, spec,
                partition=seed.partition))
    scr_us = 1e6 * t_scr.s / len(events)

    # ---- incremental lane: shared PlannerCache, same events ----------
    cache = PlannerCache()
    warm = plan_with_spec(model.graph, base, model.input_size, spec,
                          planner_cache=cache)
    inc_plans = []
    with Timer() as t_inc:
        for c in events:
            inc_plans.append(plan_with_spec(
                model.graph, c, model.input_size, spec,
                partition=warm.partition, planner_cache=cache))
    inc_us = 1e6 * t_inc.s / len(events)
    speedup = t_scr.s / t_inc.s if t_inc.s > 0 else 0.0

    rows.append(csv_row("fleet_planner.scratch", scr_us,
                        f"rate={1e6 / scr_us:.2f}"))
    rows.append(csv_row("fleet_planner.incremental", inc_us,
                        f"rate={1e6 / inc_us:.2f};speedup={speedup:.2f}"))

    # ---- bit-identity: incremental plans == scratch twins ------------
    assert all(p.source == "incremental" for p in inc_plans)
    with Timer() as t_cmp:
        mismatches = sum(_plan_sig(a) != _plan_sig(b)
                         for a, b in zip(scratch_plans, inc_plans))
    rows.append(csv_row("fleet_planner.bit_identical", 1e6 * t_cmp.s,
                        f"{1.0 if mismatches == 0 else 0.0}"))

    # ---- registry: identical cells, fresh names, one shared cache ----
    reg = PlanRegistry(capacity=max(4, cfg["cells"]))
    cells = [Cluster([dataclasses.replace(d, name=f"cell{k}.{d.name}")
                      for d in base.devices], bandwidth=base.bandwidth)
             for k in range(cfg["cells"])]
    with Timer() as t_reg:
        admitted = [reg.get_or_plan(model, c, spec) for c in cells]
    reg_us = 1e6 * t_reg.s / len(cells)
    n_hits = sum(p.source == "registry" for p in admitted)
    rows.append(csv_row(
        "fleet_planner.registry", reg_us,
        f"hit_rate={reg.hit_rate:.4f};hits={n_hits};misses={reg.misses}"))
    return rows


def main(argv: list[str] | None = None) -> None:
    """Standalone entry point for CI's planner-bench lane:
    ``python -m benchmarks.fig_fleet_planner --smoke --out X.json``
    writes the same rows/metrics JSON shape as ``benchmarks.run`` so
    ``tools/bench_gate.py`` can gate it."""
    import argparse
    import json
    import time

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    from .run import parse_metrics
    t0 = time.time()
    print("name,us_per_call,derived")
    rows = run(smoke=args.smoke)
    wall = time.time() - t0
    if args.out:
        with open(args.out, "w") as fh:
            json.dump({"rows": rows, "metrics": parse_metrics(rows),
                       "wall_s": wall,
                       "mode": "smoke" if args.smoke else "full"},
                      fh, indent=2, sort_keys=True)


if __name__ == "__main__":
    main()
