"""Paper Fig. 12: speedup of graph-partitioned pieces vs block-as-layer
for ResNet34 and InceptionV3 across CPU frequencies and 2-8 devices."""

from __future__ import annotations

from .common import csv_row, paper_cluster, single_device_latency
from repro.core import baselines as B
from repro.core import partition_graph
from repro.core.partition import Piece, block_pieces
from repro.models.cnn import zoo


def _block_pieces(m):
    """Treat every block (and the glue between blocks) as a piece —
    the strategy of [6]/[17] the paper compares against."""
    g = m.graph
    in_block = {n for b in m.blocks for n in b}
    pieces = []
    cur: list[str] = []
    blocks_sorted = []
    seen = set()
    for n in g.topo_order:
        b = next((bl for bl in m.blocks if n in bl and id(bl) not in seen),
                 None)
        if b is not None:
            if cur:
                pieces.append(frozenset(cur))
                cur = []
            pieces.append(frozenset(b))
            seen.add(id(b))
        elif n not in in_block:
            cur.append(n)
    if cur:
        pieces.append(frozenset(cur))
    return [Piece(p, 0.0, i) for i, p in enumerate(pieces)]


def run() -> list[str]:
    rows = []
    cases = [("resnet34", zoo.resnet34(input_size=(224, 224))),
             ("inceptionv3", zoo.inceptionv3(input_size=(299, 299)))]
    for name, m in cases:
        fine = partition_graph(m.graph, m.input_size, n_split=8).pieces \
            if name != "inceptionv3" else \
            partition_graph(m.graph, m.input_size, n_split=8).pieces
        if m.blocks:
            coarse = _block_pieces(m)
        else:
            # inception blocks are concat-delimited: cut at every concat
            cuts, cur = [], []
            for n in m.graph.topo_order:
                cur.append(n)
                if m.graph.layers[n].kind == "concat":
                    cuts.append(frozenset(cur))
                    cur = []
            if cur:
                cuts.append(frozenset(cur))
            coarse = [Piece(p, 0.0, i) for i, p in enumerate(cuts)]
        for freq in (0.6, 1.0, 1.5):
            for n_dev in (2, 4, 6, 8):
                cluster = paper_cluster(n_dev, freq)
                single = single_device_latency(m, cluster)
                for tag, pieces in (("block", coarse), ("piece", fine)):
                    res = B.pico_scheme(m.graph, pieces, cluster,
                                        m.input_size)
                    rows.append(csv_row(
                        f"fig12/{name}_{tag}_f{freq}_d{n_dev}",
                        res.period * 1e6,
                        f"speedup={single/res.period:.2f};"
                        f"pieces={len(pieces)}"))
    return rows


if __name__ == "__main__":
    run()
