"""Paper Fig. 5: FLOPs vs number of fused layers and devices (VGG16).

(a) per-device FLOPs; (b) total FLOPs of all devices.  Shows the
fused-layer scheme's redundancy explosion that motivates pipelining.
"""

from __future__ import annotations

from .common import csv_row
from repro.core.cost import segment_cost
from repro.models.cnn import zoo


def run() -> list[str]:
    m = zoo.vgg16(input_size=(224, 224))
    g = m.graph
    full = g.forward_sizes(m.input_size)
    order = [n for n in g.topo_order
             if g.layers[n].kind in ("conv", "pool")]
    rows = []
    for n_fused in (1, 2, 4, 6, 8, 10, 13):
        nodes = frozenset(order[:n_fused])
        exact = g.segment_flops(
            nodes, {n: full[n] for n in nodes})
        for n_dev in (1, 2, 4, 6, 8):
            seg = segment_cost(g, nodes, full, m.input_size,
                               [1.0 / n_dev] * n_dev)
            per_dev = max(seg.per_device_flops)
            total = sum(seg.per_device_flops)
            rows.append(csv_row(
                f"fig5/fused{n_fused}_dev{n_dev}", 0.0,
                f"per_device_gflops={per_dev/1e9:.2f};"
                f"total_gflops={total/1e9:.2f};"
                f"redundancy={max(0.0, total/exact - 1):.3f}"))
    return rows


if __name__ == "__main__":
    run()
