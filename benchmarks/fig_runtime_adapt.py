"""Runtime adaptivity benchmark: throughput before / during / after churn.

An 8-device heterogeneous Pi cluster (the paper's largest testbed)
streams frames through the event-driven runtime; mid-run the fastest
device drops out.  We report windowed throughput for the pre-churn,
re-plan/migration, and post-recovery phases, the re-plan wall time, and
the recovery ratio — post-churn throughput relative to what a fresh
plan on the surviving devices achieves (the acceptance bar is >= 0.8).

Rows: ``runtime_adapt.<model>.<phase>,us_per_frame,throughput_per_min``.
"""

from __future__ import annotations

from .common import csv_row
from repro.core import Cluster, make_pi_cluster, plan
from repro.models.cnn import zoo
from repro.runtime import DeviceLeave, PipelineRuntime

FRAMES = 240
DROP_AFTER = 80          # frames before the strongest device leaves


def eight_device_cluster() -> Cluster:
    """8 heterogeneous Pis: 2x1.5, 2x1.2, 2x1.0, 2x0.8 GHz."""
    return make_pi_cluster([1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8])


def run(models=("vgg16", "squeezenet"), frames: int = FRAMES) -> list[str]:
    rows = []
    builders = {
        "vgg16": lambda: zoo.vgg16(input_size=(224, 224), scale=0.25),
        "squeezenet": lambda: zoo.squeezenet(input_size=(224, 224),
                                             scale=0.5),
    }
    for name in models:
        m = builders[name]()
        cluster = eight_device_cluster()
        pico = plan(m.graph, cluster, m.input_size)
        drop_dev = max(cluster.devices, key=lambda d: d.capacity)
        drop_t = pico.period * DROP_AFTER
        rt = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                             churn=[DeviceLeave(drop_t, drop_dev.name)])
        rep = rt.run(frames)

        # phase windows: pre-churn, churn+migration, steady post-recovery
        mig_end = max((r.time + r.migration_s for r in rep.replans),
                      default=drop_t)
        pre = rep.windowed_throughput(0.0, drop_t)
        during = rep.windowed_throughput(drop_t, mig_end)
        post = rep.windowed_throughput(mig_end, rep.makespan)

        # reference: fresh plan on the surviving 7 devices
        survivors = Cluster([d for d in cluster.devices
                             if d.name != drop_dev.name],
                            bandwidth=cluster.bandwidth)
        ref = plan(m.graph, survivors, m.input_size)
        ref_tput = 1.0 / ref.period
        recovery = post / ref_tput if ref_tput > 0 else 0.0

        for phase, tput in (("pre", pre), ("during", during),
                            ("post", post)):
            us = 1e6 / tput if tput > 0 else float("inf")
            rows.append(csv_row(f"runtime_adapt.{name}.{phase}", us,
                                f"{tput * 60.0:.1f}"))
        # recovery vs the best any plan can do on the survivors, and vs
        # the pre-churn throughput (the acceptance bar: >= 0.8 of pre)
        rows.append(csv_row(f"runtime_adapt.{name}.recovery",
                            sum(r.wall_s for r in rep.replans) * 1e6,
                            f"{recovery:.3f}"))
        rows.append(csv_row(f"runtime_adapt.{name}.recovery_vs_pre",
                            sum(r.migration_s for r in rep.replans) * 1e6,
                            f"{post / pre if pre > 0 else 0.0:.3f}"))
    return rows


if __name__ == "__main__":
    run()
