"""Conv-kernel microbenchmark: tuned Pallas vs XLA ref vs pre-tuning tiles.

For every *distinct* conv-epilogue shape in the zoo (channels, filter,
stride, fused relu/pool — spatial sizes shrunk to smoke scale), times
three lowerings of the same fused chain:

* ``tuned``  — the Pallas kernel at the autotuner's winning
  (block_ci, block_co);
* ``legacy`` — the Pallas kernel at the pre-autotune ``_pick_tile``
  divisor blocks (what every conv used before tuning existed);
* ``xla``    — the composed ``lax`` reference sequence.

On CPU the Pallas kernel runs in interpret mode, so absolute wall
times are not meaningful to gate; the *structural* outcomes are: the
summary row pins ``fallbacks`` (must be 0 — every zoo conv now has a
Pallas lowering) and ``shapes`` (coverage), both deterministic.  On a
TPU the same rows become real kernel speedups.

Rows::

    kernel_conv/<key>      tuned us; xla_us, legacy_us, tuned_vs_legacy
    kernel_conv/summary    total tuned us; shapes, fallbacks, tuned counts

``export_autotune(path)`` writes the accumulated winners as a
versioned CostTable artifact (CI uploads it from the bench-smoke job).
"""

from __future__ import annotations

import time
import warnings

import jax

from .common import csv_row
from repro.api import artifacts
from repro.core.cost import CostTable
from repro.exec.autotune import autotune_conv, conv_shapes, install, installed
from repro.kernels.conv2d.conv2d import _pick_tile
from repro.kernels.conv2d.ops import (conv2d_fused, fallback_count,
                                      reset_fallbacks)
from repro.models.cnn import zoo

# tiny zoo builds: every distinct conv *channel geometry* of the seven
# models at smoke scale (interpret mode makes full-size spatial dims
# pointless on CPU)
ZOO_TINY = {
    "vgg16": dict(input_size=(40, 40), scale=0.1, head=False),
    "yolov2": dict(input_size=(64, 64), scale=0.05),
    "resnet34": dict(input_size=(64, 64), scale=0.1),
    "inceptionv3": dict(input_size=(96, 96), scale=0.1),
    "squeezenet": dict(input_size=(64, 64), scale=0.1),
    "mobilenetv3": dict(input_size=(64, 64), scale=0.1),
    "nasnet": dict(n_cells=2, input_size=(48, 48), scale=0.15),
}

# smoke candidate set: small blocks only — zoo-tiny channel counts never
# reach 128, and interpret-mode trials are wall-time-expensive
SMOKE_CANDIDATES = ((32, 32), (16, 16), (8, 8))
SMOKE_SHAPE_CAP = 12    # distinct shapes benched in --smoke mode


def _bench(fn, iters: int = 2) -> float:
    jax.block_until_ready(fn())   # compile outside the timed region
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def distinct_conv_shapes(smoke: bool = False) -> list[dict]:
    """Distinct conv-epilogue shapes across the whole zoo, round-robin
    interleaved across models so a capped smoke subset still covers
    every model's characteristic convs (strided stems, fused pools)
    rather than just the first model's.  The cap itself is logged in
    the summary row, not silent."""
    seen: set[tuple] = set()
    per_model: list[list[dict]] = []
    for name, cfg in ZOO_TINY.items():
        m = zoo.build(name, **cfg)
        mine = []
        for d in conv_shapes(m):
            k = (d["w_shape"][-2], d["w_shape"][-1], d["w_shape"][:2],
                 d["stride"], d["pool"])
            if k not in seen:
                seen.add(k)
                mine.append(d)
        per_model.append(mine)
    out: list[dict] = []
    for i in range(max(len(m) for m in per_model)):
        out.extend(m[i] for m in per_model if i < len(m))
    return out


def run(smoke: bool = False) -> list[str]:
    rows: list[str] = []
    shapes = distinct_conv_shapes(smoke)
    total = len(shapes)
    if smoke:
        shapes = shapes[:SMOKE_SHAPE_CAP]
    candidates = SMOKE_CANDIDATES if smoke else None
    iters = 1 if smoke else 3
    reset_fallbacks()
    t_tuned_sum = 0.0
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for d in shapes:
            kw = dict(stride=d["stride"], relu=d["relu"], pool=d["pool"])
            res = autotune_conv(
                d["x_shape"], d["w_shape"], iters=iters,
                **(dict(candidates=candidates) if candidates else {}), **kw)
            install({res.key: res.entry()})
            key, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 4)
            x = jax.random.normal(k1, d["x_shape"])
            w = jax.random.normal(k2, d["w_shape"]) * 0.1
            b = jax.random.normal(k3, (d["w_shape"][-1],))
            t_tuned = _bench(lambda: conv2d_fused(
                x, w, b, block_ci=res.block_ci, block_co=res.block_co,
                interpret=True, **kw), iters)
            t_legacy = _bench(lambda: conv2d_fused(
                x, w, b, block_ci=_pick_tile(d["w_shape"][-2]),
                block_co=_pick_tile(d["w_shape"][-1]),
                interpret=True, **kw), iters)
            t_xla = _bench(lambda: conv2d_fused(
                x, w, b, use_pallas=False, **kw), iters)
            t_tuned_sum += t_tuned
            ci, co = d["w_shape"][-2], d["w_shape"][-1]
            kh, kw_ = d["w_shape"][:2]
            sh, sw = d["stride"]
            tag = (f"c{ci}-c{co}-k{kh}x{kw_}-s{sh}x{sw}"
                   + ("-pool" if d["pool"] else ""))
            rows.append(csv_row(
                f"kernel_conv/{tag}", t_tuned * 1e6,
                f"xla_us={t_xla * 1e6:.1f};legacy_us={t_legacy * 1e6:.1f};"
                f"tuned_vs_legacy={t_legacy / t_tuned:.2f};"
                f"blocks={res.block_ci}x{res.block_co}"))
    rows.append(csv_row(
        "kernel_conv/summary", t_tuned_sum * 1e6,
        f"shapes={len(shapes)};shapes_total={total};"
        f"fallbacks={fallback_count()};tuned={len(installed())}"))
    return rows


def export_autotune(path: str) -> str:
    """Write the winners installed by :func:`run` as a versioned
    CostTable artifact JSON (the autotune-results CI artifact)."""
    table = CostTable(kernels=installed())
    with open(path, "w") as fh:
        fh.write(artifacts.cost_table_to_json(table, indent=1))
        fh.write("\n")
    return path


if __name__ == "__main__":
    for r in run(smoke=True):
        print(r)
