"""Bench-regression gate: compare a ``benchmarks.run --out`` JSON
against committed baselines and fail on regressions.

Usage::

    python tools/bench_gate.py BENCH_smoke.json \
        --baseline benchmarks/baselines/smoke.json [--threshold 0.2]

The baseline file pins *self-normalized* metrics only (speedups,
recovery ratios, counts) — raw wall-time numbers vary with CI hardware
and would flap.  Each entry declares its good direction::

    {"metrics": {"exec/vgg16_stage_compiled.speedup":
                     {"value": 2.5, "direction": "higher"},
                 "serving_mt.dropped_inflight":
                     {"value": 0.0, "direction": "lower"}}}

A ``higher`` metric fails below ``value * (1 - threshold)``; a
``lower`` metric fails above ``value * (1 + threshold)`` (for a zero
baseline that means any increase fails).  An entry may also pin an
absolute ``min``/``max`` — a hard acceptance bar the relative
threshold must not soften (e.g. churn recovery >= 0.95 regardless of
how high the baseline sits).  A metric missing from the measured run
fails too — silently dropping a benchmark is itself a regression.
Exit code 1 on any failure.

The measured file may be any of three shapes:

* legacy ``benchmarks.run --out`` JSON (``{"metrics": {...}, ...}``),
* a bare flat ``{name: value}`` dict,
* a versioned ``repro.obs`` metrics snapshot
  (``{"artifact": "metrics", "version": 1, "payload": {...}}``, as
  written by ``Deployment.metrics_snapshot()``) — counters and gauges
  gate by name (labelled series as ``name{k=v,...}``), histograms
  expand to ``.count/.sum/.mean/.min/.max/.p50/.p95/.p99`` sub-keys.

A ``--out`` file that embeds a ``snapshot`` alongside the legacy
``metrics`` dict exposes both namespaces (legacy names win on clash).
The snapshot flattening here is intentionally self-contained: CI runs
this gate without PYTHONPATH, so it must not import ``repro``.
"""

from __future__ import annotations

import argparse
import json
import sys

DEFAULT_THRESHOLD = 0.2

# Newest snapshot schema this gate understands; mirror of
# repro.obs.metrics.METRICS_SCHEMA_VERSION (kept literal on purpose —
# no repro import, see module docstring).
SNAPSHOT_VERSION = 1


def _num(v) -> float:
    """Decode a snapshot number (floats round-trip non-finite values as
    the strings "Infinity"/"-Infinity"/"NaN")."""
    return float(v)


def flatten_snapshot(doc: dict) -> dict[str, float]:
    """Flatten a ``repro.obs`` metrics snapshot into ``{name: value}``.

    Matches ``repro.obs.metrics.flatten``: labelled series become
    ``name{k=v,...}`` (labels sorted by key), histograms expand into
    ``.count/.sum/.mean/.min/.max`` plus the snapshot's percentile
    keys (``.p50`` etc.).  Raises ValueError on a newer schema version
    than this gate understands."""
    if doc.get("artifact") != "metrics":
        raise ValueError(f"not a metrics snapshot: "
                         f"artifact={doc.get('artifact')!r}")
    version = int(doc.get("version", 0))
    if version > SNAPSHOT_VERSION:
        raise ValueError(f"metrics snapshot version {version} is newer "
                         f"than supported ({SNAPSHOT_VERSION})")
    payload = doc.get("payload", {})

    def flat_name(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
        return f"{name}{{{inner}}}"

    out: dict[str, float] = {}
    for c in payload.get("counters", ()):
        out[flat_name(c["name"], c.get("labels", {}))] = _num(c["value"])
    for g in payload.get("gauges", ()):
        out[flat_name(g["name"], g.get("labels", {}))] = _num(g["value"])
    for h in payload.get("histograms", ()):
        base = flat_name(h["name"], h.get("labels", {}))
        for k, v in h.items():
            if k in ("count", "sum", "mean", "min", "max") \
                    or (k.startswith("p") and k[1:].replace(".", "").isdigit()):
                out[f"{base}.{k}"] = _num(v)
    return out


def metrics_view(measured: dict) -> dict:
    """Resolve whichever measured-file shape we were handed into one
    flat ``{name: value}`` map (see module docstring)."""
    if measured.get("artifact") == "metrics":
        return flatten_snapshot(measured)
    metrics = measured.get("metrics", measured)
    snapshot = measured.get("snapshot")
    if isinstance(snapshot, dict) and snapshot.get("artifact") == "metrics":
        merged = flatten_snapshot(snapshot)
        merged.update(metrics)  # legacy names win on clash
        return merged
    return metrics


def check(measured: dict, baseline: dict,
          threshold: float | None = None) -> list[str]:
    """Return a list of human-readable failures (empty = gate passes)."""
    thr = threshold if threshold is not None \
        else baseline.get("threshold", DEFAULT_THRESHOLD)
    metrics = metrics_view(measured)
    failures = []
    for name, spec in baseline["metrics"].items():
        base = float(spec["value"])
        direction = spec.get("direction", "higher")
        if direction not in ("higher", "lower"):
            raise ValueError(f"{name}: bad direction {direction!r}")
        got = metrics.get(name)
        if got is None:
            failures.append(f"{name}: missing from measured metrics")
            continue
        got = float(got)
        if direction == "higher":
            allowed = base * (1.0 - thr)
            if got < allowed:
                failures.append(
                    f"{name}: {got:.4g} < {allowed:.4g} "
                    f"(baseline {base:.4g}, higher-is-better, "
                    f"threshold {thr:.0%})")
        else:
            allowed = base * (1.0 + thr)
            if got > allowed:
                failures.append(
                    f"{name}: {got:.4g} > {allowed:.4g} "
                    f"(baseline {base:.4g}, lower-is-better, "
                    f"threshold {thr:.0%})")
        if "min" in spec and got < float(spec["min"]):
            failures.append(f"{name}: {got:.4g} below hard floor "
                            f"{float(spec['min']):.4g}")
        if "max" in spec and got > float(spec["max"]):
            failures.append(f"{name}: {got:.4g} above hard ceiling "
                            f"{float(spec['max']):.4g}")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("measured", help="JSON from benchmarks.run --out")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON")
    ap.add_argument("--threshold", type=float, default=None,
                    help=f"relative regression allowance (default: "
                         f"baseline file's, else {DEFAULT_THRESHOLD})")
    args = ap.parse_args(argv)

    with open(args.measured) as fh:
        measured = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)

    failures = check(measured, baseline, args.threshold)
    metrics = metrics_view(measured)
    for name, spec in baseline["metrics"].items():
        got = metrics.get(name)
        status = "MISS" if got is None else f"{float(got):.4g}"
        print(f"  {name}: measured={status} baseline={spec['value']} "
              f"({spec.get('direction', 'higher')})")
    if failures:
        print(f"\nBENCH GATE FAILED ({len(failures)}):", file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print("\nbench gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
