#!/usr/bin/env python
"""Thin launcher for ``repro.tools.plan`` when the package is not on
``sys.path`` (CI and repo-root usage): ``python tools/plan_cli.py ...``
is identical to ``PYTHONPATH=src python -m repro.tools.plan ...``."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.tools.plan import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
