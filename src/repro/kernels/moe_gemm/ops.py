"""jit'd public wrapper for the grouped expert GEMM kernel."""

import jax

from .moe_gemm import moe_gemm as _moe_gemm_pallas
from .ref import moe_gemm_ref


def moe_gemm(x: jax.Array, w: jax.Array, *, use_pallas: bool = True,
             interpret: bool = False) -> jax.Array:
    if not use_pallas:
        return moe_gemm_ref(x, w)
    return _moe_gemm_pallas(x, w, interpret=interpret)
