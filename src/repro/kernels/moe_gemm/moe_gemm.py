"""Pallas TPU grouped (expert-batched) GEMM for MoE FFNs.

Computes y[e] = x[e] @ w[e] for every expert's capacity buffer — the
hot matmul of the capacity-dispatch MoE (granite: 40 experts, mixtral:
8).  Grid (E, C/TC, F/TF, D/TD) with the contraction axis innermost so
the fp32 accumulator persists in VMEM scratch across its sequential
iterations; C/F tiles are MXU-aligned where the shapes allow.

On the dry-run meshes the expert hidden dim is model-sharded, so each
chip runs this kernel on its (E, C, d) x (E, d, F/16) slice.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_d: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(di == n_d - 1)
    def _emit():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def _tile(n: int, pref: int) -> int:
    if n % pref == 0:
        return pref
    for t in (256, 128, 64, 32, 16, 8, 4, 2):
        if n % t == 0:
            return min(t, n)
    return n


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_gemm(x: jax.Array, w: jax.Array, *, interpret: bool = False
             ) -> jax.Array:
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    _, _, F = w.shape
    tc = _tile(C, 128)
    tf = _tile(F, 128)
    td = _tile(D, 512)
    n_d = D // td
    kernel = functools.partial(_moe_gemm_kernel, n_d=n_d)
    return pl.pallas_call(
        kernel,
        grid=(E, C // tc, F // tf, n_d),
        in_specs=[
            pl.BlockSpec((1, tc, td), lambda e, c, f, d: (e, c, d)),
            pl.BlockSpec((1, td, tf), lambda e, c, f, d: (e, d, f)),
        ],
        out_specs=pl.BlockSpec((1, tc, tf), lambda e, c, f, d: (e, c, f)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((tc, tf), jnp.float32)],
        interpret=interpret,
    )(x, w)
