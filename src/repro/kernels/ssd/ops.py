"""jit'd public wrapper for the SSD intra-chunk kernel."""

import jax

from .ssd_chunk import ssd_chunk as _ssd_pallas
from .ref import ssd_chunk_ref


def ssd_chunk(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
              Cm: jax.Array, *, use_pallas: bool = True,
              interpret: bool = False):
    if not use_pallas:
        return ssd_chunk_ref(x, dt, A, Bm, Cm)
    return _ssd_pallas(x, dt, A, Bm, Cm, interpret=interpret)
