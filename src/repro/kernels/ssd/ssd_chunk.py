"""Pallas TPU kernel for the Mamba2 SSD intra-chunk step.

Computes, per (batch*chunk, head) grid cell, the quadratic intra-chunk
output and the chunk state summary of the SSD algorithm
(arXiv:2405.21060):

    Y_intra[i] = sum_{j<=i} (C_i . B_j) exp(cumA_i - cumA_j) dt_j x_j
    state      = sum_j B_j^T (exp(cumA_last - cumA_j) dt_j x_j)

The inter-chunk recurrence (a tiny (B,H,P,N) scan over chunks) stays in
JAX — it is O(S/Q) sequential steps and bandwidth-trivial; the MXU-heavy
(Q x Q) @ (Q x P) work lives here.  Chunk length Q and head dim P are
the MXU-aligned tile dims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                      y_ref, st_ref):
    x = x_ref[0, :, 0, :]          # (Q, P)
    dt = dt_ref[0, :, 0]           # (Q,)
    A = a_ref[0]                   # ()
    Bm = b_ref[0]                  # (Q, N)
    Cm = c_ref[0]                  # (Q, N)

    a = (dt * A).astype(jnp.float32)            # (Q,)
    cum = jnp.cumsum(a)                          # (Q,)
    seg = cum[:, None] - cum[None, :]            # (Q, Q)
    Q = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)   # (Q, Q)
    cb = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    M = cb * L * dt[None, :].astype(jnp.float32)
    y = jnp.dot(M.astype(x.dtype), x, preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    decay_tail = jnp.exp(cum[-1] - cum) * dt.astype(jnp.float32)  # (Q,)
    xw = x.astype(jnp.float32) * decay_tail[:, None]              # (Q, P)
    st = jnp.dot(xw.T.astype(x.dtype), Bm,
                 preferred_element_type=jnp.float32)              # (P, N)
    st_ref[0, 0] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
              Cm: jax.Array, *, interpret: bool = False):
    """Intra-chunk SSD.

    x: (BC, Q, H, P); dt: (BC, Q, H) (post-softplus); A: (H,);
    Bm/Cm: (BC, Q, N).  Returns (y_intra (BC,Q,H,P), state (BC,H,P,N)).
    """
    BC, Q, H, P = x.shape
    N = Bm.shape[-1]
    grid = (BC, H)
    out_shapes = (
        jax.ShapeDtypeStruct((BC, Q, H, P), x.dtype),
        jax.ShapeDtypeStruct((BC, H, P, N), x.dtype),
    )
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda bc, h: (bc, 0, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda bc, h: (bc, 0, h)),
            pl.BlockSpec((1,), lambda bc, h: (h,)),
            pl.BlockSpec((1, Q, N), lambda bc, h: (bc, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda bc, h: (bc, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, Q, 1, P), lambda bc, h: (bc, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bc, h: (bc, h, 0, 0)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
