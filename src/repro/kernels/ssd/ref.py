"""Pure-jnp oracle for the SSD intra-chunk kernel."""

import jax
import jax.numpy as jnp


def ssd_chunk_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                  Bm: jax.Array, Cm: jax.Array):
    """x: (BC, Q, H, P); dt: (BC, Q, H); A: (H,); Bm/Cm: (BC, Q, N).

    Returns (y_intra, state):
      y_intra[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
      state      = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
    """
    a = (dt * A).astype(jnp.float32)          # (BC, Q, H)
    cum = jnp.cumsum(a, axis=1)
    seg = cum[:, :, None, :] - cum[:, None, :, :]          # (BC,Q,Q,H)
    Q = x.shape[1]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bin,bjn->bij", Cm, Bm)               # (BC,Q,Q)
    M = cb[..., None] * L * dt[:, None, :, :].astype(jnp.float32)
    y = jnp.einsum("bijh,bjhp->bihp", M.astype(x.dtype), x)
    decay_tail = jnp.exp(cum[:, -1:, :] - cum) * dt.astype(jnp.float32)
    st = jnp.einsum("bqn,bqh,bqhp->bhpn", Bm,
                    decay_tail.astype(x.dtype), x)
    return y.astype(x.dtype), st.astype(x.dtype)
