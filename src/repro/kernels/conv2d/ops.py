"""jit'd public wrapper for the conv2d Pallas kernel with shape guards."""

import jax

from .conv2d import conv2d as _conv2d_pallas
from .ref import conv2d_ref


def conv2d(x: jax.Array, w: jax.Array, *, use_pallas: bool = True,
           interpret: bool = False) -> jax.Array:
    """Stride-1 VALID NHWC conv.  Falls back to the XLA conv when the
    shape is unsupported by the kernel (tiny channel counts)."""
    N, H, W, CI = x.shape
    KH, KW, CI2, CO = w.shape
    assert CI == CI2, (x.shape, w.shape)
    if not use_pallas or H < KH or W < KW:
        return conv2d_ref(x, w)
    return _conv2d_pallas(x, w, interpret=interpret)
