"""jit'd public wrapper for the conv2d Pallas kernel with shape guards."""

import time as _time
import warnings

import jax

from .conv2d import conv2d as _conv2d_pallas
from .ref import conv2d_ref
from ...obs import trace as obs_trace
from ...obs.metrics import default_registry

_warned: set[tuple] = set()


def _warn_once(key: tuple, msg: str) -> None:
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(msg, RuntimeWarning, stacklevel=4)


def _fallback(reason: str, x_shape: tuple, w_shape: tuple,
              stride: tuple, msg: str) -> None:
    """Account one Pallas->XLA fallback: a ``conv.fallback`` counter
    labelled with the offending shape/stride (countable per run via
    ``Deployment.metrics_snapshot()``), a ``conv.fallback`` instant in
    the active tracer, and the once-per-shape RuntimeWarning."""
    default_registry().counter(
        "conv.fallback", reason=reason, x_shape=str(x_shape),
        w_shape=str(w_shape), stride=str(stride)).inc()
    tr = obs_trace.current()
    if tr:
        tr.instant("conv.fallback", _time.perf_counter() - tr.epoch,
                   reason=reason, x_shape=x_shape, w_shape=w_shape,
                   stride=stride)
    _warn_once((reason, x_shape, w_shape, stride), msg)


def fallback_count() -> int:
    """Total Pallas->XLA fallbacks recorded this process (all shapes)."""
    return int(default_registry().total("conv.fallback"))


def conv2d(x: jax.Array, w: jax.Array, *, stride: tuple[int, int] = (1, 1),
           use_pallas: bool = True, interpret: bool = False) -> jax.Array:
    """VALID NHWC conv.  The Pallas implicit-GEMM kernel handles the
    stride-1 case; strided or kernel-unsupported shapes fall back to the
    XLA reference *inside this wrapper*, so the caller's backend choice
    is honored for every conv in a segment instead of silently bypassing
    it.  Each fallback is structured — a labelled ``conv.fallback``
    metric plus a trace instant carrying the shape and stride — and
    still warns once per distinct shape.
    """
    N, H, W, CI = x.shape
    KH, KW, CI2, CO = w.shape
    assert CI == CI2, (x.shape, w.shape)
    if not use_pallas:
        return conv2d_ref(x, w, stride)
    if stride != (1, 1):
        _fallback("stride", tuple(x.shape), tuple(w.shape), tuple(stride),
                  f"conv2d: Pallas kernel is stride-1 only; stride={stride} "
                  f"conv {w.shape} falls back to the XLA reference")
        return conv2d_ref(x, w, stride)
    if H < KH or W < KW:
        _fallback("shape", tuple(x.shape), tuple(w.shape), tuple(stride),
                  f"conv2d: input {x.shape} smaller than kernel {w.shape}; "
                  "falling back to the XLA reference")
        return conv2d_ref(x, w, stride)
    return _conv2d_pallas(x, w, interpret=interpret)
