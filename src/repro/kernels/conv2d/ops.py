"""jit'd public wrapper for the conv2d Pallas kernel with shape guards."""

import warnings

import jax

from .conv2d import conv2d as _conv2d_pallas
from .ref import conv2d_ref

_warned: set[tuple] = set()


def _warn_once(key: tuple, msg: str) -> None:
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(msg, RuntimeWarning, stacklevel=3)


def conv2d(x: jax.Array, w: jax.Array, *, stride: tuple[int, int] = (1, 1),
           use_pallas: bool = True, interpret: bool = False) -> jax.Array:
    """VALID NHWC conv.  The Pallas implicit-GEMM kernel handles the
    stride-1 case; strided or kernel-unsupported shapes fall back to the
    XLA reference *inside this wrapper* (warning once per shape), so the
    caller's backend choice is honored for every conv in a segment
    instead of silently bypassing it.
    """
    N, H, W, CI = x.shape
    KH, KW, CI2, CO = w.shape
    assert CI == CI2, (x.shape, w.shape)
    if not use_pallas:
        return conv2d_ref(x, w, stride)
    if stride != (1, 1):
        _warn_once(("stride", stride, w.shape),
                   f"conv2d: Pallas kernel is stride-1 only; stride={stride} "
                   f"conv {w.shape} falls back to the XLA reference")
        return conv2d_ref(x, w, stride)
    if H < KH or W < KW:
        _warn_once(("shape", x.shape, w.shape),
                   f"conv2d: input {x.shape} smaller than kernel {w.shape}; "
                   "falling back to the XLA reference")
        return conv2d_ref(x, w, stride)
    return _conv2d_pallas(x, w, interpret=interpret)
