"""jit'd public wrapper for the conv2d Pallas kernel with shape guards."""

import time as _time
import warnings

import jax

from .conv2d import conv2d_fused as _conv2d_fused_pallas
from .ref import conv2d_fused_ref, conv2d_ref
from ...obs import trace as obs_trace
from ...obs.metrics import default_registry

_warned: set[tuple] = set()


def _warn_once(key: tuple, msg: str) -> None:
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(msg, RuntimeWarning, stacklevel=4)


def _fallback(reason: str, x_shape: tuple, w_shape: tuple,
              stride: tuple, msg: str) -> None:
    """Account one Pallas->XLA fallback: a ``conv.fallback`` counter
    labelled with the offending shape/stride (countable per run via
    ``Deployment.metrics_snapshot()``), a ``conv.fallback`` instant in
    the active tracer, and the once-per-shape RuntimeWarning."""
    default_registry().counter(
        "conv.fallback", reason=reason, x_shape=str(x_shape),
        w_shape=str(w_shape), stride=str(stride)).inc()
    tr = obs_trace.current()
    if tr:
        tr.instant("conv.fallback", _time.perf_counter() - tr.epoch,
                   reason=reason, x_shape=x_shape, w_shape=w_shape,
                   stride=stride)
    _warn_once((reason, x_shape, w_shape, stride), msg)


def fallback_count() -> int:
    """Total Pallas->XLA fallbacks recorded since process start or the
    last :func:`reset_fallbacks` (all shapes)."""
    return int(default_registry().total("conv.fallback"))


def reset_fallbacks() -> None:
    """Zero the fallback accounting so ``fallback_count()`` can be
    scoped per run instead of per process: drops every labelled
    ``conv.fallback`` counter from the default registry and clears the
    (otherwise unbounded) warn-once shape set along with it."""
    default_registry().drop("conv.fallback")
    _warned.clear()


def normalize_stride(stride) -> tuple[int, int]:
    """Accept ``int | tuple[int, int]``; an int applies to both axes."""
    if isinstance(stride, int):
        stride = (stride, stride)
    sh, sw = (int(s) for s in stride)
    if sh < 1 or sw < 1:
        raise ValueError(f"conv2d: stride must be >= 1, got {stride!r}")
    return (sh, sw)


def conv2d_fused(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
                 stride=(1, 1), relu: bool = False,
                 pool: tuple[int, int] | None = None,
                 block_ci: int | None = None, block_co: int | None = None,
                 use_pallas: bool = True, interpret: bool = False
                 ) -> jax.Array:
    """VALID NHWC conv with a fused epilogue (bias + relu + optional
    non-overlapping max-pool) in one Pallas call.

    The implicit-GEMM kernel handles any stride >= 1 and any channel
    count (tails are zero-padded up to the channel block); the only
    remaining fallback is an input spatially smaller than the kernel,
    which falls back to the composed XLA reference *inside this
    wrapper*, so the caller's backend choice is honored for every conv
    in a segment instead of silently bypassing it.  Each fallback is
    structured — a labelled ``conv.fallback`` metric plus a trace
    instant carrying the shape and stride — and still warns once per
    distinct shape.
    """
    stride = normalize_stride(stride)
    N, H, W, CI = x.shape
    KH, KW, CI2, CO = w.shape
    assert CI == CI2, (x.shape, w.shape)
    if pool is not None:
        pool = tuple(int(p) for p in pool)
    if not use_pallas:
        return conv2d_fused_ref(x, w, b, stride=stride, relu=relu, pool=pool)
    if H < KH or W < KW:
        _fallback("shape", tuple(x.shape), tuple(w.shape), stride,
                  f"conv2d: input {x.shape} smaller than kernel {w.shape}; "
                  "falling back to the XLA reference")
        return conv2d_fused_ref(x, w, b, stride=stride, relu=relu, pool=pool)
    return _conv2d_fused_pallas(x, w, b, stride=stride, relu=relu, pool=pool,
                                block_ci=block_ci, block_co=block_co,
                                interpret=interpret)


def conv2d(x: jax.Array, w: jax.Array, *, stride=(1, 1),
           use_pallas: bool = True, block_ci: int | None = None,
           block_co: int | None = None, interpret: bool = False
           ) -> jax.Array:
    """VALID NHWC conv, no epilogue — :func:`conv2d_fused` without the
    fused tail.  Kept as the plain-kernel entry point for sweeps and
    benchmarks."""
    stride = normalize_stride(stride)
    N, H, W, CI = x.shape
    KH, KW, CI2, CO = w.shape
    assert CI == CI2, (x.shape, w.shape)
    if not use_pallas:
        return conv2d_ref(x, w, stride)
    if H < KH or W < KW:
        _fallback("shape", tuple(x.shape), tuple(w.shape), stride,
                  f"conv2d: input {x.shape} smaller than kernel {w.shape}; "
                  "falling back to the XLA reference")
        return conv2d_ref(x, w, stride)
    return _conv2d_fused_pallas(x, w, None, stride=stride,
                                block_ci=block_ci, block_co=block_co,
                                interpret=interpret)
