"""Pallas TPU conv2d kernel (implicit GEMM) — the paper's compute hot spot.

TPU adaptation (DESIGN.md §3): instead of porting a CUDA im2col conv, the
kernel decomposes the convolution into KH*KW shifted matmuls feeding the
MXU, with BlockSpec tiling over (batch, out-channel, in-channel) and an
fp32 VMEM accumulator.  The in-channel grid axis is innermost so the
accumulator lives across its iterations (sequential grid on TPU).

Layout: NHWC x HWIO -> NHWC, VALID (the executable zoo's tiled stages
present exactly this: padding is materialized by the stage boundary).

Supported conv space:

* any stride >= 1 per spatial axis — the shifted-matmul patch gather
  strides its slices, so the GEMM shape shrinks with the output instead
  of computing discarded rows;
* any channel count — inputs/weights are zero-padded up to the channel
  block in the wrapper (zeros contribute nothing to the accumulation and
  the padded out-channel tail is sliced off), so the MXU block size never
  degrades to a tiny divisor tile for channel tails;
* a fused epilogue executed inside the accumulator emit: bias add, relu,
  and an optional non-overlapping max-pool (kernel == stride, e.g. 2x2),
  all in fp32 before the final cast, so a conv->bias->relu->pool chain is
  one Pallas call with no VMEM round-trips between the ops.

Channel block sizes (``block_ci``/``block_co``) are tunable —
``repro.exec.autotune`` searches them per conv shape and persists the
winners in the CostTable artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv2d_kernel(*refs, kh: int, kw: int, sh: int, sw: int, h_out: int,
                   w_out: int, n_ci_blocks: int, relu: bool,
                   pool: tuple[int, int] | None, has_bias: bool):
    if has_bias:
        x_ref, w_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, o_ref, acc_ref = refs
        b_ref = None
    ci = pl.program_id(3)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]          # (H_in, W_in, TCI)
    w = w_ref[...]        # (KH, KW, TCI, TCO)
    acc = acc_ref[...]
    for dh in range(kh):
        for dw in range(kw):
            patch = x[dh:dh + (h_out - 1) * sh + 1:sh,
                      dw:dw + (w_out - 1) * sw + 1:sw, :]   # (H,W,TCI)
            lhs = patch.reshape(h_out * w_out, patch.shape[-1])
            rhs = w[dh, dw]                                  # (TCI, TCO)
            acc += jnp.dot(lhs, rhs,
                           preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(ci == n_ci_blocks - 1)
    def _emit():
        y = acc.reshape(h_out, w_out, -1)
        if b_ref is not None:
            y = y + b_ref[0]
        if relu:
            y = jnp.maximum(y, 0.0)
        if pool is not None:
            ph, pw = pool
            hp, wp = h_out // ph, w_out // pw
            y = y[:hp * ph, :wp * pw, :]
            y = y.reshape(hp, ph, wp, pw, y.shape[-1]).max(axis=(1, 3))
        o_ref[0] = y.astype(o_ref.dtype)


def _pick_tile(c: int, pref: int = 128) -> int:
    """Pre-padding tile heuristic: largest power-of-two *divisor* of the
    channel count.  Kept as the legacy reference the microbench compares
    tuned blocks against; the fast path no longer needs a divisor (the
    wrapper pads channel tails up to the block)."""
    if c % pref == 0:
        return pref
    for t in (64, 32, 16, 8):
        if c % t == 0:
            return t
    return c


def _pick_block(c: int, pref: int = 128) -> int:
    """Default channel block: the MXU-aligned 128 when the axis reaches
    it, else the axis rounded up to the next power of two >= 8 (a single
    zero-padded block)."""
    if c >= pref:
        return pref
    b = 8
    while b < c:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=(
    "stride", "relu", "pool", "block_ci", "block_co", "interpret"))
def conv2d_fused(x: jax.Array, w: jax.Array, b: jax.Array | None = None, *,
                 stride: tuple[int, int] = (1, 1), relu: bool = False,
                 pool: tuple[int, int] | None = None,
                 block_ci: int | None = None, block_co: int | None = None,
                 interpret: bool = False) -> jax.Array:
    """x: (N, H, W, CI); w: (KH, KW, CI, CO); b: (CO,) or None.

    Strided VALID conv with the fused epilogue described in the module
    docstring.  ``pool`` is the max-pool window (== its stride); the
    pooled output is ``(H_out // ph, W_out // pw)`` — identical to a
    VALID non-overlapping ``lax.reduce_window``.  ``block_ci`` /
    ``block_co`` override the channel block sizes (autotune winners).
    """
    N, H, W, CI = x.shape
    KH, KW, CI2, CO = w.shape
    assert CI == CI2, (x.shape, w.shape)
    sh, sw = stride
    HO = (H - KH) // sh + 1
    WO = (W - KW) // sw + 1
    tci = block_ci or _pick_block(CI)
    tco = block_co or _pick_block(CO)
    ci_pad = -CI % tci
    co_pad = -CO % tco
    if ci_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, ci_pad)))
        w = jnp.pad(w, ((0, 0), (0, 0), (0, ci_pad), (0, 0)))
    if co_pad:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, co_pad)))
        if b is not None:
            b = jnp.pad(b, (0, co_pad))
    n_ci = (CI + ci_pad) // tci
    n_co = (CO + co_pad) // tco
    if pool is not None:
        HP, WP = HO // pool[0], WO // pool[1]
    else:
        HP, WP = HO, WO

    grid = (N, 1, n_co, n_ci)
    kernel = functools.partial(
        _conv2d_kernel, kh=KH, kw=KW, sh=sh, sw=sw, h_out=HO, w_out=WO,
        n_ci_blocks=n_ci, relu=relu, pool=pool, has_bias=b is not None)
    in_specs = [
        pl.BlockSpec((1, H, W, tci), lambda n, h, co, ci: (n, 0, 0, ci)),
        pl.BlockSpec((KH, KW, tci, tco), lambda n, h, co, ci: (0, 0, ci, co)),
    ]
    args = [x, w]
    if b is not None:
        in_specs.append(pl.BlockSpec((1, tco), lambda n, h, co, ci: (0, co)))
        args.append(b.reshape(1, -1))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, HP, WP, tco),
                               lambda n, h, co, ci: (n, 0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((N, HP, WP, CO + co_pad), x.dtype),
        scratch_shapes=[pltpu.VMEM((HO * WO, tco), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out[..., :CO] if co_pad else out


def conv2d(x: jax.Array, w: jax.Array, *,
           stride: tuple[int, int] = (1, 1),
           block_ci: int | None = None, block_co: int | None = None,
           interpret: bool = False) -> jax.Array:
    """Plain strided VALID conv (no epilogue) — thin alias over
    :func:`conv2d_fused`."""
    return conv2d_fused(x, w, None, stride=stride, block_ci=block_ci,
                        block_co=block_co, interpret=interpret)
