"""Pallas TPU conv2d kernel (implicit GEMM) — the paper's compute hot spot.

TPU adaptation (DESIGN.md §3): instead of porting a CUDA im2col conv, the
kernel decomposes the convolution into KH*KW shifted matmuls feeding the
MXU, with BlockSpec tiling over (batch, out-channel, in-channel) and an
fp32 VMEM accumulator.  The in-channel grid axis is innermost so the
accumulator lives across its iterations (sequential grid on TPU).

Layout: NHWC x HWIO -> NHWC, stride 1, VALID (the executable zoo's tiled
stages present exactly this: padding is materialized by the stage
boundary).  Channel tiles are MXU-aligned (128) whenever the channel
counts allow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv2d_kernel(x_ref, w_ref, o_ref, acc_ref, *, kh: int, kw: int,
                   n_ci_blocks: int):
    ci = pl.program_id(3)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]          # (H_in, W_in, TCI)
    w = w_ref[...]        # (KH, KW, TCI, TCO)
    H_out = o_ref.shape[1]
    W_out = o_ref.shape[2]
    acc = acc_ref[...]
    for dh in range(kh):
        for dw in range(kw):
            patch = x[dh:dh + H_out, dw:dw + W_out, :]       # (H,W,TCI)
            lhs = patch.reshape(H_out * W_out, patch.shape[-1])
            rhs = w[dh, dw]                                   # (TCI, TCO)
            acc += jnp.dot(lhs, rhs,
                           preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(ci == n_ci_blocks - 1)
    def _emit():
        o_ref[0] = acc.reshape(H_out, W_out, -1).astype(o_ref.dtype)


def _pick_tile(c: int, pref: int = 128) -> int:
    if c % pref == 0:
        return pref
    for t in (64, 32, 16, 8):
        if c % t == 0:
            return t
    return c


@functools.partial(jax.jit, static_argnames=("interpret",))
def conv2d(x: jax.Array, w: jax.Array, *, interpret: bool = False
           ) -> jax.Array:
    """x: (N, H, W, CI); w: (KH, KW, CI, CO).  Stride-1 VALID conv."""
    N, H, W, CI = x.shape
    KH, KW, _, CO = w.shape
    HO, WO = H - KH + 1, W - KW + 1
    tci = _pick_tile(CI)
    tco = _pick_tile(CO)
    n_ci = CI // tci

    grid = (N, 1, CO // tco, n_ci)
    kernel = functools.partial(_conv2d_kernel, kh=KH, kw=KW,
                               n_ci_blocks=n_ci)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, W, tci), lambda n, h, co, ci: (n, 0, 0, ci)),
            pl.BlockSpec((KH, KW, tci, tco),
                         lambda n, h, co, ci: (0, 0, ci, co)),
        ],
        out_specs=pl.BlockSpec((1, HO, WO, tco),
                               lambda n, h, co, ci: (n, 0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((N, HO, WO, CO), x.dtype),
        scratch_shapes=[pltpu.VMEM((HO * WO, tco), jnp.float32)],
        interpret=interpret,
    )(x, w)
