"""Pure-jnp oracle for the conv2d kernel."""

import jax


def conv2d_ref(x: jax.Array, w: jax.Array,
               stride: tuple[int, int] = (1, 1)) -> jax.Array:
    """x: (N, H, W, CI); w: (KH, KW, CI, CO).  VALID conv, (sh, sw) stride."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
