"""Pure-jnp oracle for the conv2d kernel."""

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (N, H, W, CI); w: (KH, KW, CI, CO).  Stride-1 VALID conv."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
