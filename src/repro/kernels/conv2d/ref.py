"""Pure-jnp oracle for the conv2d kernel."""

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array,
               stride: tuple[int, int] = (1, 1)) -> jax.Array:
    """x: (N, H, W, CI); w: (KH, KW, CI, CO).  VALID conv, (sh, sw) stride."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def conv2d_fused_ref(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
                     *, stride: tuple[int, int] = (1, 1), relu: bool = False,
                     pool: tuple[int, int] | None = None) -> jax.Array:
    """Composed-ops oracle for the fused conv epilogue: VALID conv,
    + bias, relu, then a VALID non-overlapping (kernel == stride)
    max-pool — the eager sequence the fused kernel collapses."""
    y = conv2d_ref(x, w, stride)
    if b is not None:
        y = y + b
    if relu:
        y = jax.nn.relu(y)
    if pool is not None:
        ph, pw = pool
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max,
            window_dimensions=(1, ph, pw, 1),
            window_strides=(1, ph, pw, 1), padding="VALID")
    return y
