"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as <name>.py (pl.pallas_call + BlockSpec), ops.py
(jit'd wrapper with fallback) and ref.py (pure-jnp oracle).  Kernels are
validated on CPU in interpret mode; pure-JAX paths are used on the CPU
dry-run (Pallas lowers for TPU targets only).
"""
