"""Pallas TPU flash-decode attention kernel (GQA, masked KV length).

One new query token per sequence attends to a (possibly partially
filled) KV cache.  Grid: (batch, kv_head, kv_blocks); the kv-block axis
is innermost so the online-softmax state (m, l, acc) lives in VMEM
scratch across its sequential iterations — the classic flash-decoding
structure, restated for the TPU's sequential grid instead of CUDA
thread-block splits (DESIGN.md §3).

The valid cache length arrives via scalar prefetch so block masking is
computed on-core.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *, ts: int, n_s: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                    # (G, D)
    k = k_ref[0, :, 0, :]              # (TS, D)
    v = v_ref[0, :, 0, :]              # (TS, D)
    valid_len = len_ref[0]
    scale = 1.0 / math.sqrt(q.shape[-1])

    pos = s * ts + jax.lax.broadcasted_iota(jnp.int32, (ts,), 0)
    mask = pos < valid_len             # (TS,)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[None, :], scores, -1e30)   # (G, TS)

    m_prev = m_ref[...]                # (G, 1)
    m_new = jnp.maximum(m_prev[:, 0], scores.max(axis=-1))[:, None]
    p = jnp.exp(scores - m_new)        # (G, TS)
    corr = jnp.exp(m_prev - m_new)     # (G, 1)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _pick_ts(S: int, pref: int = 512) -> int:
    if S % pref == 0:
        return pref
    for t in (256, 128, 64, 32, 16, 8):
        if S % t == 0:
            return t
    return S


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, *, interpret: bool = False
                     ) -> jax.Array:
    """q: (B, K, G, D); k/v: (B, S, K, D); valid_len: () int32.

    Returns (B, K, G, D) — softmax(q k^T / sqrt(D)) v over the first
    ``valid_len`` cache entries.
    """
    B, K, G, D = q.shape
    S = k.shape[1]
    ts = _pick_ts(S)
    n_s = S // ts
    kernel = functools.partial(_decode_attn_kernel, ts=ts, n_s=n_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, K, n_s),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, s, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, ts, 1, D), lambda b, h, s, lens: (b, s, h, 0)),
            pl.BlockSpec((1, ts, 1, D), lambda b, h, s, lens: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, s, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    lens = jnp.asarray(valid_len, jnp.int32).reshape(1)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        interpret=interpret,
    )(lens, q, k, v)
