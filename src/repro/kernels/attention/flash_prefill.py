"""Pallas TPU flash-attention prefill kernel (causal, GQA).

This is the on-TPU answer to the §Perf finding that blockwise attention
in plain XLA materializes every f32 score/prob block to HBM (the
dominant memory-roofline term for prefill_32k): here the (qb x kb)
score tile lives entirely in VMEM scratch; HBM sees only q/k/v/o tiles.

Grid: (batch*kv_head, q_blocks, kv_blocks), kv innermost so the online
softmax state (m, l, acc) persists in VMEM scratch across the kv sweep.
Causality is enforced per-tile; fully-masked tiles still iterate (TPU
grids are static) but skip the matmuls via @pl.when.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  qb: int, kb: int, n_kb: int, sliding_window: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile is live iff some (q, k) pair inside is causal-visible
    live = (qi + 1) * qb - 1 >= kj * kb
    if sliding_window:
        live &= qi * qb - ((kj + 1) * kb - 1) < sliding_window

    @pl.when(live)
    def _compute():
        q = q_ref[0]                   # (qb, G, D)
        k = k_ref[0]                   # (kb, D)
        v = v_ref[0]                   # (kb, D)
        G, D = q.shape[1], q.shape[2]
        scale = 1.0 / math.sqrt(D)
        qf = q.reshape(qb * G, D)
        s = jnp.dot(qf, k.T, preferred_element_type=jnp.float32) * scale
        s = s.reshape(qb, G, kb)
        q_pos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, 1, kb), 0)
        k_pos = kj * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, 1, kb), 2)
        mask = q_pos >= k_pos
        if sliding_window:
            mask &= (q_pos - k_pos) < sliding_window
        s = jnp.where(mask, s, -1e30)

        m_prev = m_ref[...]            # (qb, G)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        pv = jnp.dot(p.reshape(qb * G, kb).astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[..., None].reshape(
            qb * G, 1) + pv
        m_ref[...] = m_new

    @pl.when(kj == n_kb - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30).reshape(-1, 1)
        o_ref[0] = (acc_ref[...] / l).reshape(o_ref.shape[1:]) \
            .astype(o_ref.dtype)


def _pick(s: int, pref: int) -> int:
    if s % pref == 0:
        return pref
    for t in (256, 128, 64, 32, 16, 8):
        if s % t == 0:
            return t
    return s


@functools.partial(jax.jit,
                   static_argnames=("sliding_window", "interpret"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  sliding_window: int = 0, interpret: bool = False
                  ) -> jax.Array:
    """Causal GQA attention.  q: (B, S, K, G, D); k/v: (B, S, K, D).

    Returns (B, S, K, G, D).
    """
    B, S, K, G, D = q.shape
    qb = _pick(S, 512)
    kb = _pick(S, 512)
    n_qb, n_kb = S // qb, S // kb
    # fold (B, K) into one grid axis via reshape
    qr = q.transpose(0, 2, 1, 3, 4).reshape(B * K, S, G, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * K, S, D)
    kernel = functools.partial(_flash_kernel, qb=qb, kb=kb, n_kb=n_kb,
                               sliding_window=sliding_window)
    out = pl.pallas_call(
        kernel,
        grid=(B * K, n_qb, n_kb),
        in_specs=[
            pl.BlockSpec((1, qb, G, D), lambda b, i, j: (b, i, 0, 0)),
            pl.BlockSpec((1, kb, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, G, D), lambda b, i, j: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, S, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, G), jnp.float32),
            pltpu.VMEM((qb, G), jnp.float32),
            pltpu.VMEM((qb * G, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, K, S, G, D).transpose(0, 2, 1, 3, 4)
