"""Pure-jnp oracle for flash-decode attention."""

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         valid_len: jax.Array) -> jax.Array:
    """q: (B, K, G, D); k/v: (B, S, K, D)."""
    S = k.shape[1]
    s = jnp.einsum("bkgd,bskd->bkgs", q, k) / math.sqrt(q.shape[-1])
    mask = jnp.arange(S) < valid_len
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(v.dtype)
    return jnp.einsum("bkgs,bskd->bkgd", w, v)
