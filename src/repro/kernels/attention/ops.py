"""jit'd public wrapper for the flash-decode attention kernel."""

import jax

from .decode_attn import decode_attention as _decode_pallas
from .ref import decode_attention_ref


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len, *, use_pallas: bool = True,
                     interpret: bool = False) -> jax.Array:
    if not use_pallas:
        return decode_attention_ref(q, k, v, valid_len)
    return _decode_pallas(q, k, v, valid_len, interpret=interpret)
