"""Operational CLIs over the public ``repro.api`` facade."""
