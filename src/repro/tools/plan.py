"""Plan CLI: build, save, load, and validate deployment artifacts.

The offline half of the paper's offline-plan/online-execute split as a
shell command — plan on a workstation, ship ``plan.json`` to the fleet:

    # plan VGG16 across 4 heterogeneous Pis and save the artifact
    python -m repro.tools.plan --model vgg16 --devices 4 --out plan.json

    # on the target: reload and verify without re-planning
    python -m repro.tools.plan --load plan.json --validate

``--validate`` proves the artifact round-trips (re-serialization is
byte-identical), prices coherently (simulate matches the plan period),
and — with ``--execute`` — still produces numerics bit-exact with the
monolithic forward.
"""

from __future__ import annotations

import argparse
import json
import sys

DEFAULT_FREQS = (1.5, 1.2, 1.0, 0.8)


def _build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.plan", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--model", default=None,
                    help="zoo model name (vgg16, resnet34, squeezenet, ...)")
    ap.add_argument("--scale", type=float, default=1.0,
                    help="channel scale for the zoo model")
    ap.add_argument("--input", default=None, metavar="W[,H]",
                    help="input size override, e.g. 128 or 128,96")
    ap.add_argument("--devices", type=int, default=4,
                    help="cluster size (Raspberry-Pi model)")
    ap.add_argument("--freqs", default=None,
                    help="comma-separated device GHz (cycled to --devices); "
                         f"default {','.join(map(str, DEFAULT_FREQS))}")
    ap.add_argument("--bandwidth-mbps", type=float, default=50.0)
    ap.add_argument("--t-lim", type=float, default=float("inf"))
    ap.add_argument("--max-diameter", type=int, default=5)
    ap.add_argument("--n-split", type=int, default=None)
    ap.add_argument("--backend", default=None,
                    help="conv lowering backend (xla, pallas)")
    ap.add_argument("--calibrate", action="store_true",
                    help="time compiled stages and re-plan on measured costs")
    ap.add_argument("--out", default=None, help="write the deployment here")
    ap.add_argument("--load", default=None, metavar="PLAN_JSON",
                    help="load a saved deployment instead of planning")
    ap.add_argument("--validate", action="store_true",
                    help="with --load: verify round-trip + simulate")
    ap.add_argument("--execute", action="store_true",
                    help="with --validate: run one frame and check numerics")
    return ap


def _make_cluster(args):
    from repro.core import make_pi_cluster
    freqs = ([float(f) for f in args.freqs.split(",")] if args.freqs
             else list(DEFAULT_FREQS))
    freqs = [freqs[i % len(freqs)] for i in range(args.devices)]
    return make_pi_cluster(freqs, bandwidth_mbps=args.bandwidth_mbps)


def _make_model(args):
    from repro.models.cnn import zoo
    kw = {"scale": args.scale}
    if args.input:
        parts = [int(x) for x in args.input.split(",")]
        kw["input_size"] = (parts[0], parts[-1] if len(parts) > 1
                            else parts[0])
    return zoo.build(args.model, **kw)


def _cmd_plan(args) -> int:
    import repro
    model = _make_model(args)
    cluster = _make_cluster(args)
    dep = repro.compile(
        model, cluster,
        repro.PlanSpec(t_lim=args.t_lim, max_diameter=args.max_diameter,
                       n_split=args.n_split),
        repro.ExecSpec(backend=args.backend, calibrate=args.calibrate))
    print(dep.describe())
    if args.out:
        path = dep.save(args.out)
        print(f"saved deployment artifact -> {path}")
    return 0


def _cmd_load(args) -> int:
    import repro
    dep = repro.Deployment.load(args.load)
    print(dep.describe())
    if not args.validate:
        return 0
    # 1. re-serialization is byte-identical (stable schema)
    s = dep.to_json()
    if repro.Deployment.from_json(s).to_json() != s:
        print("FAIL: artifact does not re-serialize identically",
              file=sys.stderr)
        return 1
    with open(args.load) as f:
        version = json.load(f).get("version")
    # 2. the priced plan is internally coherent
    rep = dep.simulate(frames=16)
    worst = max(st.cost.total for st in dep.pipeline.stages)
    if abs(rep.period - worst) > 1e-9 * max(1.0, worst):
        print(f"FAIL: simulate period {rep.period} != plan period {worst}",
              file=sys.stderr)
        return 1
    print(f"validate: schema v{version} ok, round-trip ok, "
          f"simulated period {rep.period * 1e3:.2f} ms, "
          f"avg util {rep.avg_utilization:.2f}")
    if args.execute:
        import jax
        import numpy as np
        w, h = dep.model.input_size
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (1, h, w, dep.model.in_channels))
        out = dep.run(x)
        ref = dep.model.forward(dep.params, x)
        for k in ref:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(ref[k]),
                                       rtol=1e-5, atol=1e-5)
        print("execute: pipelined outputs match monolithic forward ✓")
    return 0


def main(argv=None) -> int:
    args = _build_parser().parse_args(argv)
    if args.load:
        return _cmd_load(args)
    if not args.model:
        print("error: need --model to plan or --load to reload",
              file=sys.stderr)
        return 2
    return _cmd_plan(args)


if __name__ == "__main__":
    sys.exit(main())
