"""Trace CLI: summarize and validate ``repro.obs`` Perfetto traces.

The online half of the observability story as a shell command — run a
traced deployment (``DeploySpec(trace=True)``), save the trace, then:

    # structural validation (CI runs this on the smoke-bench artifact)
    python -m repro.tools.trace TRACE.json --validate

    # human summary: per-device and per-stage breakdowns, the
    # pipeline-bubble fraction, and the modeled critical path
    python -m repro.tools.trace TRACE.json

The summary is computed purely from the span tree (no re-simulation):
device rows aggregate ``stage.compute`` spans per track, the bubble
fraction is the idle share of each device's busy window, and the
critical path chains the worst compute phase of every stage plus the
inter-stage transfers — the pipeline's latency lower bound as traced.
"""

from __future__ import annotations

import argparse
import json
import sys


def _span_stats(spans) -> dict:
    """Aggregate a span list into the summary's building blocks."""
    from repro.obs.metrics import quantile
    compute = [s for s in spans if s.name == "stage.compute"]
    comm = [s for s in spans if s.name in ("stage.comm", "halo.exchange")]
    frames = [s for s in spans if s.name == "frame"]
    t0 = min((s.ts for s in spans), default=0.0)
    t1 = max((s.end for s in spans), default=0.0)
    per_device: dict[str, dict] = {}
    for s in compute:
        d = per_device.setdefault(s.track, {"n": 0, "busy": 0.0})
        d["n"] += 1
        d["busy"] += s.dur
    per_stage: dict[int, list] = {}
    for s in compute:
        per_stage.setdefault(int(s.attr("stage", -1)), []).append(s.dur)
    comm_per_stage: dict[int, list] = {}
    for s in comm:
        comm_per_stage.setdefault(int(s.attr("stage", -1)), []).append(s.dur)
    # critical path: the worst compute phase of every stage, chained,
    # plus the worst transfer after each stage
    critical = (sum(max(d) for d in per_stage.values())
                + sum(max(d) for d in comm_per_stage.values()))
    lat = [s.dur for s in frames]
    return {
        "window": (t0, t1),
        "per_device": per_device,
        "per_stage": per_stage,
        "comm_per_stage": comm_per_stage,
        "critical_path_s": critical,
        "frames": len(frames),
        "frame_lat": {"mean": sum(lat) / len(lat) if lat else 0.0,
                      "p50": quantile(lat, 50.0),
                      "p95": quantile(lat, 95.0)},
    }


def bubble_fraction(spans) -> float:
    """Idle share of the pipeline: 1 - busy/(devices x window), over
    the span window.  0 = perfectly packed, 1 = fully idle."""
    stats = _span_stats(spans)
    t0, t1 = stats["window"]
    window = t1 - t0
    devices = stats["per_device"]
    if window <= 0.0 or not devices:
        return 0.0
    busy = sum(d["busy"] for d in devices.values())
    return max(0.0, 1.0 - busy / (window * len(devices)))


def summarize(spans, out=sys.stdout) -> None:
    """Print the per-device / per-stage breakdown for a span list."""
    st = _span_stats(spans)
    t0, t1 = st["window"]
    window = t1 - t0
    print(f"trace: {len(spans)} spans over {window * 1e3:.3f} ms "
          f"({st['frames']} frames)", file=out)
    if st["frames"]:
        fl = st["frame_lat"]
        print(f"frame latency: mean {fl['mean'] * 1e3:.3f} ms, "
              f"p50 {fl['p50'] * 1e3:.3f} ms, p95 {fl['p95'] * 1e3:.3f} ms",
              file=out)
    if st["per_device"]:
        print("per-device compute:", file=out)
        for track in sorted(st["per_device"]):
            d = st["per_device"][track]
            util = d["busy"] / window if window > 0 else 0.0
            print(f"  {track:<12} {d['n']:>5} phases  "
                  f"busy {d['busy'] * 1e3:9.3f} ms  util {util:6.1%}",
                  file=out)
        print(f"pipeline bubble fraction: {bubble_fraction(spans):.1%}",
              file=out)
    if st["per_stage"]:
        print("per-stage compute:", file=out)
        for s in sorted(st["per_stage"]):
            durs = st["per_stage"][s]
            comm = sum(st["comm_per_stage"].get(s, ()))
            print(f"  stage {s:<3} {len(durs):>5} phases  "
                  f"mean {sum(durs) / len(durs) * 1e3:8.3f} ms  "
                  f"max {max(durs) * 1e3:8.3f} ms  "
                  f"comm {comm * 1e3:8.3f} ms", file=out)
        print(f"critical path (worst chain): "
              f"{st['critical_path_s'] * 1e3:.3f} ms", file=out)
    other = sorted({s.name for s in spans}
                   - {"stage.compute", "stage.comm", "halo.exchange",
                      "frame"})
    if other:
        counts = {n: sum(1 for s in spans if s.name == n) for n in other}
        print("other spans: " + ", ".join(f"{n} x{c}"
                                          for n, c in counts.items()),
              file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tools.trace", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("trace", help="Chrome-trace JSON from Tracer.save()")
    ap.add_argument("--validate", action="store_true",
                    help="structural validation only (exit 1 on problems)")
    args = ap.parse_args(argv)

    from repro.obs.trace import from_chrome_trace, validate_chrome_trace
    with open(args.trace) as f:
        doc = json.load(f)
    errors = validate_chrome_trace(doc)
    if args.validate:
        if errors:
            print(f"INVALID: {len(errors)} problem(s)", file=sys.stderr)
            for e in errors:
                print(f"  {e}", file=sys.stderr)
            return 1
        n = sum(1 for ev in doc["traceEvents"]
                if ev.get("ph") in ("X", "i", "I"))
        print(f"valid chrome trace: {n} events, "
              f"{len({ev.get('pid') for ev in doc['traceEvents']})} tracks")
        return 0
    if errors:
        print(f"cannot summarize: trace has {len(errors)} structural "
              f"problem(s) — run with --validate for the list",
              file=sys.stderr)
        return 1
    summarize(from_chrome_trace(doc))
    return 0


if __name__ == "__main__":
    sys.exit(main())
