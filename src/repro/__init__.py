"""PICO reproduction — pipelined CNN inference on heterogeneous clusters.

Public API (the ``repro.api`` facade):

    import repro
    dep = repro.compile(model, cluster,
                        repro.PlanSpec(t_lim=0.5),
                        repro.ExecSpec(backend="xla", calibrate=True))
    dep.run(frames); dep.save("plan.json")
    dep = repro.Deployment.load("plan.json")     # no re-plan, no re-calib

Subsystems (``repro.core``, ``repro.exec``, ``repro.runtime``,
``repro.serving``, ``repro.models``, ...) import on demand; nothing
heavyweight loads at package import time.
"""

from .api._compat import lazy_exports

_LAZY = {
    "compile": ("repro.api.deployment", "compile"),
    "Deployment": ("repro.api.deployment", "Deployment"),
    "PlanSpec": ("repro.api.specs", "PlanSpec"),
    "ExecSpec": ("repro.api.specs", "ExecSpec"),
    "DeploySpec": ("repro.api.specs", "DeploySpec"),
    "FleetSpec": ("repro.api.specs", "FleetSpec"),
    "DistSpec": ("repro.api.specs", "DistSpec"),
    "DistLauncher": ("repro.dist.launcher", "DistLauncher"),
    "ObjectiveSpec": ("repro.api.specs", "ObjectiveSpec"),
    "OBJECTIVE_PRESETS": ("repro.api.specs", "OBJECTIVE_PRESETS"),
    "plan_front": ("repro.core.pareto", "plan_front"),
    "ParetoFront": ("repro.core.pareto", "ParetoFront"),
    "PlanRegistry": ("repro.fleet.registry", "PlanRegistry"),
    "FleetRouter": ("repro.fleet.router", "FleetRouter"),
    "api": ("repro.api", None),
    "obs": ("repro.obs", None),
    "fleet": ("repro.fleet", None),
    "dist": ("repro.dist", None),
}

__all__ = ["compile", "Deployment", "PlanSpec", "ExecSpec", "DeploySpec",
           "FleetSpec", "DistSpec", "DistLauncher", "ObjectiveSpec",
           "OBJECTIVE_PRESETS", "plan_front", "ParetoFront", "PlanRegistry",
           "FleetRouter", "api", "obs", "fleet", "dist"]

__getattr__, __dir__ = lazy_exports(__name__, globals(), _LAZY)
