"""Version shims for the moving jax API surface.

``shard_map``: top-level export with ``check_vma`` on jax >= 0.6;
``jax.experimental.shard_map`` with ``check_rep`` before that.  Call
sites use the modern spelling and this wrapper translates.
"""

from __future__ import annotations

import contextlib

import jax

try:
    from jax import shard_map as _shard_map_impl
    _NO_CHECK_KW = {"check_vma": False}
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _NO_CHECK_KW = {"check_rep": False}


def _ambient_mesh():
    """The mesh of the enclosing ``with mesh:`` context (old-jax only,
    where shard_map has no mesh-optional form)."""
    from jax._src import mesh as _mesh_lib
    m = _mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def shard_map(f, *, in_specs, out_specs, mesh=None, check_vma=True):
    kw = {} if check_vma else dict(_NO_CHECK_KW)
    if mesh is None and "check_rep" in _NO_CHECK_KW:
        mesh = _ambient_mesh()
        if mesh is None:
            raise ValueError("shard_map on this jax version needs an "
                             "explicit mesh= or an enclosing `with mesh:`")
    if mesh is not None:
        kw["mesh"] = mesh
    return _shard_map_impl(f, in_specs=in_specs, out_specs=out_specs, **kw)


def set_mesh(mesh):
    """``jax.set_mesh`` context where it exists; on older jax the plain
    ``with mesh:`` context (which callers already hold) is sufficient,
    so this degrades to a no-op context manager."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return contextlib.nullcontext(mesh)
