"""Pluggable per-layer execution backends.

A backend is how a *conv* vertex is lowered — everything else (pool,
fc, connectors) is backend-independent XLA.  Backends are registered in
a process-wide table but *selected* explicitly: :class:`CNNDef` carries
a ``backend`` field and the stage executors thread it through, so there
is no mutable module global deciding the numerics of an already-built
model (the seed's ``_CONV_BACKEND`` failure mode).

Registered backends:

``xla``
    ``lax.conv_general_dilated`` — the reference path on every platform.
``pallas``
    The repro's implicit-GEMM Pallas kernel (``kernels.conv2d``), which
    handles any stride >= 1 and any channel count (tails are padded up
    to the channel block) and carries the conv epilogue — bias, relu,
    optional non-overlapping max-pool — inside the kernel.
    ``interpret`` is auto-detected from the JAX platform: on TPU the
    kernel actually compiles; elsewhere it runs in interpret mode
    (slow but bit-faithful).  Channel block sizes come from
    ``exec.autotune``'s installed winners when present.

A backend may additionally register a *fused* lowering: the signature
covers the whole conv epilogue (conv + bias + relu + optional pool) in
one call, and ``exec.compiler.fusable_chains`` only rewrites segments
for backends that have one — backends without it (xla) keep the exact
composed-op sequence, preserving bit-equality with the eager oracle.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..core.graph import LayerSpec

# conv backend signature: (spec, params, x, pad_w) -> y  (NHWC, VALID +
# explicit pad_w/ph padding, no bias, no activation)
ConvFn = Callable[[LayerSpec, dict, jax.Array, tuple[int, int]], jax.Array]

# fused lowering: (conv_spec, pool_spec | None, params, x, pad_w, relu)
# -> y, with bias + relu (+ pool) applied — one kernel call per chain
FusedConvFn = Callable[
    [LayerSpec, Optional[LayerSpec], dict, jax.Array, tuple[int, int], bool],
    jax.Array]

_REGISTRY: dict[str, ConvFn] = {}
_FUSED: dict[str, FusedConvFn] = {}
DEFAULT_BACKEND = "xla"


def register_backend(name: str, fn: ConvFn,
                     fused: FusedConvFn | None = None) -> None:
    _REGISTRY[name] = fn
    if fused is not None:
        _FUSED[name] = fused
    else:
        _FUSED.pop(name, None)


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def get_backend(name: str | None) -> ConvFn:
    name = name or DEFAULT_BACKEND
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown exec backend {name!r}; "
                         f"registered: {available_backends()}") from None


def has_fused(name: str | None) -> bool:
    """Does ``name`` register a fused conv-epilogue lowering?"""
    return (name or DEFAULT_BACKEND) in _FUSED


def default_interpret() -> bool:
    """Pallas interpret mode: only compile for real on TPU."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

def _conv_xla(spec: LayerSpec, p: dict, x: jax.Array,
              pad_w: tuple[int, int]) -> jax.Array:
    ph = spec.padding[1]
    return jax.lax.conv_general_dilated(
        x, p["w"],
        window_strides=(spec.stride[1], spec.stride[0]),
        padding=((ph, ph), pad_w),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _tuned(xp: jax.Array, w: jax.Array, stride, relu: bool,
           pool) -> tuple[int | None, int | None]:
    from .autotune import tuned_blocks
    return tuned_blocks(xp.shape, w.shape, stride, relu, pool,
                        backend="pallas")


def _conv_pallas(spec: LayerSpec, p: dict, x: jax.Array,
                 pad_w: tuple[int, int]) -> jax.Array:
    from ..kernels.conv2d.ops import conv2d as conv2d_kernel
    ph = spec.padding[1]
    xp = jnp.pad(x, ((0, 0), (ph, ph), pad_w, (0, 0)))
    stride = (spec.stride[1], spec.stride[0])
    bci, bco = _tuned(xp, p["w"], stride, False, None)
    return conv2d_kernel(xp, p["w"], stride=stride, block_ci=bci,
                         block_co=bco, interpret=default_interpret())


def _conv_pallas_fused(spec: LayerSpec, pool_spec: LayerSpec | None, p: dict,
                       x: jax.Array, pad_w: tuple[int, int],
                       relu: bool) -> jax.Array:
    from ..kernels.conv2d.ops import conv2d_fused
    ph = spec.padding[1]
    xp = jnp.pad(x, ((0, 0), (ph, ph), pad_w, (0, 0)))
    stride = (spec.stride[1], spec.stride[0])
    pool = None if pool_spec is None \
        else (pool_spec.kernel[1], pool_spec.kernel[0])
    bci, bco = _tuned(xp, p["w"], stride, relu, pool)
    return conv2d_fused(xp, p["w"], p["b"], stride=stride, relu=relu,
                        pool=pool, block_ci=bci, block_co=bco,
                        interpret=default_interpret())


register_backend("xla", _conv_xla)
register_backend("pallas", _conv_pallas, fused=_conv_pallas_fused)


# ---------------------------------------------------------------------------
# layer application (backend-dispatching successor of builder._apply)
# ---------------------------------------------------------------------------

def apply_conv(spec: LayerSpec, p, x: jax.Array, relu: bool,
               pad_w: tuple[int, int] = (0, 0),
               backend: str | None = None,
               pool_spec: LayerSpec | None = None) -> jax.Array:
    """Apply one conv epilogue chain (conv + bias + relu + optional
    non-overlapping max-pool) to an NHWC tile.

    Backends with a fused lowering execute the whole chain as one
    kernel call; others compose the exact eager sequence, so a backend
    without fusion stays bit-identical to the oracle.  ``pool_spec``
    must describe a VALID kernel==stride pool (the only shape
    ``fusable_chains`` emits).
    """
    name = backend or DEFAULT_BACKEND
    fused = _FUSED.get(name)
    if fused is not None:
        return fused(spec, pool_spec, p, x, pad_w, relu)
    y = get_backend(name)(spec, p, x, pad_w) + p["b"]
    if relu:
        y = jax.nn.relu(y)
    if pool_spec is not None:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max,
            window_dimensions=(1, pool_spec.kernel[1], pool_spec.kernel[0], 1),
            window_strides=(1, pool_spec.stride[1], pool_spec.stride[0], 1),
            padding="VALID",
        )
    return y


def apply_layer(spec: LayerSpec, p, x: jax.Array, relu: bool,
                pad_w: tuple[int, int] = (0, 0),
                backend: str | None = None) -> jax.Array:
    """Apply one layer to an NHWC tile.

    ``pad_w`` is the tile's share of the layer's zero padding along W
    (only boundary tiles get any); H is never tiled, so the full
    (p_h, p_h) padding always applies.  ``backend`` selects the conv
    lowering; every other kind is plain XLA.
    """
    ph = spec.padding[1]
    if spec.kind == "conv":
        return apply_conv(spec, p, x, relu, pad_w, backend)
    if spec.kind == "pool":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            window_dimensions=(1, spec.kernel[1], spec.kernel[0], 1),
            window_strides=(1, spec.stride[1], spec.stride[0], 1),
            padding=((0, 0), (ph, ph), pad_w, (0, 0)),
        )
    if spec.kind == "gpool":
        return jnp.mean(x, axis=(1, 2), keepdims=True)
    if spec.kind == "fc":
        flat = x.reshape(x.shape[0], -1)
        y = flat @ p["w"] + p["b"]
        return y.reshape(x.shape[0], 1, 1, -1)  # stay NHWC for uniformity
    if spec.kind in ("identity", "input", "output"):
        return x
    raise NotImplementedError(spec.kind)
