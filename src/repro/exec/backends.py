"""Pluggable per-layer execution backends.

A backend is how a *conv* vertex is lowered — everything else (pool,
fc, connectors) is backend-independent XLA.  Backends are registered in
a process-wide table but *selected* explicitly: :class:`CNNDef` carries
a ``backend`` field and the stage executors thread it through, so there
is no mutable module global deciding the numerics of an already-built
model (the seed's ``_CONV_BACKEND`` failure mode).

Registered backends:

``xla``
    ``lax.conv_general_dilated`` — the reference path on every platform.
``pallas``
    The repro's implicit-GEMM Pallas kernel (``kernels.conv2d``).
    ``interpret`` is auto-detected from the JAX platform: on TPU the
    kernel actually compiles; elsewhere it runs in interpret mode
    (slow but bit-faithful).  Strided or kernel-unsupported shapes
    route through :func:`kernels.conv2d.ops.conv2d`'s reference
    fallback, which warns once per offending shape.
"""

from __future__ import annotations

from typing import Callable, Protocol

import jax
import jax.numpy as jnp

from ..core.graph import LayerSpec

# conv backend signature: (spec, params, x, pad_w) -> y  (NHWC, VALID +
# explicit pad_w/ph padding, no bias, no activation)
ConvFn = Callable[[LayerSpec, dict, jax.Array, tuple[int, int]], jax.Array]

_REGISTRY: dict[str, ConvFn] = {}
DEFAULT_BACKEND = "xla"


def register_backend(name: str, fn: ConvFn) -> None:
    _REGISTRY[name] = fn


def available_backends() -> list[str]:
    return sorted(_REGISTRY)


def get_backend(name: str | None) -> ConvFn:
    name = name or DEFAULT_BACKEND
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown exec backend {name!r}; "
                         f"registered: {available_backends()}") from None


def default_interpret() -> bool:
    """Pallas interpret mode: only compile for real on TPU."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# built-in backends
# ---------------------------------------------------------------------------

def _conv_xla(spec: LayerSpec, p: dict, x: jax.Array,
              pad_w: tuple[int, int]) -> jax.Array:
    ph = spec.padding[1]
    return jax.lax.conv_general_dilated(
        x, p["w"],
        window_strides=(spec.stride[1], spec.stride[0]),
        padding=((ph, ph), pad_w),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _conv_pallas(spec: LayerSpec, p: dict, x: jax.Array,
                 pad_w: tuple[int, int]) -> jax.Array:
    from ..kernels.conv2d.ops import conv2d as conv2d_kernel
    ph = spec.padding[1]
    xp = jnp.pad(x, ((0, 0), (ph, ph), pad_w, (0, 0)))
    return conv2d_kernel(xp, p["w"], stride=(spec.stride[1], spec.stride[0]),
                         interpret=default_interpret())


register_backend("xla", _conv_xla)
register_backend("pallas", _conv_pallas)


# ---------------------------------------------------------------------------
# layer application (backend-dispatching successor of builder._apply)
# ---------------------------------------------------------------------------

def apply_layer(spec: LayerSpec, p, x: jax.Array, relu: bool,
                pad_w: tuple[int, int] = (0, 0),
                backend: str | None = None) -> jax.Array:
    """Apply one layer to an NHWC tile.

    ``pad_w`` is the tile's share of the layer's zero padding along W
    (only boundary tiles get any); H is never tiled, so the full
    (p_h, p_h) padding always applies.  ``backend`` selects the conv
    lowering; every other kind is plain XLA.
    """
    ph = spec.padding[1]
    if spec.kind == "conv":
        y = get_backend(backend)(spec, p, x, pad_w) + p["b"]
        return jax.nn.relu(y) if relu else y
    if spec.kind == "pool":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            window_dimensions=(1, spec.kernel[1], spec.kernel[0], 1),
            window_strides=(1, spec.stride[1], spec.stride[0], 1),
            padding=((0, 0), (ph, ph), pad_w, (0, 0)),
        )
    if spec.kind == "gpool":
        return jnp.mean(x, axis=(1, 2), keepdims=True)
    if spec.kind == "fc":
        flat = x.reshape(x.shape[0], -1)
        y = flat @ p["w"] + p["b"]
        return y.reshape(x.shape[0], 1, 1, -1)  # stay NHWC for uniformity
    if spec.kind in ("identity", "input", "output"):
        return x
    raise NotImplementedError(spec.kind)
