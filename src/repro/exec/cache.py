"""Executable cache for compiled stage segments.

Keyed on (segment signature, tile shapes, boundary dtypes, backend) —
NOT on model object identity — so a re-plan that reproduces the same
stage structure, or a rebuilt but identical model, reuses the existing
jitted executable instead of re-tracing.  Bounded LRU: past ``maxsize``
the least-recently-used entry is dropped.

Observability: every probe emits a ``cache.lookup`` instant (and every
miss a ``compile`` span with its build wall-time) into the active
tracer (:func:`repro.obs.trace.current`), and the hit/miss/eviction
counters are published into the process-default metrics registry by a
registered collector — hot paths only bump plain ints.
"""

from __future__ import annotations

import time as _time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Mapping, Sequence

from .compiler import CompiledStage, segment_signature
from ..obs import trace as obs_trace
from ..obs.metrics import default_registry
from ..pipeline.halo import tile_signature


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def entries(self) -> int:
        return len(_CACHE)

    def snapshot(self) -> "CacheStats":
        """Frozen copy, for windowed accounting (``since``)."""
        return CacheStats(self.hits, self.misses, self.evictions)

    def since(self, mark: "CacheStats") -> "CacheStats":
        """Counter deltas accumulated after ``mark`` — how many stage
        compilations a serve / re-plan actually paid vs reused."""
        return CacheStats(self.hits - mark.hits, self.misses - mark.misses,
                          self.evictions - mark.evictions)


_CACHE: "OrderedDict[tuple, CompiledStage]" = OrderedDict()
_STATS = CacheStats()
_MAXSIZE = 256


def _publish_stats(reg) -> None:
    """Collector: mirror the cache counters into a metrics registry at
    snapshot time (the hot path only bumps the plain ints above)."""
    reg.gauge("exec.cache.hits").set(_STATS.hits)
    reg.gauge("exec.cache.misses").set(_STATS.misses)
    reg.gauge("exec.cache.evictions").set(_STATS.evictions)
    reg.gauge("exec.cache.entries").set(len(_CACHE))


default_registry().register_collector(_publish_stats)


def cache_stats() -> CacheStats:
    return _STATS


def clear_cache() -> None:
    _CACHE.clear()
    _STATS.hits = _STATS.misses = _STATS.evictions = 0


def set_cache_size(n: int) -> int:
    """Bound the executable cache; returns the previous bound so a
    scoped caller (tests, benchmarks) can restore it afterwards.  The
    cache is process-global, so the bound is last-write-wins across
    deployments."""
    global _MAXSIZE
    prev = _MAXSIZE
    _MAXSIZE = max(1, int(n))
    while len(_CACHE) > _MAXSIZE:
        _CACHE.popitem(last=False)
        _STATS.evictions += 1
    return prev


def static_stage_key(model, nodes, plans, needs) -> tuple:
    """The per-call-invariant part of a stage's cache key.  Callers on a
    hot path (StageExecutor) compute this once and pass it back via
    ``static_key=`` so the signature sort is not re-done per frame."""
    return (segment_signature(model.graph, nodes, model.input_size),
            tile_signature(plans), tuple(needs))


def stage_cache_key(model, nodes, plans, needs, *, backend, relu, donate,
                    boundary: Mapping, static_key: tuple | None = None,
                    fuse: bool = True) -> tuple:
    shapes = tuple((k, tuple(boundary[k].shape), str(boundary[k].dtype))
                   for k in needs)
    if static_key is None:
        static_key = static_stage_key(model, nodes, plans, needs)
    return (*static_key, backend, relu, bool(donate), bool(fuse), shapes)


def compiled_stage(model, nodes, plans, needs: Sequence, sinks: Sequence,
                   *, backend: str | None, relu: bool, donate: bool,
                   boundary: Mapping, static_key: tuple | None = None,
                   fuse: bool = True) -> CompiledStage:
    """Fetch-or-build the executable for one stage + boundary shapes."""
    key = stage_cache_key(model, nodes, plans, needs, backend=backend,
                          relu=relu, donate=donate, boundary=boundary,
                          static_key=static_key, fuse=fuse)
    hit = _CACHE.get(key)
    tr = obs_trace.current()
    if hit is not None:
        _STATS.hits += 1
        _CACHE.move_to_end(key)
        if tr:
            tr.instant("cache.lookup", _time.perf_counter() - tr.epoch,
                       hit=True)
        return hit
    _STATS.misses += 1
    if tr:
        tr.instant("cache.lookup", _time.perf_counter() - tr.epoch,
                   hit=False)
    t0 = _time.perf_counter()
    cs = CompiledStage(model, nodes, plans, needs, sinks, backend=backend,
                       relu=relu, donate=donate, fuse=fuse)
    build_s = _time.perf_counter() - t0
    default_registry().histogram("exec.compile.build_s").observe(build_s)
    if tr:
        tr.emit("compile", t0 - tr.epoch, build_s,
                n_nodes=len(nodes), backend=backend or "default")
    _CACHE[key] = cs
    while len(_CACHE) > _MAXSIZE:
        _CACHE.popitem(last=False)
        _STATS.evictions += 1
    return cs
