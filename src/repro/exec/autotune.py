"""Channel-block autotuning for the Pallas conv kernel.

The implicit-GEMM kernel (``kernels.conv2d``) takes two tunable block
sizes — ``block_ci``/``block_co``, the in/out-channel tiles fed to the
MXU.  The default heuristic (128, or the axis rounded up to a power of
two) is safe everywhere but not best everywhere; this module searches
the candidate space per conv shape, records each trial as a
compile-adjacent ``autotune`` span + ``exec.autotune.*`` metrics, and
persists winners into the :class:`~repro.core.cost.CostTable` artifact
(``kernels`` field) so calibration ratios and kernel tunings share one
versioned store, survive ``Deployment.save()/load()``, and feed the
planner costs measured on the *tuned* kernels.

Keys (:func:`shape_key`) are deliberately spatial-size-agnostic —
``conv:<backend>:c{ci}x{co}:k..:s..:r..:p..`` — because the pipeline
runs the same conv on many tile widths; channel blocking is a
channel-geometry decision, so one winner covers every tile of a layer.

Winners are *installed* process-wide (:func:`install`); the pallas
backend lowering consults :func:`tuned_blocks` on every conv call and
silently uses the kernel default when no entry matches.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence

import jax
import jax.numpy as jnp

from ..core.cost import CostTable
from ..obs import trace as obs_trace
from ..obs.metrics import default_registry

# (block_ci, block_co) candidates.  The kernel zero-pads channel tails
# up to the block, so every candidate is legal for every channel count;
# small blocks win on small layers (less padding waste), 128s on big
# ones (MXU-aligned).
DEFAULT_CANDIDATES: tuple[tuple[int, int], ...] = (
    (128, 128), (128, 64), (64, 128), (64, 64), (32, 32), (16, 16), (8, 8))


def shape_key(x_shape, w_shape, stride, relu=False, pool=None,
              backend: str = "pallas") -> str:
    """Stable CostTable key for one conv-epilogue configuration.

    Spatial dims are excluded on purpose (see module docstring); the
    key captures channels, filter, stride, epilogue, and backend.
    """
    ci = x_shape[-1]
    kh, kw, _, co = w_shape
    sh, sw = stride
    p = "-" if pool is None else f"{pool[0]}x{pool[1]}"
    return (f"conv:{backend}:c{ci}x{co}:k{kh}x{kw}:s{sh}x{sw}"
            f":r{int(bool(relu))}:p{p}")


# ---------------------------------------------------------------------------
# installed winners (process-wide, consulted by exec.backends)
# ---------------------------------------------------------------------------

_TUNED: dict[str, dict] = {}


def install(kernels: Mapping[str, Mapping]) -> None:
    """Merge CostTable ``kernels`` entries into the process-wide tuned
    registry (last write wins per key).  ``Deployment`` calls this on
    construction/load, so a saved artifact re-arms the fast path."""
    for k, e in kernels.items():
        _TUNED[k] = dict(e)


def installed() -> dict[str, dict]:
    """Copy of the currently installed tuned entries."""
    return {k: dict(e) for k, e in _TUNED.items()}


def clear_installed() -> None:
    _TUNED.clear()


def tuned_blocks(x_shape, w_shape, stride, relu=False, pool=None, *,
                 backend: str = "pallas") -> tuple[int | None, int | None]:
    """(block_ci, block_co) for this conv call, or (None, None) when no
    tuned entry is installed (the kernel default applies)."""
    e = _TUNED.get(shape_key(x_shape, w_shape, stride, relu, pool, backend))
    if e is None:
        return (None, None)
    return (int(e["block_ci"]), int(e["block_co"]))


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

@dataclass
class TuneResult:
    key: str
    block_ci: int
    block_co: int
    best_us: float
    trials: list[tuple[int, int, float]] = field(default_factory=list)

    def entry(self, backend: str = "pallas") -> dict:
        """The CostTable ``kernels`` entry for this winner."""
        return {"block_ci": self.block_ci, "block_co": self.block_co,
                "best_us": self.best_us, "backend": backend}


def _time_call(fn, *args, iters: int) -> float:
    fn(*args).block_until_ready()  # compile outside the timed region
    t0 = _time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (_time.perf_counter() - t0) / iters


def autotune_conv(x_shape: Sequence[int], w_shape: Sequence[int], *,
                  stride=(1, 1), relu: bool = False,
                  pool: tuple[int, int] | None = None, bias: bool = True,
                  backend: str = "pallas",
                  candidates: Iterable[tuple[int, int]] = DEFAULT_CANDIDATES,
                  iters: int = 3, interpret: bool | None = None,
                  key: jax.Array | None = None) -> TuneResult:
    """Search ``candidates`` for the fastest (block_ci, block_co) on one
    conv-epilogue shape; emits an ``autotune`` span per shape and an
    ``exec.autotune.trial_s`` histogram sample per candidate."""
    from ..kernels.conv2d.ops import conv2d_fused
    from .backends import default_interpret
    if interpret is None:
        interpret = default_interpret()
    key = jax.random.PRNGKey(0) if key is None else key
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, tuple(x_shape), jnp.float32)
    w = jax.random.normal(k2, tuple(w_shape), jnp.float32) * 0.1
    b = jax.random.normal(k3, (w_shape[-1],), jnp.float32) if bias else None
    stride = tuple(int(s) for s in stride)
    skey = shape_key(x_shape, w_shape, stride, relu, pool, backend)
    reg = default_registry()
    tr = obs_trace.current()
    trials: list[tuple[int, int, float]] = []
    with tr.wall_span("autotune", key=skey) if tr else _null():
        for bci, bco in candidates:
            dt = _time_call(
                lambda xx, ww: conv2d_fused(
                    xx, ww, b, stride=stride, relu=relu, pool=pool,
                    block_ci=bci, block_co=bco, interpret=interpret),
                x, w, iters=iters)
            trials.append((bci, bco, dt))
            reg.histogram("exec.autotune.trial_s").observe(dt)
    bci, bco, best = min(trials, key=lambda t: t[2])
    reg.counter("exec.autotune.tuned", backend=backend).inc()
    return TuneResult(skey, bci, bco, best * 1e6, trials)


def _null():
    from contextlib import nullcontext
    return nullcontext()


def conv_shapes(model) -> list[dict]:
    """Distinct conv-epilogue invocation shapes of a model, fused the
    way the compiler will fuse them (conv->pool chains collapse into
    one shape with ``pool`` set).  Spatial dims come from the model's
    full (untiled) geometry — representative, and irrelevant to the
    spatial-size-agnostic key."""
    from .compiler import fusable_chains
    g = model.graph
    fusion = fusable_chains(g, frozenset(g.layers))
    shapes: dict[str, dict] = {}
    for n, spec in g.layers.items():
        if spec.kind != "conv":
            continue
        ps = g.preds[n]
        w_in, h_in = (model.full_sizes[ps[0]] if ps else model.input_size)
        pw, ph = spec.padding
        x_shape = (1, h_in + 2 * ph, w_in + 2 * pw, spec.in_channels)
        w_shape = (spec.kernel[1], spec.kernel[0], spec.in_channels,
                   spec.out_channels)
        stride = (spec.stride[1], spec.stride[0])
        pool = None
        if n in fusion:
            pspec = g.layers[fusion[n]]
            pool = (pspec.kernel[1], pspec.kernel[0])
        d = dict(x_shape=x_shape, w_shape=w_shape, stride=stride,
                 relu=True, pool=pool)
        shapes.setdefault(shape_key(x_shape, w_shape, stride, True, pool), d)
    return list(shapes.values())


def autotune_model(model, *, backend: str = "pallas",
                   table: CostTable | None = None,
                   candidates: Iterable[tuple[int, int]] = DEFAULT_CANDIDATES,
                   iters: int = 3, install_winners: bool = True,
                   key: jax.Array | None = None
                   ) -> tuple[CostTable, list[TuneResult]]:
    """Tune every distinct conv shape of ``model`` not already present
    in ``table.kernels`` (a loaded artifact re-tunes nothing), merge the
    winners into the table, and (by default) install them process-wide.

    Returns ``(table, results)`` where ``results`` holds only the
    shapes actually tuned this call."""
    table = table if table is not None else CostTable()
    results: list[TuneResult] = []
    for d in conv_shapes(model):
        skey = shape_key(d["x_shape"], d["w_shape"], d["stride"],
                         d["relu"], d["pool"], backend)
        if skey in table.kernels:
            continue
        res = autotune_conv(d["x_shape"], d["w_shape"], stride=d["stride"],
                            relu=d["relu"], pool=d["pool"], backend=backend,
                            candidates=candidates, iters=iters, key=key)
        table.kernels[skey] = res.entry(backend)
        results.append(res)
    if install_winners and table.kernels:
        install(table.kernels)
    return table, results
