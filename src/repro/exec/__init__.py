"""Compiled per-stage execution backend with calibrated costs.

The execution layer between the planner's :class:`StagePlan` and JAX:

* :mod:`~repro.exec.backends` — pluggable conv backends (``xla``,
  ``pallas``) selected per model/executor, no mutable module global;
  backends may register a *fused* conv-epilogue lowering;
* :mod:`~repro.exec.compiler` — lowers one stage's fused segment (all
  device tiles) into a single jitted callable, pattern-matching
  conv->pool chains into single fused kernel calls, with optional
  buffer donation and ``lax.scan`` micro-batching over frames;
* :mod:`~repro.exec.cache` — executable cache keyed on (segment
  signature, tile shapes, dtype, backend, fuse);
* :mod:`~repro.exec.calibrate` — times compiled stages and feeds a
  measured :class:`~repro.core.cost.CostTable` back into the planner;
* :mod:`~repro.exec.autotune` — searches the Pallas kernel's channel
  block sizes per conv shape and persists winners into the same
  CostTable artifact.
"""

from .backends import (apply_conv, apply_layer, available_backends,
                       default_interpret, get_backend, has_fused,
                       register_backend)
from .compiler import (CompiledStage, compile_stage, fusable_chains,
                       segment_signature)
from .cache import (CacheStats, cache_stats, clear_cache, compiled_stage,
                    set_cache_size, stage_cache_key, static_stage_key)
from .calibrate import (CalibrationReport, StageCalibration, calibrate_plan,
                        calibrated_plan, measure_host_flops)
from .autotune import (DEFAULT_CANDIDATES, TuneResult, autotune_conv,
                       autotune_model, clear_installed, install, installed,
                       shape_key, tuned_blocks)

__all__ = [
    "apply_conv", "apply_layer", "available_backends", "default_interpret",
    "get_backend", "has_fused", "register_backend", "CompiledStage",
    "compile_stage", "fusable_chains", "segment_signature", "CacheStats",
    "cache_stats", "clear_cache", "compiled_stage", "set_cache_size",
    "stage_cache_key", "static_stage_key",
    "CalibrationReport", "StageCalibration", "calibrate_plan",
    "calibrated_plan", "measure_host_flops",
    "DEFAULT_CANDIDATES", "TuneResult", "autotune_conv", "autotune_model",
    "clear_installed", "install", "installed", "shape_key", "tuned_blocks",
]
