"""Compiled per-stage execution backend with calibrated costs.

The execution layer between the planner's :class:`StagePlan` and JAX:

* :mod:`~repro.exec.backends` — pluggable conv backends (``xla``,
  ``pallas``) selected per model/executor, no mutable module global;
* :mod:`~repro.exec.compiler` — lowers one stage's fused segment (all
  device tiles) into a single jitted callable, with optional buffer
  donation and ``lax.scan`` micro-batching over frames;
* :mod:`~repro.exec.cache` — executable cache keyed on (segment
  signature, tile shapes, dtype, backend);
* :mod:`~repro.exec.calibrate` — times compiled stages and feeds a
  measured :class:`~repro.core.cost.CostTable` back into the planner.
"""

from .backends import (apply_layer, available_backends, default_interpret,
                       get_backend, register_backend)
from .compiler import CompiledStage, compile_stage, segment_signature
from .cache import (CacheStats, cache_stats, clear_cache, compiled_stage,
                    set_cache_size, stage_cache_key, static_stage_key)
from .calibrate import (CalibrationReport, StageCalibration, calibrate_plan,
                        calibrated_plan, measure_host_flops)

__all__ = [
    "apply_layer", "available_backends", "default_interpret", "get_backend",
    "register_backend", "CompiledStage", "compile_stage",
    "segment_signature", "CacheStats", "cache_stats", "clear_cache",
    "compiled_stage", "set_cache_size", "stage_cache_key",
    "static_stage_key",
    "CalibrationReport", "StageCalibration", "calibrate_plan",
    "calibrated_plan", "measure_host_flops",
]
