"""Segment compiler: one StagePlan's fused segment -> one jitted callable.

The seed executed a stage as an eager Python loop — re-interpreting the
segment DAG per tile, per frame, with one XLA dispatch per layer.  This
module lowers the *whole* stage — split, every device tile's sub-DAG,
stitch — into a single ``jax.jit`` callable, so the planner's per-stage
cost has an executable counterpart that can actually be measured
(see :mod:`repro.exec.calibrate`).

Two entry points per :class:`CompiledStage`:

* ``__call__(params, boundary)`` — one frame;
* ``run_frames(params, boundary)`` — a stack of frames with a leading
  frame axis, micro-batched through ``lax.scan`` so the whole stream is
  one dispatch with constant memory in the number of frames.

Buffer donation (``donate=True``) hands the boundary buffers to XLA for
in-place reuse — safe only when the caller will not read them again
(the scan/benchmark paths own their inputs; the multi-stage runner
shares ``produced`` tensors across stages, so it keeps donation off).
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax

from ..pipeline.halo import (TilePlan, plan_tiles, split_inputs,
                             stitch_outputs)
from .backends import DEFAULT_BACKEND, has_fused


def fusable_chains(graph, nodes) -> dict[str, str]:
    """conv -> pool pairs in ``nodes`` lowerable as one fused kernel.

    A pool is fusable into its producing conv when the chain is private
    and the pool collapses onto the conv's output grid:

    * the pool is VALID (no padding) and non-overlapping
      (kernel == stride — e.g. the zoo's 2x2/s2 pools), which is the
      shape the kernel epilogue implements as an in-register reshape;
    * its only predecessor is an in-segment conv;
    * that conv feeds nothing else — no other in-segment successor and
      not a segment sink — so skipping its materialization is safe.

    Together with ``Graph.required_ranges``'s width-range arithmetic
    these conditions also pin the tile geometry: the conv tile is
    exactly the pool's input and starts on the pool grid, which
    ``run_segment`` re-checks per tile before fusing.
    """
    nodes = frozenset(nodes)
    sinks = set(graph.sinks(nodes))
    chains: dict[str, str] = {}
    for n in nodes:
        spec = graph.layers[n]
        if spec.kind != "pool":
            continue
        if (tuple(spec.kernel) != tuple(spec.stride)
                or tuple(spec.padding) != (0, 0)):
            continue
        ps = graph.preds[n]
        if len(ps) != 1 or ps[0] not in nodes:
            continue
        conv = ps[0]
        if graph.layers[conv].kind != "conv" or conv in sinks:
            continue
        if [s for s in graph.succs[conv] if s in nodes] != [n]:
            continue
        chains[conv] = n
    return chains


def segment_signature(graph, nodes, input_size) -> tuple:
    """Hashable fingerprint of a fused segment's geometry + weights.

    Two models whose segments agree on this signature lower to the same
    executable, so cache entries survive re-plans and model rebuilds.
    """
    nodes = frozenset(nodes)
    layers = tuple(sorted(
        (n, s.kind, s.kernel, s.stride, s.padding, s.in_channels,
         s.out_channels, s.flops_coeff, s.global_rf)
        for n, s in ((n, graph.layers[n]) for n in nodes)))
    edges = tuple(sorted((u, v) for u, v in graph.edges
                         if u in nodes and v in nodes))
    return (layers, edges, tuple(input_size))


class CompiledStage:
    """All device tiles of one stage as a single jitted executable."""

    def __init__(self, model, nodes, plans: Sequence[TilePlan],
                 needs: Sequence[tuple[str, str | None]],
                 sinks: Sequence[str], *, backend: str | None = None,
                 relu: bool = True, donate: bool = False,
                 fuse: bool = True):
        self.model = model
        self.nodes = frozenset(nodes)
        self.plans = list(plans)
        self.needs = list(needs)
        self.sinks = list(sinks)
        self.backend = backend
        self.relu = relu
        # conv->pool chains lowered as one fused kernel call; only for
        # backends with a fused lowering (xla keeps the composed-op
        # sequence and with it bit-equality vs the eager oracle)
        self.fuse = bool(fuse)
        name = backend or getattr(model, "backend", None) or DEFAULT_BACKEND
        self.fusion = fusable_chains(model.graph, self.nodes) \
            if self.fuse and has_fused(name) else {}
        # XLA on CPU cannot alias donated buffers; donation there only
        # produces warnings, so honor the flag on accelerators only
        self.donate = bool(donate) and jax.default_backend() != "cpu"
        dn = tuple(range(1, 1 + len(self.needs))) if self.donate else ()
        self._fn = jax.jit(self._run, donate_argnums=dn)
        self._scan_fn = jax.jit(self._run_frames, donate_argnums=dn)

    # traced bodies ------------------------------------------------------

    def _run(self, params, *bufs):
        boundary = dict(zip(self.needs, bufs))
        tiles_in = split_inputs(self.plans, self.needs, boundary)
        tiles_out = []
        for tp, tin in zip(self.plans, tiles_in):
            if tp.empty:
                tiles_out.append({})
                continue
            tiles_out.append(self.model.run_segment(
                params, self.nodes, tin,
                ranges=(tp.out_ranges, tp.in_ranges),
                relu=self.relu, backend=self.backend,
                fusion=self.fusion))
        return stitch_outputs(self.plans, self.sinks, tiles_out)

    def _run_frames(self, params, *bufs):
        def body(carry, xs):
            return carry, self._run(params, *xs)
        _, outs = jax.lax.scan(body, None, bufs)
        return outs

    # public -------------------------------------------------------------

    def __call__(self, params, boundary: Mapping) -> dict[str, jax.Array]:
        return self._fn(params, *(boundary[k] for k in self.needs))

    def run_frames(self, params, boundary: Mapping) -> dict[str, jax.Array]:
        """``boundary`` tensors carry a leading frame axis (F, N, H, W, C);
        returns sink tensors stacked the same way."""
        return self._scan_fn(params, *(boundary[k] for k in self.needs))

def compile_stage(model, nodes, fractions: Sequence[float], *,
                  backend: str | None = None, relu: bool = True,
                  donate: bool = False, fuse: bool = True,
                  spec=None) -> CompiledStage:
    """Convenience: plan tiles for ``fractions`` and compile the stage.
    ``spec`` (:class:`~repro.api.specs.ExecSpec`) supersedes the
    individual ``backend``/``donate``/``fuse`` knobs when given."""
    if spec is not None:
        backend, donate, fuse = spec.backend, spec.donate, spec.fuse
    nodes = frozenset(nodes)
    g = model.graph
    plans = plan_tiles(g, nodes, model.full_sizes, model.input_size,
                       list(fractions))
    return CompiledStage(model, nodes, plans, model.boundary_needs(nodes),
                         g.sinks(nodes), backend=backend, relu=relu,
                         donate=donate, fuse=fuse)
