"""Cost calibration: time compiled stage executables, emit a CostTable.

The planner's analytic model prices a segment as FLOPs/capacity times a
per-device regression coefficient alpha (Eq. 7).  That coefficient was
never measured against anything the system actually executes — the seed
timed nothing.  This module runs each stage of a plan through its
*compiled* executable (:mod:`repro.exec.compiler`), measures wall time,
and expresses the result as a per-segment ratio

    ratio(seg) = measured_seconds / (executed_FLOPs / host_FLOPs)

i.e. how much slower (or faster, via fusion) the segment runs than the
pure roofline estimate on the calibration host.  The resulting
:class:`~repro.core.cost.CostTable` plugs into ``core.cost.stage_cost``
and the planner's ``plan``/``replan``/``recost``, replacing the purely
analytic alpha with measured numbers — the DistrEdge/DynO lesson that
partition quality hinges on measured per-stage costs.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.cost import CostTable
from ..obs import trace as obs_trace
from ..obs.metrics import default_registry


def measure_host_flops(n: int = 512, iters: int = 5) -> float:
    """Estimate the host's achievable matmul FLOP/s with a jitted GEMM."""
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda x, y: x @ y)
    f(a, a).block_until_ready()          # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        f(a, a).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return 2.0 * n ** 3 / best


@dataclass
class StageCalibration:
    index: int
    nodes: frozenset[str]
    flops: float
    measured_s: float
    analytic_s: float

    @property
    def ratio(self) -> float:
        return self.measured_s / self.analytic_s if self.analytic_s > 0 else 1.0


@dataclass
class CalibrationReport:
    host_flops: float
    stages: list[StageCalibration] = field(default_factory=list)

    def table(self) -> CostTable:
        ratios = {s.nodes: s.ratio for s in self.stages if s.analytic_s > 0}
        mean = (sum(ratios.values()) / len(ratios)) if ratios else 1.0
        return CostTable(ratios, default=mean)


def calibrate_plan(model, params, stages: Sequence, *,
                   backend: str | None = None, image=None,
                   iters: int = 3, host_flops: float | None = None,
                   key: int = 0) -> CalibrationReport:
    """Time every stage of a plan through its compiled executable.

    ``stages`` is the ``PicoPlan.pipeline.stages`` list (each entry
    carries nodes, fractions and the analytic SegmentCost).  Boundary
    tensors are produced by actually running the pipeline in plan order,
    so each stage is timed on its real input shapes.  Returns a report
    whose :meth:`~CalibrationReport.table` feeds the planner.
    """
    from ..pipeline.stage import StageExecutor     # lazy: avoid cycle
    host_flops = host_flops or measure_host_flops()
    if image is None:
        w, h = model.input_size
        image = jax.random.normal(jax.random.PRNGKey(key),
                                  (1, h, w, model.in_channels))
    report = CalibrationReport(host_flops)
    produced: dict = {}
    for si, st in enumerate(stages):
        with obs_trace.current().wall_span("calibrate", stage=si,
                                           n_nodes=len(st.nodes)):
            ex = StageExecutor(model, st.nodes, list(st.fractions),
                               name=f"calib{si}", backend=backend)
            outs = ex(params, produced, image)          # compile + warm
            jax.block_until_ready(outs)
            best = float("inf")
            for _ in range(max(1, iters)):
                t0 = time.perf_counter()
                jax.block_until_ready(ex(params, produced, image))
                best = min(best, time.perf_counter() - t0)
        flops = float(sum(st.cost.seg.per_device_flops))
        default_registry().histogram("exec.calibrate.stage_s").observe(best)
        report.stages.append(StageCalibration(
            si, frozenset(st.nodes), flops, best, flops / host_flops))
        produced.update(outs)
    return report


def calibrated_plan(g, cluster, input_size, model, params, *,
                    backend: str | None = None, t_lim: float = float("inf"),
                    iters: int = 3, plan_spec=None):
    """Plan -> calibrate -> re-plan on measured costs (one closed loop).

    Returns ``(pico, report)`` where ``pico`` was re-planned with the
    measured :class:`CostTable` and ``report`` holds the raw timings.
    ``plan_spec`` (:class:`~repro.api.specs.PlanSpec`) supersedes the
    bare ``t_lim``.
    """
    from ..api.specs import PlanSpec
    from ..core.planner import plan_with_spec
    spec = plan_spec or PlanSpec(t_lim=t_lim)
    first = plan_with_spec(g, cluster, input_size, spec)
    report = calibrate_plan(model, params, first.pipeline.stages,
                            backend=backend, iters=iters)
    table = report.table()
    return plan_with_spec(g, cluster, input_size, spec,
                          partition=first.partition,
                          cost_table=table), report
