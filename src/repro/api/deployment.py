"""``repro.compile(model, cluster) -> Deployment`` — the public facade.

One object owns the whole plan → calibrate → execute lifecycle that the
paper splits into an offline optimizer and an online executor:

    dep = repro.compile(model, cluster, plan_spec, exec_spec)
    dep.run(frames)                  # bit-exact pipelined inference
    dep.runtime(deploy_spec)         # event-driven cluster runtime
    dep.server(streaming=True)       # serving front-end
    dep.scheduler(tenants=[...])     # multi-tenant co-hosting
    dep.save("plan.json")            # durable, versioned artifact
    dep2 = repro.Deployment.load("plan.json")   # no re-plan, no re-calib

``save``/``load`` round-trips are exact: the loaded deployment's
``simulate()`` report and per-frame outputs are bit-identical to the
original, and neither the planner nor the calibrator runs on load —
the offline plan ships to the fleet as data.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Mapping, Sequence

from ..core.cost import Cluster, CostTable
from ..core.planner import PicoPlan, plan_with_spec
from ..obs import trace as obs_trace
from ..obs.metrics import MetricsRegistry, default_registry
from ..obs.trace import Tracer
from . import artifacts
from .specs import DeploySpec, ExecSpec, PlanSpec


def compile(model, cluster: Cluster,
            plan_spec: PlanSpec | None = None,
            exec_spec: ExecSpec | None = None, *,
            cost_table: CostTable | None = None,
            params=None, key=None) -> "Deployment":
    """Plan (and optionally calibrate) ``model`` on ``cluster``.

    ``model`` is a graph carrier (:class:`~repro.models.cnn.builder.CNNDef`
    or anything with ``.graph``/``.input_size``).  With
    ``exec_spec.calibrate`` every stage of the initial plan is timed
    through its compiled executable and the plan is re-built on the
    measured :class:`CostTable` (piece chain reused).  ``params``/``key``
    seed the model weights for calibration and later ``run()`` calls;
    ``cost_table`` supplies a previously measured table up front.
    """
    plan_spec = plan_spec or PlanSpec()
    exec_spec = exec_spec or ExecSpec()
    if params is None and key is not None:
        params = _init_params(model, key)
    # the deployment's tracer captures its whole lifecycle: the offline
    # plan (and calibration) spans land here, and later traced runtime
    # runs append to the same timeline
    tracer = Tracer()
    with obs_trace.scoped(tracer):
        if exec_spec.autotune:
            # tune kernel blocks first so calibration (and with it the
            # planner's cost ratios) measures the tuned kernels; winners
            # merge into the same CostTable artifact as the ratios
            from ..exec.autotune import autotune_model
            cost_table, _ = autotune_model(
                model,
                backend=exec_spec.backend
                or getattr(model, "backend", None) or "pallas",
                table=cost_table, iters=exec_spec.autotune_iters)
        # one PlannerCache for the deployment's lifetime: the post-
        # calibration re-plan and any later .replan() hops reuse the
        # initial plan's segment geometry (incremental hot path)
        from ..core.pipeline_dp import PlannerCache
        cache = PlannerCache()
        pico = plan_with_spec(model.graph, cluster, model.input_size,
                              plan_spec, cost_table=cost_table,
                              planner_cache=cache)
        if exec_spec.calibrate:
            from ..exec.calibrate import calibrate_plan
            if params is None:
                params = _init_params(model, key)
            report = calibrate_plan(model, params, pico.pipeline.stages,
                                    backend=exec_spec.backend,
                                    iters=exec_spec.calibrate_iters)
            tuned = cost_table.kernels if cost_table is not None else {}
            cost_table = report.table()
            cost_table.kernels.update(tuned)  # ratios + tunings, one store
            pico = plan_with_spec(model.graph, cluster, model.input_size,
                                  plan_spec, partition=pico.partition,
                                  cost_table=cost_table,
                                  planner_cache=cache)
    dep = Deployment(model, cluster, plan_spec, exec_spec, pico,
                     cost_table=cost_table, params=params, tracer=tracer)
    dep._planner_cache = cache
    return dep


def _init_params(model, key=None):
    import jax
    return model.init(key if key is not None else jax.random.PRNGKey(0))


@dataclass
class Deployment:
    """A planned (and optionally calibrated) pipeline, ready to execute,
    serve, re-plan, or ship as a JSON artifact."""

    model: object
    cluster: Cluster
    plan_spec: PlanSpec
    exec_spec: ExecSpec
    pico: PicoPlan
    cost_table: CostTable | None = None
    params: object = field(default=None, repr=False, compare=False)
    _runner: object = field(default=None, repr=False, compare=False)
    #: span sink for the deployment lifecycle — plan/calibrate spans
    #: from :func:`compile`, plus every runtime run started with
    #: ``DeploySpec(trace=True)``.  Export with ``tracer.save(path)``.
    tracer: object = field(default=None, repr=False, compare=False)
    #: deployment-scoped metrics registry; runtime runs with
    #: ``DeploySpec(metrics=True)`` (the default) publish here.
    metrics: object = field(default=None, repr=False, compare=False)

    def __post_init__(self):
        # the executable-cache bound is process-global; a deployment
        # carrying one applies it the same way on compile and on load
        self.exec_spec.apply_cache_limit()
        # autotuned kernel winners ride in the cost table; install them
        # process-wide so a loaded artifact re-arms the fast path with
        # zero re-tuning (same compile/load symmetry as the cache bound)
        if self.cost_table is not None and \
                getattr(self.cost_table, "kernels", None):
            from ..exec.autotune import install
            install(self.cost_table.kernels)
        if self.tracer is None:
            self.tracer = Tracer()
        if self.metrics is None:
            self.metrics = MetricsRegistry()

    # ---------------- plan views ----------------

    @property
    def pipeline(self):
        return self.pico.pipeline

    @property
    def partition(self):
        return self.pico.partition

    @property
    def period(self) -> float:
        return self.pico.period

    @property
    def latency(self) -> float:
        return self.pico.latency

    @property
    def throughput(self) -> float:
        return self.pico.throughput

    def describe(self) -> str:
        """One-paragraph human summary (CLI/report helper)."""
        st = self.pico.pipeline.stages
        lines = [f"{getattr(self.model, 'name', 'model')}: "
                 f"{len(self.pico.partition.pieces)} pieces -> "
                 f"{len(st)} stages on {len(self.cluster)} devices; "
                 f"period {self.period * 1e3:.2f} ms "
                 f"({60.0 / self.period:.1f} frames/min), "
                 f"latency {self.latency * 1e3:.2f} ms"]
        for s in st:
            lines.append(
                f"  stage pieces {s.first_piece}-{s.last_piece} on "
                f"{[d.name for d in s.devices]}  "
                f"T={s.cost.total * 1e3:.2f} ms")
        if self.cost_table is not None:
            lines.append(f"  calibrated: {len(self.cost_table)} segment "
                         f"ratio(s)")
            if self.cost_table.kernels:
                lines.append(f"  autotuned: {len(self.cost_table.kernels)} "
                             f"kernel shape(s)")
        return "\n".join(lines)

    # ---------------- execution ----------------

    def load_params(self, key=None) -> "Deployment":
        """Initialize model weights (idempotent unless ``key`` given)."""
        if self.params is None or key is not None:
            self.params = _init_params(self.model, key)
            self._runner = None
        return self

    @property
    def runner(self):
        """Lazy :class:`~repro.pipeline.runner.PipelineRunner` over the
        plan's stages (compiled per ``exec_spec``)."""
        if self._runner is None:
            from ..pipeline.runner import PipelineRunner
            self._runner = PipelineRunner(self.model, self.pico.pipeline,
                                          exec_spec=self.exec_spec)
        return self._runner

    def run(self, frames, params=None):
        """Execute frame(s) through the pipelined stages (bit-exact with
        the monolithic forward).  A single array returns one sink dict;
        a sequence returns a list of sink dicts.  Multi-frame sequences
        go through the compiled ``lax.scan`` ``run_frames`` path (one
        dispatch per stage) unless ``exec_spec.scan_batch`` is off."""
        if params is None:
            params = self.load_params().params
        if hasattr(frames, "ndim"):
            return self.runner(params, frames)
        frames = list(frames)
        if self.exec_spec.scan_batch and len(frames) > 1:
            import jax.numpy as jnp
            outs = self.runner.run_frames(params, jnp.stack(frames))
            return [{k: v[i] for k, v in outs.items()}
                    for i in range(len(frames))]
        return [self.runner(params, x) for x in frames]

    def simulate(self, frames: int = 64):
        """Closed-form steady-state report for the plan (Table 5
        quantities)."""
        from ..core.simulate import simulate
        return simulate(self.pico.pipeline, frames, cluster=self.cluster)

    # ---------------- observability ----------------

    def metrics_snapshot(self, meta: Mapping | None = None) -> dict:
        """Versioned metrics-snapshot document for this deployment.

        Merges the deployment-scoped registry (runtime frame/monitor
        series from runs with ``DeploySpec(metrics=True)``) with the
        process-default registry (executable-cache hits/misses/
        evictions, per-segment compile wall-times, ``conv.fallback``
        counts) into one
        :meth:`~repro.obs.metrics.MetricsRegistry.snapshot` envelope —
        see :func:`repro.obs.metrics.open_snapshot`/``flatten`` for the
        reader side.
        """
        reg = MetricsRegistry()
        reg.merge(self.metrics)
        reg.merge(default_registry())
        base = {"model": getattr(self.model, "name", "model"),
                "devices": len(self.cluster),
                "stages": len(self.pico.pipeline.stages)}
        base.update(meta or {})
        return reg.snapshot(meta=base)

    def save_trace(self, path: str | os.PathLike) -> str:
        """Write the lifecycle trace as Perfetto-loadable Chrome-trace
        JSON (one process row per device); returns the path."""
        return self.tracer.save(path)

    # ---------------- online forms ----------------

    def runtime(self, deploy_spec: DeploySpec | None = None, *,
                churn: Sequence = (), real_compute: bool | None = None):
        """Event-driven cluster runtime over this plan (no re-planning).

        ``real_compute`` defaults to "yes iff params are loaded"; pass
        ``False`` for a timing-only run on a deployment that has
        weights."""
        from ..runtime.executor import PipelineRuntime
        spec = deploy_spec or DeploySpec()
        real = (self.params is not None if real_compute is None
                else real_compute)
        if real and self.params is None:
            self.load_params()
        kw = dict(cluster=self.cluster, pico=self.pico,
                  config=spec.to_runtime_config(), churn=churn,
                  plan_spec=self.plan_spec, exec_spec=self.exec_spec,
                  cost_table=self.cost_table)
        if spec.trace:
            kw["tracer"] = self.tracer       # append to the lifecycle trace
        if spec.metrics:
            kw["metrics"] = self.metrics     # publish into this deployment
        if real:
            return PipelineRuntime(model=self.model, params=self.params,
                                   **kw)
        return PipelineRuntime(g=self.model.graph,
                               input_size=self.model.input_size, **kw)

    def server(self, deploy_spec: DeploySpec | None = None, *,
               streaming: bool = False, churn: Sequence = ()):
        """Serving front-end over this plan: the closed-form
        :class:`~repro.serving.server.PipelineServer`, or (with
        ``streaming=True``) the runtime-backed streaming server."""
        from ..serving.server import PipelineServer, StreamingPipelineServer
        if streaming:
            spec = deploy_spec or DeploySpec()
            srv = StreamingPipelineServer(
                self.model, self.cluster, deploy_spec=spec, churn=churn,
                plan_spec=self.plan_spec, exec_spec=self.exec_spec,
                cost_table=self.cost_table, pico=self.pico)
        else:
            if deploy_spec is not None:
                raise TypeError("deploy_spec applies to the runtime-backed "
                                "server; pass streaming=True (the "
                                "closed-form PipelineServer has no deploy "
                                "knobs)")
            if churn:
                raise TypeError("churn applies to the runtime-backed "
                                "server; pass streaming=True")
            srv = PipelineServer(
                self.model, self.cluster, plan_spec=self.plan_spec,
                exec_spec=self.exec_spec, cost_table=self.cost_table,
                pico=self.pico)
        if self.params is not None:
            srv.params = self.params
        return srv

    def fleet(self, dist_spec=None, **kw):
        """Real distributed execution of this deployment
        (:class:`~repro.dist.launcher.DistLauncher`): one worker per
        pipeline stage — persistent threads or spawned processes per
        :class:`~repro.api.specs.DistSpec` — each rebuilt from this
        deployment's versioned JSON artifact (the artifact round-trip
        is the hand-off).  ``launcher.run(frames)`` executes and
        drains; ``repro.dist.validate(dep)`` pins the outputs
        bit-identical to :meth:`run`.

        Workers re-initialize weights deterministically from
        ``DistSpec.seed`` (the artifact deliberately ships no weights),
        so results match :meth:`run` under the same default params."""
        from ..dist.launcher import DistLauncher
        return DistLauncher(self, dist_spec, **kw)

    def scheduler(self, tenants: Sequence, config=None):
        """Multi-tenant scheduler co-hosting ``tenants``
        (:class:`~repro.serving.scheduler.TenantConfig`) on this
        deployment's cluster, inheriting its exec spec and cost table."""
        from ..serving.scheduler import ServingScheduler
        return ServingScheduler(tenants, self.cluster, config=config,
                                exec_spec=self.exec_spec,
                                cost_table=self.cost_table)

    def replan(self, cluster: Cluster) -> "Deployment":
        """Re-plan onto a changed cluster, reusing Algorithm 1's piece
        chain and any measured cost table (the runtime feedback loop as
        a pure function: old deployment + new cluster -> new one).

        A :class:`~repro.core.pipeline_dp.PlannerCache` is carried
        across the replan chain, so every hop after the first is the
        incremental hot path (``pico.source == "incremental"``)."""
        from ..core.pipeline_dp import PlannerCache
        cache = getattr(self, "_planner_cache", None)
        if cache is None:
            cache = self._planner_cache = PlannerCache()
        pico = plan_with_spec(self.model.graph, cluster,
                              self.model.input_size, self.plan_spec,
                              partition=self.pico.partition,
                              cost_table=self.cost_table,
                              planner_cache=cache)
        dep = Deployment(self.model, cluster, self.plan_spec,
                         self.exec_spec, pico, cost_table=self.cost_table,
                         params=self.params)
        dep._planner_cache = cache
        return dep

    # ---------------- persistence ----------------

    def _payload(self) -> dict:
        return {
            "plan_spec": self.plan_spec.to_dict(),
            "exec_spec": self.exec_spec.to_dict(),
            "model": artifacts.model_to_dict(self.model),
            "cluster": artifacts.cluster_to_dict(self.cluster),
            "pico": artifacts.plan_to_dict(self.pico),
            "cost_table": (None if self.cost_table is None
                           else artifacts.cost_table_to_dict(self.cost_table)),
        }

    def to_dict(self) -> dict:
        return artifacts.envelope("deployment", self._payload())

    @classmethod
    def _from_payload(cls, p: Mapping, model=None, params=None
                      ) -> "Deployment":
        return cls(
            model if model is not None else artifacts.model_from_dict(
                p["model"]),
            artifacts.cluster_from_dict(p["cluster"]),
            PlanSpec.from_dict(p["plan_spec"]),
            ExecSpec.from_dict(p["exec_spec"]),
            artifacts.plan_from_dict(p["pico"]),
            cost_table=(None if p.get("cost_table") is None
                        else artifacts.cost_table_from_dict(p["cost_table"])),
            params=params)

    @classmethod
    def from_dict(cls, d: Mapping, model=None, params=None) -> "Deployment":
        return cls._from_payload(artifacts.open_envelope(d, "deployment"),
                                 model=model, params=params)

    def to_json(self, **dump_kw) -> str:
        return artifacts.dumps_payload("deployment", self._payload(),
                                       **dump_kw)

    @classmethod
    def from_json(cls, s: str, model=None, params=None) -> "Deployment":
        return cls._from_payload(artifacts.loads_payload("deployment", s),
                                 model=model, params=params)

    def save(self, path: str | os.PathLike) -> str:
        """Write the deployment artifact (plan + specs + model def +
        cluster + cost table) as versioned JSON; returns the path.

        Model *weights* are deliberately not part of the artifact —
        the plan ships as data, weights ship as checkpoints.  Default
        weights reproduce exactly on load (``init`` is deterministic in
        the serialized graph + PRNG key); trained weights must be
        reattached via ``Deployment.load(path, params=...)``."""
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))
            f.write("\n")
        return os.fspath(path)

    @classmethod
    def load(cls, path: str | os.PathLike, model=None,
             params=None) -> "Deployment":
        """Rebuild a deployment from :meth:`save` output.  Neither the
        planner nor the calibrator runs — the plan, its measured cost
        table, and the model definition all come from the artifact.
        Pass ``model=`` to attach an existing model object instead of
        rebuilding one from the serialized graph, and ``params=`` to
        reattach trained weights (see :meth:`save`)."""
        with open(path) as f:
            return cls.from_json(f.read(), model=model, params=params)
