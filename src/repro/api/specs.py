"""Frozen configuration specs for the ``repro.api`` facade.

One declarative config surface replacing the ``(t_lim, backend,
n_split, dnc_threshold, max_diameter, ...)`` kwarg sprawl that every
entry point used to re-thread:

* :class:`PlanSpec`   — the offline optimizer (Algorithms 1-3) knobs;
* :class:`ExecSpec`   — how plans lower to executables (backend,
  compile mode, donation, scan batching, cache limits, calibration);
* :class:`DeploySpec` — the online runtime/serving knobs (batching,
  link realism, churn/drift re-planning policy).

All three are frozen dataclasses with eager validation and an exact
JSON round-trip (``to_json``/``from_json``); non-finite floats are
encoded as the strings ``"Infinity"``/``"-Infinity"`` so the payloads
stay strict-JSON parseable.  The module deliberately imports nothing
heavyweight — specs are safe to build in a CLI before JAX loads.
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass

SPEC_VERSION = 1

_EXEC_MODES = ("compiled", "eager")


def encode_float(v):
    """JSON-safe float: non-finite values become their string spelling
    (``"Infinity"``/``"-Infinity"``/``"NaN"``) so documents stay
    strict-JSON parseable."""
    if isinstance(v, float) and not math.isfinite(v):
        if math.isnan(v):
            return "NaN"
        return "Infinity" if v > 0 else "-Infinity"
    return v


def decode_float(v):
    if v == "Infinity":
        return float("inf")
    if v == "-Infinity":
        return float("-inf")
    if v == "NaN":
        return float("nan")
    return v


def _encode_deep(v):
    """Recursive :func:`encode_float` (nested spec payloads carry their
    own non-finite floats, e.g. an ``ObjectiveSpec`` inside a
    ``PlanSpec``)."""
    if isinstance(v, dict):
        return {k: _encode_deep(x) for k, x in v.items()}
    if isinstance(v, list):
        return [_encode_deep(x) for x in v]
    return encode_float(v)


class _SpecBase:
    """Shared (de)serialization for the frozen spec dataclasses."""

    #: fields omitted from payloads while None — additive evolution:
    #: documents written before the field existed stay byte-identical,
    #: and so do every registry/artifact key derived from them.
    _omit_if_none: tuple = ()

    def to_dict(self) -> dict:
        """Plain payload dict (raw float values — non-finite floats are
        spelled out only at JSON-encode time, by :meth:`to_json` or the
        enclosing artifact encoder).  Nested specs become nested payload
        dicts."""
        out = {"kind": type(self).__name__, "version": SPEC_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v is None and f.name in self._omit_if_none:
                continue
            out[f.name] = v.to_dict() if isinstance(v, _SpecBase) else v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "_SpecBase":
        d = dict(d)
        kind = d.pop("kind", cls.__name__)
        if kind != cls.__name__:
            raise ValueError(f"expected a {cls.__name__} payload, got {kind!r}")
        version = d.pop("version", SPEC_VERSION)
        if not isinstance(version, int):
            raise ValueError(f"{cls.__name__} payload version must be an "
                             f"integer, got {version!r}")
        if version > SPEC_VERSION:
            raise ValueError(f"{cls.__name__} payload version {version} is "
                             f"newer than supported {SPEC_VERSION}")
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"unknown {cls.__name__} fields: {sorted(unknown)}")
        vals = {}
        for k, v in d.items():
            if isinstance(v, dict) and v.get("kind") in SPEC_KINDS:
                vals[k] = SPEC_KINDS[v["kind"]].from_dict(v)
            else:
                vals[k] = decode_float(v)
        return cls(**vals)

    def to_json(self, **dump_kw) -> str:
        dump_kw.setdefault("sort_keys", True)
        return json.dumps(_encode_deep(self.to_dict()), **dump_kw)

    @classmethod
    def from_json(cls, s: str) -> "_SpecBase":
        return cls.from_dict(json.loads(s))

    def replace(self, **changes) -> "_SpecBase":
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ObjectiveSpec(_SpecBase):
    """Multi-objective planner scoring: weights + hard constraints over
    throughput (pipeline period), end-to-end latency, steady-state
    per-frame energy, and peak per-device memory.

    The default instance is *pure throughput* — it reproduces the
    single-objective planner bit-identically.  Weights are unit-free:
    :meth:`score` normalizes each metric by a reference point (the
    front's elementwise minimum in :meth:`~repro.core.pareto.
    ParetoFront.select`) before weighting, so ``latency=1.0`` means
    "one unit of relative latency costs as much as one unit of relative
    period".  Constraints are absolute: seconds for ``max_latency_s``,
    Joules/frame for ``max_energy_j``, bytes for ``max_memory_bytes``
    (peak, per device).

    Inside Algorithm 2, ``max_latency_s`` tightens ``t_lim``,
    ``max_memory_bytes`` prunes stage candidates whose peak per-device
    footprint (params + live features) exceeds the budget, and a
    positive ``latency`` weight switches the DP comparison from
    lexicographic (period, latency) to the weighted scalarization —
    on both the scalar and the vectorized solver paths.  Energy is a
    whole-plan quantity (idle power depends on the final period), so
    its weight/constraint apply at plan scoring, not inside the DP.
    """

    throughput: float = 1.0
    latency: float = 0.0
    energy: float = 0.0
    memory: float = 0.0
    max_latency_s: float = float("inf")
    max_energy_j: float = float("inf")
    max_memory_bytes: float = float("inf")

    def __post_init__(self):
        weights = (self.throughput, self.latency, self.energy, self.memory)
        for name, w in zip(("throughput", "latency", "energy", "memory"),
                           weights):
            if not (w >= 0 and math.isfinite(w)):
                raise ValueError(f"{name} weight must be finite and >= 0, "
                                 f"got {w}")
        if not any(w > 0 for w in weights):
            raise ValueError("at least one objective weight must be > 0")
        for name in ("max_latency_s", "max_energy_j", "max_memory_bytes"):
            if not getattr(self, name) > 0:
                raise ValueError(f"{name} must be > 0, "
                                 f"got {getattr(self, name)}")

    # -- planner-facing views -------------------------------------------
    @property
    def is_throughput_only(self) -> bool:
        """True for the default single-objective planner behavior."""
        return (self.latency == 0 and self.energy == 0 and self.memory == 0
                and not math.isfinite(self.max_latency_s)
                and not math.isfinite(self.max_energy_j)
                and not math.isfinite(self.max_memory_bytes))

    @property
    def shapes_dp(self) -> bool:
        """Whether Algorithm 2's DP must deviate from the pure
        throughput solver (latency enters the comparison, or stage
        candidates are memory-pruned)."""
        return self.latency > 0 or math.isfinite(self.max_memory_bytes)

    def dp_signature(self) -> tuple:
        """The part of the objective a solved DP table depends on
        (``max_latency_s`` folds into ``t_lim`` upstream)."""
        return (self.throughput, self.latency, self.max_memory_bytes)

    def relaxed(self) -> "ObjectiveSpec":
        """Constraints dropped, weights kept — the best-effort fallback
        target when the constrained problem is infeasible."""
        return self.replace(max_latency_s=float("inf"),
                            max_energy_j=float("inf"),
                            max_memory_bytes=float("inf"))

    # -- plan scoring ---------------------------------------------------
    def feasible(self, metrics) -> bool:
        """Whether a plan's metrics satisfy every hard constraint."""
        return (metrics.latency <= self.max_latency_s
                and metrics.energy_j <= self.max_energy_j
                and metrics.memory_bytes <= self.max_memory_bytes)

    def score(self, metrics, ref=None) -> float:
        """Weighted scalarization of a plan's metrics (lower is better).

        ``metrics``/``ref`` carry ``period``/``latency``/``energy_j``/
        ``memory_bytes``; with ``ref`` each term is normalized by the
        reference value so the weights compare like-for-like.
        """
        def norm(v, r):
            return v / r if (r is not None and r > 0) else v
        r = ref
        return (self.throughput * norm(metrics.period,
                                       r.period if r else None)
                + self.latency * norm(metrics.latency,
                                      r.latency if r else None)
                + self.energy * norm(metrics.energy_j,
                                     r.energy_j if r else None)
                + self.memory * norm(metrics.memory_bytes,
                                     r.memory_bytes if r else None))

    def label(self) -> str:
        """Preset name when this spec equals one, else ``"custom"`` —
        the human-readable provenance carried on plans it selects."""
        for name, preset in OBJECTIVE_PRESETS.items():
            if preset == self:
                return name
        return "custom"

    @classmethod
    def named(cls, name: str) -> "ObjectiveSpec":
        """Look up a preset objective (``throughput`` / ``latency`` /
        ``battery`` / ``memory`` / ``balanced``)."""
        try:
            return OBJECTIVE_PRESETS[name]
        except KeyError:
            raise ValueError(f"unknown objective {name!r}; presets: "
                             f"{sorted(OBJECTIVE_PRESETS)}") from None


#: Named deployment profiles: ``throughput`` is the paper's planner;
#: ``latency`` favors short end-to-end frames (interactive SLOs);
#: ``battery`` favors low per-frame energy (edge fleets on battery);
#: ``memory`` favors small peak per-device footprints; ``balanced``
#: weighs all four equally.
OBJECTIVE_PRESETS = {
    "throughput": ObjectiveSpec(),
    "latency": ObjectiveSpec(throughput=0.1, latency=1.0),
    "battery": ObjectiveSpec(throughput=0.1, energy=1.0),
    "memory": ObjectiveSpec(throughput=0.1, memory=1.0),
    "balanced": ObjectiveSpec(throughput=1.0, latency=1.0, energy=1.0,
                              memory=1.0),
}


@dataclass(frozen=True)
class PlanSpec(_SpecBase):
    """Offline-planner configuration (Algorithm 1 + 2 + 3 knobs).

    ``n_split`` is the reference tiling for Algorithm 1's C(M); ``None``
    defers to ``max(2, len(cluster))`` at plan time.  Graphs with more
    than ``dnc_threshold`` vertices use the divide-and-conquer
    partitioner.  ``t_lim`` is the paper's soft latency budget.
    ``objective`` makes the planner multi-objective
    (:class:`ObjectiveSpec`); ``None`` is the legacy pure-throughput
    planner, and is omitted from payloads so pre-objective documents —
    and every registry key derived from them — stay byte-identical.
    """

    t_lim: float = float("inf")
    max_diameter: int = 5
    n_split: int | None = None
    dnc_threshold: int = 120
    objective: ObjectiveSpec | None = None

    _omit_if_none = ("objective",)

    def __post_init__(self):
        if not self.t_lim > 0:
            raise ValueError(f"t_lim must be > 0, got {self.t_lim}")
        if self.max_diameter < 1:
            raise ValueError(f"max_diameter must be >= 1, "
                             f"got {self.max_diameter}")
        if self.n_split is not None and self.n_split < 2:
            raise ValueError(f"n_split must be None or >= 2, "
                             f"got {self.n_split}")
        if self.dnc_threshold < 1:
            raise ValueError(f"dnc_threshold must be >= 1, "
                             f"got {self.dnc_threshold}")
        if self.objective is not None and \
                not isinstance(self.objective, ObjectiveSpec):
            raise ValueError(f"objective must be None or an ObjectiveSpec, "
                             f"got {type(self.objective).__name__}")

    def resolve_n_split(self, n_devices: int) -> int:
        return self.n_split or max(2, n_devices)


@dataclass(frozen=True)
class ExecSpec(_SpecBase):
    """Execution-backend configuration for compiled plans.

    ``backend`` picks the conv lowering (``exec.backends`` registry;
    ``None`` = model default).  ``mode`` selects the compiled whole-stage
    executable or the eager per-tile oracle.  ``donate`` hands boundary
    buffers to XLA — honored only by single-stage entry points
    (:func:`repro.exec.compiler.compile_stage`, the exec benchmarks);
    multi-stage runners share boundary tensors across stages, where
    donation would corrupt later reads, so they always keep it off.
    ``scan_batch`` routes multi-frame cohorts through the ``lax.scan``
    ``run_frames`` path.  ``cache_size`` bounds the *process-wide*
    executable cache (applied whenever a Deployment carrying the spec
    is built or loaded).  ``calibrate`` makes :func:`repro.api.compile`
    time each stage and re-plan on the measured
    :class:`~repro.core.cost.CostTable`.  ``profile`` wraps every stage
    invocation in a ``jax.profiler`` trace annotation so stages show up
    named in XLA profiles (opt-in; no-op when the profiler is absent).
    ``fuse`` lowers conv->pool chains as one fused kernel call on
    backends with a fused lowering (numerics-neutral on the others).
    ``autotune`` makes :func:`repro.api.compile` search the Pallas
    kernel's channel block sizes per conv shape before calibration and
    persist the winners in the deployment's CostTable artifact.
    """

    backend: str | None = None
    mode: str = "compiled"
    donate: bool = False
    scan_batch: bool = True
    cache_size: int | None = None
    calibrate: bool = False
    calibrate_iters: int = 3
    profile: bool = False       # jax.profiler bracket around each stage call
    fuse: bool = True           # fuse conv->pool chains into one kernel call
    autotune: bool = False      # tune kernel block sizes at compile time
    autotune_iters: int = 3

    def __post_init__(self):
        if self.mode not in _EXEC_MODES:
            raise ValueError(f"mode must be one of {_EXEC_MODES}, "
                             f"got {self.mode!r}")
        if self.cache_size is not None and self.cache_size < 1:
            raise ValueError(f"cache_size must be None or >= 1, "
                             f"got {self.cache_size}")
        if self.calibrate_iters < 1:
            raise ValueError(f"calibrate_iters must be >= 1, "
                             f"got {self.calibrate_iters}")
        if self.autotune_iters < 1:
            raise ValueError(f"autotune_iters must be >= 1, "
                             f"got {self.autotune_iters}")

    def apply_cache_limit(self) -> int | None:
        """Apply ``cache_size`` to the process-global executable cache
        (no-op when unset).  Last-write-wins across deployments — the
        cache is shared process state, not per-deployment.  Returns the
        previous bound (or None if nothing was applied) so a scoped
        caller can restore it."""
        if self.cache_size is None:
            return None
        from ..exec.cache import set_cache_size
        return set_cache_size(self.cache_size)


@dataclass(frozen=True)
class DeploySpec(_SpecBase):
    """Online runtime/serving configuration (maps onto
    :class:`~repro.runtime.executor.RuntimeConfig`).

    The default is *ideal* — no jitter, no noise, free inter-stage
    hand-off — which reproduces ``core.simulate`` exactly.

    ``objective`` names the :data:`OBJECTIVE_PRESETS` profile this
    deployment optimizes for; :meth:`~repro.core.pareto.ParetoFront.
    deployment` uses it to pick the Pareto-front point to ship, and the
    chosen plan carries the name as provenance
    (``PicoPlan.objective``).  ``None`` means unspecified (throughput).
    """

    seed: int = 0
    max_batch: int = 1
    compute_noise: float = 0.0
    inter_stage_bandwidth: float | None = None
    link_latency_s: float = 0.0
    link_jitter_s: float = 0.0
    mem_budget_bytes: float = float("inf")
    replan_on_churn: bool = True
    replan_on_drift: bool = True
    drift_threshold: float = 0.25
    drift_cooldown: int = 24
    ewma_beta: float = 0.3
    migration_bandwidth: float | None = None
    trace: bool = False         # record repro.obs spans during runs
    metrics: bool = True        # publish runtime metrics (repro.obs)
    objective: str | None = None  # OBJECTIVE_PRESETS profile to deploy

    _omit_if_none = ("objective",)

    def __post_init__(self):
        if self.objective is not None and \
                self.objective not in OBJECTIVE_PRESETS:
            raise ValueError(f"objective must be None or one of "
                             f"{sorted(OBJECTIVE_PRESETS)}, "
                             f"got {self.objective!r}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        for name in ("compute_noise", "link_latency_s", "link_jitter_s",
                     "drift_threshold", "drift_cooldown"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0, "
                                 f"got {getattr(self, name)}")
        if not 0 < self.ewma_beta <= 1:
            raise ValueError(f"ewma_beta must be in (0, 1], "
                             f"got {self.ewma_beta}")
        if self.mem_budget_bytes <= 0:
            raise ValueError(f"mem_budget_bytes must be > 0, "
                             f"got {self.mem_budget_bytes}")
        for name in ("inter_stage_bandwidth", "migration_bandwidth"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be None or > 0, got {v}")

    def to_runtime_config(self):
        from ..runtime.executor import RuntimeConfig
        return RuntimeConfig(
            seed=self.seed,
            compute_noise=self.compute_noise,
            inter_stage_bandwidth=self.inter_stage_bandwidth,
            link_latency_s=self.link_latency_s,
            link_jitter_s=self.link_jitter_s,
            mem_budget_bytes=self.mem_budget_bytes,
            replan_on_churn=self.replan_on_churn,
            replan_on_drift=self.replan_on_drift,
            drift_threshold=self.drift_threshold,
            drift_cooldown=self.drift_cooldown,
            ewma_beta=self.ewma_beta,
            migration_bandwidth=self.migration_bandwidth,
            max_batch=self.max_batch,
            trace=self.trace,
            metrics=self.metrics)


_ROUTE_POLICIES = ("least_loaded", "round_robin")


@dataclass(frozen=True)
class FleetSpec(_SpecBase):
    """Fleet-tier configuration (:mod:`repro.fleet`).

    ``registry_capacity`` bounds the LRU plan registry (entries =
    distinct (model, cluster signature, PlanSpec, CostTable) keys).
    ``routing`` picks the admission policy: ``least_loaded`` sends a new
    tenant to the cell with the lowest load-EWMA per unit capacity;
    ``round_robin`` ignores load.  ``ewma_beta`` is the cell-load
    smoothing factor (same convention as
    :attr:`DeploySpec.ewma_beta`).  ``scale_up_load`` /
    ``scale_down_load`` are the autoscaler watermarks on smoothed cell
    load, and ``min_clusters`` / ``max_clusters`` bound how far the
    hooks may grow or shrink the fleet.
    """

    registry_capacity: int = 256
    routing: str = "least_loaded"
    ewma_beta: float = 0.3
    scale_up_load: float = 0.8
    scale_down_load: float = 0.25
    min_clusters: int = 1
    max_clusters: int | None = None

    def __post_init__(self):
        if self.registry_capacity < 1:
            raise ValueError(f"registry_capacity must be >= 1, "
                             f"got {self.registry_capacity}")
        if self.routing not in _ROUTE_POLICIES:
            raise ValueError(f"routing must be one of {_ROUTE_POLICIES}, "
                             f"got {self.routing!r}")
        if not 0 < self.ewma_beta <= 1:
            raise ValueError(f"ewma_beta must be in (0, 1], "
                             f"got {self.ewma_beta}")
        if not 0 <= self.scale_down_load < self.scale_up_load:
            raise ValueError(
                f"need 0 <= scale_down_load < scale_up_load, got "
                f"{self.scale_down_load} / {self.scale_up_load}")
        if self.min_clusters < 1:
            raise ValueError(f"min_clusters must be >= 1, "
                             f"got {self.min_clusters}")
        if (self.max_clusters is not None
                and self.max_clusters < self.min_clusters):
            raise ValueError(f"max_clusters must be None or >= min_clusters, "
                             f"got {self.max_clusters}")


_DIST_TRANSPORTS = ("memory", "tcp")
_DIST_WORKERS = ("thread", "process")


@dataclass(frozen=True)
class DistSpec(_SpecBase):
    """Real distributed execution configuration (:mod:`repro.dist`).

    ``transport`` picks how stage tensors move between workers:
    ``memory`` (queue pair carrying the encoded wire bytes — same codec
    as TCP) or ``tcp`` (length-prefixed framed tensors over loopback/
    LAN sockets, chunked sends).  ``workers`` picks the worker
    substrate: ``thread`` (persistent threads in this process — the CI
    mode) or ``process`` (one real OS process per pipeline stage via
    the multiprocessing *spawn* context; requires ``transport="tcp"``
    since spawned workers share no memory).  Either way each worker
    receives its slice of the versioned Deployment JSON artifact — the
    artifact round-trip is the hand-off; no pickled Python objects
    cross the boundary.

    ``heartbeat_s`` is the worker liveness beacon period; a worker
    silent for ``peer_timeout_s`` is declared dead and surfaced as a
    :class:`~repro.runtime.churn.DeviceLeave` churn event.
    ``start_timeout_s`` bounds worker spawn + handshake + executable
    warmup; ``recv_timeout_s`` bounds any single blocking receive
    (drain progress) and ``shutdown_timeout_s`` the final drain before
    in-flight frames are reported dropped.  ``micro_batch`` groups
    frames per wire message through the ``lax.scan`` path;
    ``max_inflight`` caps frames in the pipe (back-pressure);
    ``chunk_bytes`` sizes transport send chunks (per-chunk byte/latency
    accounting feeds ``repro.obs``).  ``seed`` seeds the deterministic
    per-worker weight rebuild (workers re-init from the shipped graph,
    bit-identical to the launcher's params).
    """

    transport: str = "memory"
    workers: str = "thread"
    heartbeat_s: float = 0.2
    peer_timeout_s: float = 10.0
    start_timeout_s: float = 120.0
    recv_timeout_s: float = 30.0
    shutdown_timeout_s: float = 30.0
    micro_batch: int = 1
    max_inflight: int = 8
    chunk_bytes: int = 1 << 20
    seed: int = 0
    trace: bool = True          # merge worker spans into one Perfetto trace

    def __post_init__(self):
        if self.transport not in _DIST_TRANSPORTS:
            raise ValueError(f"transport must be one of {_DIST_TRANSPORTS}, "
                             f"got {self.transport!r}")
        if self.workers not in _DIST_WORKERS:
            raise ValueError(f"workers must be one of {_DIST_WORKERS}, "
                             f"got {self.workers!r}")
        if self.workers == "process" and self.transport != "tcp":
            raise ValueError("workers='process' requires transport='tcp' "
                             "(spawned workers share no memory)")
        for name in ("heartbeat_s", "peer_timeout_s", "start_timeout_s",
                     "recv_timeout_s", "shutdown_timeout_s"):
            v = getattr(self, name)
            if not (isinstance(v, (int, float)) and v > 0
                    and math.isfinite(v)):
                raise ValueError(f"{name} must be finite and > 0, got {v}")
        if self.peer_timeout_s <= self.heartbeat_s:
            raise ValueError(f"peer_timeout_s ({self.peer_timeout_s}) must "
                             f"exceed heartbeat_s ({self.heartbeat_s})")
        if self.micro_batch < 1:
            raise ValueError(f"micro_batch must be >= 1, "
                             f"got {self.micro_batch}")
        if self.max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, "
                             f"got {self.max_inflight}")
        if self.chunk_bytes < 1024:
            raise ValueError(f"chunk_bytes must be >= 1024, "
                             f"got {self.chunk_bytes}")


SPEC_KINDS = {cls.__name__: cls
              for cls in (ObjectiveSpec, PlanSpec, ExecSpec, DeploySpec,
                          FleetSpec, DistSpec)}


def spec_from_dict(d: dict):
    """Dispatch a spec payload to its dataclass by the ``kind`` field."""
    kind = d.get("kind")
    if kind not in SPEC_KINDS:
        raise ValueError(f"unknown spec kind {kind!r}")
    return SPEC_KINDS[kind].from_dict(d)
