"""Frozen configuration specs for the ``repro.api`` facade.

One declarative config surface replacing the ``(t_lim, backend,
n_split, dnc_threshold, max_diameter, ...)`` kwarg sprawl that every
entry point used to re-thread:

* :class:`PlanSpec`   — the offline optimizer (Algorithms 1-3) knobs;
* :class:`ExecSpec`   — how plans lower to executables (backend,
  compile mode, donation, scan batching, cache limits, calibration);
* :class:`DeploySpec` — the online runtime/serving knobs (batching,
  link realism, churn/drift re-planning policy).

All three are frozen dataclasses with eager validation and an exact
JSON round-trip (``to_json``/``from_json``); non-finite floats are
encoded as the strings ``"Infinity"``/``"-Infinity"`` so the payloads
stay strict-JSON parseable.  The module deliberately imports nothing
heavyweight — specs are safe to build in a CLI before JAX loads.
"""

from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass

SPEC_VERSION = 1

_EXEC_MODES = ("compiled", "eager")


def encode_float(v):
    """JSON-safe float: non-finite values become their string spelling
    (``"Infinity"``/``"-Infinity"``/``"NaN"``) so documents stay
    strict-JSON parseable."""
    if isinstance(v, float) and not math.isfinite(v):
        if math.isnan(v):
            return "NaN"
        return "Infinity" if v > 0 else "-Infinity"
    return v


def decode_float(v):
    if v == "Infinity":
        return float("inf")
    if v == "-Infinity":
        return float("-inf")
    if v == "NaN":
        return float("nan")
    return v


class _SpecBase:
    """Shared (de)serialization for the frozen spec dataclasses."""

    def to_dict(self) -> dict:
        """Plain payload dict (raw float values — non-finite floats are
        spelled out only at JSON-encode time, by :meth:`to_json` or the
        enclosing artifact encoder)."""
        out = {"kind": type(self).__name__, "version": SPEC_VERSION}
        for f in dataclasses.fields(self):
            out[f.name] = getattr(self, f.name)
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "_SpecBase":
        d = dict(d)
        kind = d.pop("kind", cls.__name__)
        if kind != cls.__name__:
            raise ValueError(f"expected a {cls.__name__} payload, got {kind!r}")
        version = d.pop("version", SPEC_VERSION)
        if not isinstance(version, int):
            raise ValueError(f"{cls.__name__} payload version must be an "
                             f"integer, got {version!r}")
        if version > SPEC_VERSION:
            raise ValueError(f"{cls.__name__} payload version {version} is "
                             f"newer than supported {SPEC_VERSION}")
        names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - names
        if unknown:
            raise ValueError(f"unknown {cls.__name__} fields: {sorted(unknown)}")
        return cls(**{k: decode_float(v) for k, v in d.items()})

    def to_json(self, **dump_kw) -> str:
        dump_kw.setdefault("sort_keys", True)
        return json.dumps({k: encode_float(v)
                           for k, v in self.to_dict().items()}, **dump_kw)

    @classmethod
    def from_json(cls, s: str) -> "_SpecBase":
        return cls.from_dict(json.loads(s))

    def replace(self, **changes) -> "_SpecBase":
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class PlanSpec(_SpecBase):
    """Offline-planner configuration (Algorithm 1 + 2 + 3 knobs).

    ``n_split`` is the reference tiling for Algorithm 1's C(M); ``None``
    defers to ``max(2, len(cluster))`` at plan time.  Graphs with more
    than ``dnc_threshold`` vertices use the divide-and-conquer
    partitioner.  ``t_lim`` is the paper's soft latency budget.
    """

    t_lim: float = float("inf")
    max_diameter: int = 5
    n_split: int | None = None
    dnc_threshold: int = 120

    def __post_init__(self):
        if not self.t_lim > 0:
            raise ValueError(f"t_lim must be > 0, got {self.t_lim}")
        if self.max_diameter < 1:
            raise ValueError(f"max_diameter must be >= 1, "
                             f"got {self.max_diameter}")
        if self.n_split is not None and self.n_split < 2:
            raise ValueError(f"n_split must be None or >= 2, "
                             f"got {self.n_split}")
        if self.dnc_threshold < 1:
            raise ValueError(f"dnc_threshold must be >= 1, "
                             f"got {self.dnc_threshold}")

    def resolve_n_split(self, n_devices: int) -> int:
        return self.n_split or max(2, n_devices)


@dataclass(frozen=True)
class ExecSpec(_SpecBase):
    """Execution-backend configuration for compiled plans.

    ``backend`` picks the conv lowering (``exec.backends`` registry;
    ``None`` = model default).  ``mode`` selects the compiled whole-stage
    executable or the eager per-tile oracle.  ``donate`` hands boundary
    buffers to XLA — honored only by single-stage entry points
    (:func:`repro.exec.compiler.compile_stage`, the exec benchmarks);
    multi-stage runners share boundary tensors across stages, where
    donation would corrupt later reads, so they always keep it off.
    ``scan_batch`` routes multi-frame cohorts through the ``lax.scan``
    ``run_frames`` path.  ``cache_size`` bounds the *process-wide*
    executable cache (applied whenever a Deployment carrying the spec
    is built or loaded).  ``calibrate`` makes :func:`repro.api.compile`
    time each stage and re-plan on the measured
    :class:`~repro.core.cost.CostTable`.  ``profile`` wraps every stage
    invocation in a ``jax.profiler`` trace annotation so stages show up
    named in XLA profiles (opt-in; no-op when the profiler is absent).
    ``fuse`` lowers conv->pool chains as one fused kernel call on
    backends with a fused lowering (numerics-neutral on the others).
    ``autotune`` makes :func:`repro.api.compile` search the Pallas
    kernel's channel block sizes per conv shape before calibration and
    persist the winners in the deployment's CostTable artifact.
    """

    backend: str | None = None
    mode: str = "compiled"
    donate: bool = False
    scan_batch: bool = True
    cache_size: int | None = None
    calibrate: bool = False
    calibrate_iters: int = 3
    profile: bool = False       # jax.profiler bracket around each stage call
    fuse: bool = True           # fuse conv->pool chains into one kernel call
    autotune: bool = False      # tune kernel block sizes at compile time
    autotune_iters: int = 3

    def __post_init__(self):
        if self.mode not in _EXEC_MODES:
            raise ValueError(f"mode must be one of {_EXEC_MODES}, "
                             f"got {self.mode!r}")
        if self.cache_size is not None and self.cache_size < 1:
            raise ValueError(f"cache_size must be None or >= 1, "
                             f"got {self.cache_size}")
        if self.calibrate_iters < 1:
            raise ValueError(f"calibrate_iters must be >= 1, "
                             f"got {self.calibrate_iters}")
        if self.autotune_iters < 1:
            raise ValueError(f"autotune_iters must be >= 1, "
                             f"got {self.autotune_iters}")

    def apply_cache_limit(self) -> int | None:
        """Apply ``cache_size`` to the process-global executable cache
        (no-op when unset).  Last-write-wins across deployments — the
        cache is shared process state, not per-deployment.  Returns the
        previous bound (or None if nothing was applied) so a scoped
        caller can restore it."""
        if self.cache_size is None:
            return None
        from ..exec.cache import set_cache_size
        return set_cache_size(self.cache_size)


@dataclass(frozen=True)
class DeploySpec(_SpecBase):
    """Online runtime/serving configuration (maps onto
    :class:`~repro.runtime.executor.RuntimeConfig`).

    The default is *ideal* — no jitter, no noise, free inter-stage
    hand-off — which reproduces ``core.simulate`` exactly.
    """

    seed: int = 0
    max_batch: int = 1
    compute_noise: float = 0.0
    inter_stage_bandwidth: float | None = None
    link_latency_s: float = 0.0
    link_jitter_s: float = 0.0
    mem_budget_bytes: float = float("inf")
    replan_on_churn: bool = True
    replan_on_drift: bool = True
    drift_threshold: float = 0.25
    drift_cooldown: int = 24
    ewma_beta: float = 0.3
    migration_bandwidth: float | None = None
    trace: bool = False         # record repro.obs spans during runs
    metrics: bool = True        # publish runtime metrics (repro.obs)

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        for name in ("compute_noise", "link_latency_s", "link_jitter_s",
                     "drift_threshold", "drift_cooldown"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0, "
                                 f"got {getattr(self, name)}")
        if not 0 < self.ewma_beta <= 1:
            raise ValueError(f"ewma_beta must be in (0, 1], "
                             f"got {self.ewma_beta}")
        if self.mem_budget_bytes <= 0:
            raise ValueError(f"mem_budget_bytes must be > 0, "
                             f"got {self.mem_budget_bytes}")
        for name in ("inter_stage_bandwidth", "migration_bandwidth"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be None or > 0, got {v}")

    def to_runtime_config(self):
        from ..runtime.executor import RuntimeConfig
        return RuntimeConfig(
            seed=self.seed,
            compute_noise=self.compute_noise,
            inter_stage_bandwidth=self.inter_stage_bandwidth,
            link_latency_s=self.link_latency_s,
            link_jitter_s=self.link_jitter_s,
            mem_budget_bytes=self.mem_budget_bytes,
            replan_on_churn=self.replan_on_churn,
            replan_on_drift=self.replan_on_drift,
            drift_threshold=self.drift_threshold,
            drift_cooldown=self.drift_cooldown,
            ewma_beta=self.ewma_beta,
            migration_bandwidth=self.migration_bandwidth,
            max_batch=self.max_batch,
            trace=self.trace,
            metrics=self.metrics)


_ROUTE_POLICIES = ("least_loaded", "round_robin")


@dataclass(frozen=True)
class FleetSpec(_SpecBase):
    """Fleet-tier configuration (:mod:`repro.fleet`).

    ``registry_capacity`` bounds the LRU plan registry (entries =
    distinct (model, cluster signature, PlanSpec, CostTable) keys).
    ``routing`` picks the admission policy: ``least_loaded`` sends a new
    tenant to the cell with the lowest load-EWMA per unit capacity;
    ``round_robin`` ignores load.  ``ewma_beta`` is the cell-load
    smoothing factor (same convention as
    :attr:`DeploySpec.ewma_beta`).  ``scale_up_load`` /
    ``scale_down_load`` are the autoscaler watermarks on smoothed cell
    load, and ``min_clusters`` / ``max_clusters`` bound how far the
    hooks may grow or shrink the fleet.
    """

    registry_capacity: int = 256
    routing: str = "least_loaded"
    ewma_beta: float = 0.3
    scale_up_load: float = 0.8
    scale_down_load: float = 0.25
    min_clusters: int = 1
    max_clusters: int | None = None

    def __post_init__(self):
        if self.registry_capacity < 1:
            raise ValueError(f"registry_capacity must be >= 1, "
                             f"got {self.registry_capacity}")
        if self.routing not in _ROUTE_POLICIES:
            raise ValueError(f"routing must be one of {_ROUTE_POLICIES}, "
                             f"got {self.routing!r}")
        if not 0 < self.ewma_beta <= 1:
            raise ValueError(f"ewma_beta must be in (0, 1], "
                             f"got {self.ewma_beta}")
        if not 0 <= self.scale_down_load < self.scale_up_load:
            raise ValueError(
                f"need 0 <= scale_down_load < scale_up_load, got "
                f"{self.scale_down_load} / {self.scale_up_load}")
        if self.min_clusters < 1:
            raise ValueError(f"min_clusters must be >= 1, "
                             f"got {self.min_clusters}")
        if (self.max_clusters is not None
                and self.max_clusters < self.min_clusters):
            raise ValueError(f"max_clusters must be None or >= min_clusters, "
                             f"got {self.max_clusters}")


SPEC_KINDS = {cls.__name__: cls
              for cls in (PlanSpec, ExecSpec, DeploySpec, FleetSpec)}


def spec_from_dict(d: dict):
    """Dispatch a spec payload to its dataclass by the ``kind`` field."""
    kind = d.get("kind")
    if kind not in SPEC_KINDS:
        raise ValueError(f"unknown spec kind {kind!r}")
    return SPEC_KINDS[kind].from_dict(d)
