"""Public deployment facade: ``compile() -> Deployment`` plus the spec
and artifact layers.  Re-exported at top level as ``repro.compile`` /
``repro.Deployment`` / ``repro.PlanSpec`` / ...

Only the lightweight pieces (specs, deprecation plumbing) import
eagerly; :func:`compile`/:class:`Deployment` and the artifact codecs
load on first touch so ``repro.core`` stays importable without JAX and
free of import cycles.
"""

from ._compat import lazy_exports, reset_legacy_warnings
from .specs import (OBJECTIVE_PRESETS, SPEC_VERSION, DeploySpec, ExecSpec,
                    FleetSpec, ObjectiveSpec, PlanSpec, spec_from_dict)

_LAZY = {
    "compile": ("repro.api.deployment", "compile"),
    "Deployment": ("repro.api.deployment", "Deployment"),
    "artifacts": ("repro.api.artifacts", None),
    "SCHEMA_VERSION": ("repro.api.artifacts", "SCHEMA_VERSION"),
}

__all__ = ["PlanSpec", "ExecSpec", "DeploySpec", "FleetSpec",
           "ObjectiveSpec", "OBJECTIVE_PRESETS", "spec_from_dict",
           "SPEC_VERSION", "SCHEMA_VERSION", "compile", "Deployment",
           "artifacts", "reset_legacy_warnings"]

__getattr__, __dir__ = lazy_exports(__name__, globals(), _LAZY)
