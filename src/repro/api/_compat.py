"""Deprecation machinery for the legacy kwarg surface.

Every pre-``repro.api`` entry point (``core.plan``, ``PipelineRuntime``,
the servers, ...) keeps accepting its historical keyword arguments, but
each such call site funnels through :func:`warn_legacy` so users see a
single :class:`DeprecationWarning` per entry point per process — loud
enough to notice, quiet enough not to drown a serving loop.
"""

from __future__ import annotations

import warnings

_WARNED: set[str] = set()


def warn_legacy(key: str, replacement: str, *, stacklevel: int = 3) -> None:
    """Warn (once per ``key``) that a legacy kwarg surface was used."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(
        f"{key} with loose keyword arguments is deprecated; "
        f"use {replacement} instead",
        DeprecationWarning, stacklevel=stacklevel)


def reset_legacy_warnings() -> None:
    """Forget which entry points already warned (test isolation hook)."""
    _WARNED.clear()


# sentinel distinguishing "caller passed nothing" from an explicit value
_UNSET = object()


def unset(*values) -> bool:
    """True iff every value is the _UNSET sentinel."""
    return all(v is _UNSET for v in values)


def pick(value, default):
    """Resolve a sentinel-defaulted kwarg."""
    return default if value is _UNSET else value


def lazy_exports(module_name: str, module_globals: dict, table: dict):
    """PEP 562 module ``__getattr__``/``__dir__`` pair over a
    ``{name: (module, attr_or_None)}`` table — shared by the package
    ``__init__`` files so heavyweight subsystems import on first touch."""

    def __getattr__(name):
        try:
            module, attr = table[name]
        except KeyError:
            raise AttributeError(
                f"module {module_name!r} has no attribute {name!r}")
        import importlib
        mod = importlib.import_module(module)
        value = mod if attr is None else getattr(mod, attr)
        module_globals[name] = value
        return value

    def __dir__():
        return sorted(set(module_globals) | set(table))

    return __getattr__, __dir__
