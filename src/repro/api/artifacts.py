"""Versioned JSON serialization for planner artifacts.

The paper's deployment story is offline-plan / online-execute: the
optimizer's output is shipped to a device fleet and executed there.
This module makes every artifact on that boundary durable —
:class:`~repro.core.planner.PicoPlan` (piece chain + stage/device
mapping + priced costs), :class:`~repro.core.partition.PartitionResult`,
:class:`~repro.core.cost.CostTable` (measured calibration ratios),
:class:`~repro.core.cost.Cluster`, and the model definition itself
(graph of :class:`~repro.core.graph.LayerSpec`) — as strict JSON with a
schema version field.

Round-trips are exact: floats serialize via ``repr`` (shortest
round-trip form, bit-identical on load), node sets as sorted lists,
non-finite floats as ``"Infinity"`` strings.  A loaded plan re-prices,
simulates and executes identically to the original with zero
re-planning or re-calibration.

Version policy: loaders reject payloads *newer* than their own
``SCHEMA_VERSION`` with a clear error, so new-format artifacts fail
fast on old code.  Additive evolution (new optional fields) does not
bump the version — decoders default missing fields (``dict.get``).  A
*breaking* payload-shape change must bump ``SCHEMA_VERSION`` and ship
a version-dispatched migration in this module alongside it; until one
exists, every version ``<=`` current decodes with the current codecs.
"""

from __future__ import annotations

import json
from typing import Mapping

from ..core.cost import (Cluster, CostTable, Device, SegmentCost, StageCost)
from ..core.graph import Graph, LayerSpec
from ..core.partition import PartitionResult, Piece
from ..core.pipeline_dp import PipelinePlan, StagePlan
from ..core.planner import PicoPlan
from .specs import decode_float, encode_float

SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# envelope
# ---------------------------------------------------------------------------

def envelope(kind: str, payload: dict) -> dict:
    return {"artifact": kind, "version": SCHEMA_VERSION, "payload": payload}


def open_envelope(d: Mapping, kind: str) -> dict:
    got = d.get("artifact")
    if got != kind:
        raise ValueError(f"expected a {kind!r} artifact, got {got!r}")
    version = d.get("version")
    if not isinstance(version, int):
        raise ValueError(f"{kind} artifact has no integer version field")
    if version > SCHEMA_VERSION:
        raise ValueError(f"{kind} artifact version {version} is newer than "
                         f"supported {SCHEMA_VERSION}")
    try:
        return d["payload"]
    except KeyError:
        raise ValueError(f"{kind} artifact envelope has no payload field")


def _nodes_out(nodes) -> list[str]:
    return sorted(nodes)


def _nodes_in(names) -> frozenset[str]:
    return frozenset(names)


# ---------------------------------------------------------------------------
# devices / clusters
# ---------------------------------------------------------------------------

def device_to_dict(d: Device) -> dict:
    return {"name": d.name, "capacity": d.capacity, "alpha": d.alpha,
            "active_power": d.active_power, "idle_power": d.idle_power}


def device_from_dict(d: Mapping) -> Device:
    return Device(d["name"], d["capacity"], d.get("alpha", 1.0),
                  d.get("active_power", 4.0), d.get("idle_power", 1.6))


def cluster_to_dict(c: Cluster) -> dict:
    return {"devices": [device_to_dict(d) for d in c.devices],
            "bandwidth": c.bandwidth,
            "pair_bandwidth": [[a, b, bw] for (a, b), bw
                               in sorted(c.pair_bandwidth.items())]}


def cluster_from_dict(d: Mapping) -> Cluster:
    return Cluster([device_from_dict(x) for x in d["devices"]],
                   bandwidth=d["bandwidth"],
                   pair_bandwidth={(a, b): bw for a, b, bw
                                   in d.get("pair_bandwidth", ())})


# ---------------------------------------------------------------------------
# partition
# ---------------------------------------------------------------------------

def piece_to_dict(p: Piece) -> dict:
    return {"nodes": _nodes_out(p.nodes), "redundancy": p.redundancy,
            "index": p.index}


def piece_from_dict(d: Mapping) -> Piece:
    return Piece(_nodes_in(d["nodes"]), d["redundancy"], d["index"])


def partition_to_dict(pr: PartitionResult) -> dict:
    return {"pieces": [piece_to_dict(p) for p in pr.pieces],
            "objective": pr.objective,
            "states_explored": pr.states_explored,
            "wall_time_s": pr.wall_time_s}


def partition_from_dict(d: Mapping) -> PartitionResult:
    return PartitionResult([piece_from_dict(p) for p in d["pieces"]],
                           d["objective"], d["states_explored"],
                           d["wall_time_s"])


# ---------------------------------------------------------------------------
# pipeline plan (priced stages)
# ---------------------------------------------------------------------------

def _segment_cost_to_dict(s: SegmentCost) -> dict:
    return {"nodes": _nodes_out(s.nodes),
            "per_device_flops": list(s.per_device_flops),
            "exact_flops": s.exact_flops,
            "in_bytes": list(s.in_bytes), "out_bytes": list(s.out_bytes),
            "param_bytes": s.param_bytes,
            "feature_bytes": list(s.feature_bytes)}


def _segment_cost_from_dict(d: Mapping) -> SegmentCost:
    return SegmentCost(_nodes_in(d["nodes"]), list(d["per_device_flops"]),
                       d["exact_flops"], list(d["in_bytes"]),
                       list(d["out_bytes"]), d["param_bytes"],
                       list(d["feature_bytes"]))


def _stage_cost_to_dict(c: StageCost) -> dict:
    return {"t_comp": c.t_comp, "t_comm": c.t_comm,
            "per_device_comp": list(c.per_device_comp),
            "seg": _segment_cost_to_dict(c.seg)}


def _stage_cost_from_dict(d: Mapping) -> StageCost:
    return StageCost(d["t_comp"], d["t_comm"], list(d["per_device_comp"]),
                     _segment_cost_from_dict(d["seg"]))


def _stage_plan_to_dict(st: StagePlan) -> dict:
    return {"first_piece": st.first_piece, "last_piece": st.last_piece,
            "devices": [device_to_dict(d) for d in st.devices],
            "nodes": _nodes_out(st.nodes),
            "cost": _stage_cost_to_dict(st.cost),
            "fractions": list(st.fractions)}


def _stage_plan_from_dict(d: Mapping) -> StagePlan:
    return StagePlan(d["first_piece"], d["last_piece"],
                     [device_from_dict(x) for x in d["devices"]],
                     _nodes_in(d["nodes"]), _stage_cost_from_dict(d["cost"]),
                     list(d["fractions"]))


def pipeline_to_dict(p: PipelinePlan) -> dict:
    return {"stages": [_stage_plan_to_dict(s) for s in p.stages],
            "period": p.period, "latency": p.latency,
            "wall_time_s": p.wall_time_s, "feasible": p.feasible}


def pipeline_from_dict(d: Mapping) -> PipelinePlan:
    return PipelinePlan([_stage_plan_from_dict(s) for s in d["stages"]],
                        d["period"], d["latency"], d["wall_time_s"],
                        d.get("feasible", True))


def plan_to_dict(pico: PicoPlan) -> dict:
    # "source" (scratch | incremental | registry) is an additive field:
    # pre-provenance artifacts load as "scratch", old loaders ignore it
    d = {"partition": partition_to_dict(pico.partition),
         "pipeline": pipeline_to_dict(pico.pipeline),
         "source": pico.source}
    # objective label (additive, omitted while None so pre-objective
    # plan documents stay byte-identical)
    if pico.objective is not None:
        d["objective"] = pico.objective
    return d


def plan_from_dict(d: Mapping) -> PicoPlan:
    return PicoPlan(partition_from_dict(d["partition"]),
                    pipeline_from_dict(d["pipeline"]),
                    source=d.get("source", "scratch"),
                    objective=d.get("objective"))


# ---------------------------------------------------------------------------
# cost table
# ---------------------------------------------------------------------------

def cost_table_to_dict(t: CostTable) -> dict:
    d = {"ratios": [{"nodes": _nodes_out(k), "ratio": v}
                    for k, v in sorted(t.ratios.items(),
                                       key=lambda kv: sorted(kv[0]))],
         "default": t.default}
    # autotuned kernel winners: additive field (absent pre-autotune
    # artifacts load fine; older loaders ignore it), so no schema bump
    if getattr(t, "kernels", None):
        d["kernels"] = [{"key": k, **t.kernels[k]}
                        for k in sorted(t.kernels)]
    return d


def cost_table_from_dict(d: Mapping) -> CostTable:
    kernels = {e["key"]: {k: v for k, v in e.items() if k != "key"}
               for e in d.get("kernels", ())}
    return CostTable({_nodes_in(e["nodes"]): e["ratio"]
                      for e in d["ratios"]}, default=d.get("default"),
                     kernels=kernels)


# ---------------------------------------------------------------------------
# model definition (graph of LayerSpecs)
# ---------------------------------------------------------------------------

def layer_spec_to_dict(s: LayerSpec) -> dict:
    return {"name": s.name, "kind": s.kind, "kernel": list(s.kernel),
            "stride": list(s.stride), "padding": list(s.padding),
            "in_channels": s.in_channels, "out_channels": s.out_channels,
            "flops_coeff": s.flops_coeff, "param_bytes": s.param_bytes,
            "global_rf": s.global_rf,
            "tile_independent_flops": s.tile_independent_flops}


def layer_spec_from_dict(d: Mapping) -> LayerSpec:
    return LayerSpec(d["name"], d["kind"], tuple(d["kernel"]),
                     tuple(d["stride"]), tuple(d["padding"]),
                     d["in_channels"], d["out_channels"], d["flops_coeff"],
                     d["param_bytes"], d["global_rf"],
                     d["tile_independent_flops"])


def graph_to_dict(g: Graph) -> dict:
    # layer order is semantic (stable Kahn topo ties break on insertion
    # order), so serialize layers as an ordered list, not a mapping
    return {"layers": [layer_spec_to_dict(g.layers[n]) for n in g.layers],
            "edges": [list(e) for e in g.edges]}


def graph_from_dict(d: Mapping) -> Graph:
    g = Graph()
    for ls in d["layers"]:
        g.layers[ls["name"]] = layer_spec_from_dict(ls)
    g.edges = [(u, v) for u, v in d["edges"]]
    g._invalidate()
    return g


def model_to_dict(model) -> dict:
    """Serialize a :class:`~repro.models.cnn.builder.CNNDef`."""
    return {"name": model.name, "graph": graph_to_dict(model.graph),
            "input_size": list(model.input_size),
            "in_channels": model.in_channels,
            "blocks": [list(b) for b in model.blocks],
            "backend": model.backend}


def model_from_dict(d: Mapping):
    from ..models.cnn.builder import CNNDef     # lazy: pulls in jax
    return CNNDef(d["name"], graph_from_dict(d["graph"]),
                  tuple(d["input_size"]), d["in_channels"],
                  [list(b) for b in d.get("blocks", ())],
                  d.get("backend"))


# ---------------------------------------------------------------------------
# pareto front (multi-objective planner output)
# ---------------------------------------------------------------------------

def _plan_metrics_to_dict(m) -> dict:
    return {"period": m.period, "latency": m.latency,
            "energy_j": m.energy_j, "memory_bytes": m.memory_bytes}


def _plan_metrics_from_dict(d: Mapping):
    from ..core.simulate import PlanMetrics
    return PlanMetrics(d["period"], d["latency"], d["energy_j"],
                       d["memory_bytes"])


def _front_point_to_dict(p) -> dict:
    return {"plan": plan_to_dict(p.plan),
            "metrics": _plan_metrics_to_dict(p.metrics),
            "n_devices": p.n_devices, "t_lim": p.t_lim}


def _front_point_from_dict(d: Mapping):
    from ..core.pareto import FrontPoint
    return FrontPoint(plan_from_dict(d["plan"]),
                      _plan_metrics_from_dict(d["metrics"]),
                      d["n_devices"], d.get("t_lim", float("inf")))


def pareto_front_to_dict(front) -> dict:
    """Serialize a :class:`~repro.core.pareto.ParetoFront`: the sweep's
    :class:`~repro.api.specs.PlanSpec` plus every non-dominated point
    (full plan + priced metrics + sweep coordinates)."""
    return {"spec": front.spec.to_dict(),
            "points": [_front_point_to_dict(p) for p in front.points]}


def pareto_front_from_dict(d: Mapping):
    from ..core.pareto import ParetoFront   # lazy: avoid import cycle
    from .specs import PlanSpec
    return ParetoFront([_front_point_from_dict(p) for p in d["points"]],
                       PlanSpec.from_dict(d["spec"]))


# ---------------------------------------------------------------------------
# fleet plan registry
# ---------------------------------------------------------------------------

def plan_registry_to_dict(reg) -> dict:
    """Serialize a :class:`~repro.fleet.registry.PlanRegistry` (entries
    in LRU order, oldest first; the payload shape is owned by the
    registry so its key scheme and this codec evolve together)."""
    return reg.to_payload()


def plan_registry_from_dict(d: Mapping):
    from ..fleet.registry import PlanRegistry   # lazy: avoid import cycle
    return PlanRegistry.from_payload(d)


# ---------------------------------------------------------------------------
# public JSON entry points
# ---------------------------------------------------------------------------

_CODECS = {
    "plan": (plan_to_dict, plan_from_dict),
    "partition": (partition_to_dict, partition_from_dict),
    "cost_table": (cost_table_to_dict, cost_table_from_dict),
    "cluster": (cluster_to_dict, cluster_from_dict),
    "model": (model_to_dict, model_from_dict),
    "plan_registry": (plan_registry_to_dict, plan_registry_from_dict),
    "pareto_front": (pareto_front_to_dict, pareto_front_from_dict),
}


def dumps_payload(kind: str, payload: dict, **dump_kw) -> str:
    """Envelope + strict-JSON encode a raw payload dict — the one spot
    where the document format (version field, float spelling, key
    order) is decided, shared by every artifact including the
    deployment bundle."""
    dump_kw.setdefault("sort_keys", True)
    return json.dumps(_finite(envelope(kind, payload)), **dump_kw)


def loads_payload(kind: str, s: str) -> dict:
    return open_envelope(_definite(json.loads(s)), kind)


def to_json(kind: str, obj, **dump_kw) -> str:
    """Serialize ``obj`` (one of ``plan``/``partition``/``cost_table``/
    ``cluster``/``model``) into its versioned JSON envelope."""
    enc, _ = _CODECS[kind]
    return dumps_payload(kind, enc(obj), **dump_kw)


def from_json(kind: str, s: str):
    _, dec = _CODECS[kind]
    return dec(loads_payload(kind, s))


def plan_to_json(pico: PicoPlan, **kw) -> str:
    return to_json("plan", pico, **kw)


def plan_from_json(s: str) -> PicoPlan:
    return from_json("plan", s)


def partition_to_json(pr: PartitionResult, **kw) -> str:
    return to_json("partition", pr, **kw)


def partition_from_json(s: str) -> PartitionResult:
    return from_json("partition", s)


def cost_table_to_json(t: CostTable, **kw) -> str:
    return to_json("cost_table", t, **kw)


def cost_table_from_json(s: str) -> CostTable:
    return from_json("cost_table", s)


_RESERVED_SPELLINGS = ("Infinity", "-Infinity", "NaN")


def _finite(x):
    """Recursively replace non-finite floats with their string spelling
    so the emitted document is strict JSON.  A *string* field that
    happens to equal one of the reserved spellings would be mangled
    into a float on load, so refuse it loudly instead of corrupting
    the artifact silently."""
    if isinstance(x, dict):
        return {k: _finite(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_finite(v) for v in x]
    if isinstance(x, str) and x in _RESERVED_SPELLINGS:
        raise ValueError(
            f"cannot serialize the string {x!r}: it collides with the "
            f"non-finite float spelling (rename the layer/device)")
    return encode_float(x)


def _definite(x):
    if isinstance(x, dict):
        return {k: _definite(v) for k, v in x.items()}
    if isinstance(x, list):
        return [_definite(v) for v in x]
    return decode_float(x)
