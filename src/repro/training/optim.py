"""Minimal-but-real AdamW + LR schedules (no optax dependency)."""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g,
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g),
                         state.v, grads)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mm, vv):
            mh = mm / c1
            vh = vv / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p
            return (p - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v)


def cosine_schedule(base_lr: float, warmup: int, total: int
                    ) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr
