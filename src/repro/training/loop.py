"""Training loop driver: data -> jitted step -> metrics -> checkpoints."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax

from ..models.transformer.config import ArchConfig
from ..models.transformer.model import init_params
from ..data.pipeline import TokenStream
from .optim import AdamW, cosine_schedule
from .steps import make_train_step
from . import checkpoint


@dataclass
class TrainReport:
    losses: list[float] = field(default_factory=list)
    steps: int = 0
    wall_s: float = 0.0
    tokens: int = 0

    @property
    def final_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


def train(cfg: ArchConfig, steps: int = 100, batch: int = 8, seq: int = 128,
          lr: float = 3e-4, seed: int = 0, log_every: int = 10,
          ckpt_path: str | None = None, warmup: int = 20) -> TrainReport:
    """End-to-end training on the synthetic token stream."""
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    opt = AdamW(lr=cosine_schedule(lr, warmup, steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    stream = iter(TokenStream(cfg.vocab_size, batch, seq, seed=seed))

    rep = TrainReport()
    t0 = time.time()
    for i in range(steps):
        batch_data = next(stream)
        params, opt_state, loss = step_fn(params, opt_state, batch_data)
        rep.losses.append(float(loss))
        rep.tokens += batch * seq
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"step {i:4d}  loss {float(loss):.4f}  "
                  f"({rep.tokens/ max(time.time()-t0, 1e-9):.0f} tok/s)",
                  flush=True)
    rep.steps = steps
    rep.wall_s = time.time() - t0
    if ckpt_path:
        checkpoint.save(Path(ckpt_path), params)
    return rep
