"""Minimal pytree checkpointing (msgpack-free: npz + structure json)."""

from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str | Path, tree) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    np.savez(path.with_suffix(".npz"),
             **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
    path.with_suffix(".tree").write_text(str(treedef))


def load(path: str | Path, like) -> object:
    """Restore into the structure of ``like`` (shapes must match)."""
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    leaves_like, treedef = jax.tree.flatten(like)
    leaves = [jax.numpy.asarray(data[f"leaf_{i}"])
              for i in range(len(leaves_like))]
    return jax.tree.unflatten(treedef, leaves)
