"""jit-able train / eval steps for the decoder substrate."""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..models.transformer.config import ArchConfig
from ..models.transformer.model import loss_fn
from .optim import AdamW, AdamWState


def make_train_step(cfg: ArchConfig, opt: AdamW, unroll: bool = False,
                    act_pspec=None, moe_pspec=None):
    """Returns train_step(params, opt_state, batch) -> (params, state, loss)."""

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, unroll=unroll,
                              act_pspec=act_pspec,
                              moe_pspec=moe_pspec))(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def make_eval_step(cfg: ArchConfig):
    def eval_step(params, batch):
        return loss_fn(cfg, params, batch)
    return eval_step
