"""Training substrate: optimizer, steps, loop, checkpointing."""

from .optim import AdamW, AdamWState, cosine_schedule
from .steps import make_train_step, make_eval_step

__all__ = ["AdamW", "AdamWState", "cosine_schedule", "make_train_step",
           "make_eval_step"]
