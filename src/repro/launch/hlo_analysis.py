"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a while-loop body ONCE, so a
scan-over-layers model under-reports FLOPs by ~n_layers, and fully
unrolling for analysis is intractable on this host (hours of XLA time
for 50-layer models at 512-way SPMD).  Instead we parse the scheduled
post-SPMD HLO text:

* split the module into computations; build a per-computation symbol
  table (op name -> result type) so name-referenced operands resolve,
* build the call graph (fusion `calls=`, `to_apply=`, while
  `body=`/`condition=`),
* read each while loop's trip count from its
  ``backend_config known_trip_count`` (fallback: the s32 constant in
  the loop condition),
* propagate execution multipliers from ENTRY,
* dot FLOPs = 2 * numel(result) * contraction size (lhs shape +
  lhs_contracting_dims); collective bytes from result shapes; HBM
  traffic from fusion/dot operand+result bytes.

The census is exact up to the loop structure the compiler kept, and
doubles as the per-computation profile used by the §Perf iterations.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPLINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]+?)\s+([\w\-]+)\((.*)$")
_CALLS = re.compile(
    r"(calls|to_apply|body|condition)=%?([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[":{]+n["\s:]+\"?(\d+)')
_OPERAND = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "token": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _numel(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes_all(text: str) -> int:
    total = 0
    for m in _SHAPE.finditer(text):
        dt, dims = m.groups()
        total += _numel(dims) * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclass
class Op:
    name: str
    result: str
    opcode: str
    rest: str
    is_root: bool = False

    @property
    def operand_names(self) -> list[str]:
        args = self.rest.split(")", 1)[0]
        return _OPERAND.findall(args)


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)
    whiles: list[tuple[str, str | None, int]] = field(default_factory=list)
    callees: list[str] = field(default_factory=list)


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    comment = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment.sub("", raw.rstrip())
        s = line.strip()
        if not s or s.startswith("HloModule") or s.startswith("//"):
            continue
        if not line.startswith(" ") and s.endswith("{") and "(" in s:
            name_m = re.match(r"(ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
            if name_m:
                cur = Computation(name_m.group(2))
                comps[cur.name] = cur
                if name_m.group(1):
                    entry = cur.name
            continue
        if s == "}" or cur is None:
            continue
        m = _OPLINE.match(line)
        if not m:
            continue
        name, result, opcode, rest = m.groups()
        op = Op(name, result.strip(), opcode, rest,
                is_root=s.startswith("ROOT"))
        cur.ops.append(op)
        cur.symbols[name] = op.result
        if opcode == "while":
            body = cond = None
            for cm in _CALLS.finditer(rest):
                if cm.group(1) == "body":
                    body = cm.group(2)
                elif cm.group(1) == "condition":
                    cond = cm.group(2)
            tm = _TRIP.search(rest)
            trips = int(tm.group(1)) if tm else 0
            if body:
                cur.whiles.append((body, cond, trips))
        else:
            for cm in _CALLS.finditer(rest):
                cur.callees.append(cm.group(2))
    if entry is None:
        entry = next((n for n in comps if n.startswith("main")),
                     next(iter(comps), ""))
    return comps, entry


def _cond_trip(comps, cond_name) -> int:
    if not cond_name or cond_name not in comps:
        return 1
    for op in comps[cond_name].ops:
        if op.opcode == "constant" and op.result.startswith("s32[]"):
            mm = re.search(r"\((\-?\d+)\)", op.rest)
            if mm:
                return max(1, int(mm.group(1)))
    return 1


def _dot_flops(op: Op, symbols: dict[str, str]) -> float:
    rm = _SHAPE.search(op.result)
    if not rm:
        return 0.0
    out_elems = _numel(rm.group(2))
    ops = op.operand_names
    if not ops:
        return 0.0
    lhs_type = symbols.get(ops[0], "")
    lm = _SHAPE.search(lhs_type)
    cm = _CONTRACT.search(op.rest)
    if not lm or not cm:
        return 0.0
    ldims = [int(x) for x in lm.group(2).split(",")] if lm.group(2) else []
    contract = 1
    if cm.group(1):
        for c in cm.group(1).split(","):
            ci = int(c)
            if ci < len(ldims):
                contract *= ldims[ci]
    return 2.0 * out_elems * contract


def _operand_bytes(op: Op, symbols: dict[str, str]) -> int:
    return sum(_shape_bytes_all(symbols.get(n, ""))
               for n in op.operand_names)


def _fusion_bytes(op: Op, symbols: dict[str, str],
                  comps: dict[str, "Computation"]) -> int:
    """HBM traffic of one fusion execution.

    An operand that is only dynamic-sliced inside the fusion touches
    only the slice, not the whole buffer (crucial for loop-carried KV
    caches / scan stacks: counting the full array per iteration inflates
    bytes by the trip count).  Likewise a dynamic-update-slice ROOT
    writes only the update (the output buffer is aliased in-place).
    """
    callee = None
    for cm in _CALLS.finditer(op.rest):
        if cm.group(1) == "calls":
            callee = comps.get(cm.group(2))
            break
    out_bytes = _shape_bytes_all(op.result)
    if callee is None:
        return out_bytes + _operand_bytes(op, symbols)

    # parameter index -> name, and users of each parameter
    params: dict[int, str] = {}
    users: dict[str, list[Op]] = {}
    for o in callee.ops:
        if o.opcode == "parameter":
            mm = re.search(r"^(\d+)\)?", o.rest)
            if mm:
                params[int(mm.group(1))] = o.name
        for nm in o.operand_names:
            users.setdefault(nm, []).append(o)

    total = 0
    for i, nm in enumerate(op.operand_names):
        full = _shape_bytes_all(symbols.get(nm, ""))
        pname = params.get(i)
        if pname is not None:
            uops = users.get(pname, [])
            if uops and all(u.opcode == "dynamic-slice" for u in uops):
                total += sum(_shape_bytes_all(u.result) for u in uops)
                continue
            if uops and all(u.opcode == "dynamic-update-slice"
                            and u.operand_names
                            and u.operand_names[0] == pname
                            for u in uops):
                # buffer only updated in place: negligible read traffic
                continue
        total += full

    root = next((o for o in callee.ops if o.is_root), None)
    if root is not None and root.opcode == "dynamic-update-slice" \
            and len(root.operand_names) >= 2:
        out_bytes = _shape_bytes_all(
            callee.symbols.get(root.operand_names[1], ""))
    return total + out_bytes


@dataclass
class HloCensus:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict[str, float] = field(default_factory=dict)
    coll_counts: dict[str, float] = field(default_factory=dict)
    by_computation: dict[str, dict] = field(default_factory=dict)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def analyze_hlo(text: str) -> HloCensus:
    comps, entry = parse_hlo(text)

    mult: dict[str, float] = {entry: 1.0}
    queue = [entry]
    while queue:
        cname = queue.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 0.0)
        for body, cond, trips in comp.whiles:
            t = trips or _cond_trip(comps, cond)
            for callee, tt in ((body, t), (cond, t + 1)):
                if callee in comps:
                    before = mult.get(callee, 0.0)
                    mult[callee] = before + m * tt
                    if before == 0.0:
                        queue.append(callee)
        for callee in comp.callees:
            if callee in comps:
                before = mult.get(callee, 0.0)
                mult[callee] = before + m
                if before == 0.0:
                    queue.append(callee)

    census = HloCensus()
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        cflops = cbytes = ccoll = 0.0
        for op in comp.ops:
            if op.opcode in ("dot", "convolution"):
                cflops += _dot_flops(op, comp.symbols)
                cbytes += (_shape_bytes_all(op.result)
                           + _operand_bytes(op, comp.symbols))
            elif op.opcode == "fusion":
                cbytes += _fusion_bytes(op, comp.symbols, comps)
            elif op.opcode in COLLECTIVES:
                b = _shape_bytes_all(op.result)
                census.coll_bytes[op.opcode] = \
                    census.coll_bytes.get(op.opcode, 0.0) + b * m
                census.coll_counts[op.opcode] = \
                    census.coll_counts.get(op.opcode, 0.0) + m
                ccoll += b
                cbytes += b
        census.flops += cflops * m
        census.hbm_bytes += cbytes * m
        if cflops or ccoll:
            census.by_computation[cname] = {
                "mult": m, "flops": cflops * m, "coll_bytes": ccoll * m}
    return census
