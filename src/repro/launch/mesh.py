"""Production mesh builders (functions only — importing this module never
touches jax device state)."""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-portable ``jax.make_mesh`` with Auto axis types.

    jax >= 0.6 takes ``axis_types``; older releases have neither the
    kwarg nor ``jax.sharding.AxisType`` (Auto is the only behavior).
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading pod=2 axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(n_devices: int | None = None, axes=("data", "model")):
    """Small mesh over however many (host) devices exist — for tests."""
    n = n_devices or len(jax.devices())
    a = 1
    while n % 2 == 0 and a * 2 <= n ** 0.5 + 1:
        a *= 2
        n //= 2
    shape = (a, (n_devices or len(jax.devices())) // a)
    return make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    """Axes used for data parallelism on this mesh."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
