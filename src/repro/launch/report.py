"""Generate the §Dry-run / §Roofline markdown tables from the dry-run
JSON artifacts.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path


def load(dirpath: str):
    recs = []
    for f in sorted(glob.glob(f"{dirpath}/*.json")):
        recs.append(json.loads(Path(f).read_text()))
    return recs


def fmt_s(x: float) -> str:
    return f"{x:.2e}"


def roofline_table(recs, mesh="pod1") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | model FLOPs/dev | useful ratio | what would move the "
           "dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    hints = {
        ("collective", "train"): "shard experts/FFN to cut all-reduce "
                                 "volume; overlap grads with compute",
        ("collective", "decode"): "keep KV local (shard batch not heads); "
                                  "pipeline layers over pods",
        ("collective", "prefill"): "sequence-shard attention (ring) to "
                                   "avoid activation all-gathers",
        ("memory", "train"): "fuse mask/softmax (less HBM traffic), bf16 "
                             "master copies, larger per-step compute",
        ("memory", "decode"): "batch more sequences per step to amortize "
                              "weight reads (decode is weight-bound)",
        ("memory", "prefill"): "larger attention blocks / fused kernels to "
                               "raise arithmetic intensity",
        ("compute", "train"): "near roofline: only kernel-level gains left",
        ("compute", "prefill"): "near roofline: only kernel-level gains",
        ("compute", "decode"): "near roofline",
    }
    for r in recs:
        if r.get("mesh") != mesh or not r.get("ok"):
            continue
        ro = r["roofline"]
        hint = hints.get((ro["dominant"], r["kind"]), "")
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(ro['compute_s'])} | "
            f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
            f"**{ro['dominant']}** | {fmt_s(r['model_flops_per_device'])} | "
            f"{r['useful_flops_ratio']:.2f} | {hint} |")
    return "\n".join(out)


def dryrun_table(recs) -> str:
    out = ["| arch | shape | mesh | lower+compile s | arg GB/dev | "
           "temp GB/dev | HLO FLOPs/dev | coll bytes/dev | collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAILED: {r.get('error','')} | | | | | |")
            continue
        ro = r["roofline"]
        counts = ro["coll_breakdown"]["counts"]
        csum = ", ".join(f"{k.split('-')[-1][:4]}:{int(v)}"
                         for k, v in sorted(counts.items()) if v)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['lower_s'] + r['compile_s']:.0f} | "
            f"{r['memory']['argument_bytes']/1e9:.2f} | "
            f"{r['memory']['temp_bytes']/1e9:.2f} | "
            f"{fmt_s(ro['flops'])} | {fmt_s(ro['coll_bytes'])} | {csum} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--kind", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--mesh", default="pod1")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.kind == "roofline":
        print(roofline_table(recs, args.mesh))
    else:
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
