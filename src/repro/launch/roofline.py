"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / peak_FLOP/s          (197e12 bf16)
    memory     = HLO_bytes / HBM_bw               (819e9 B/s)
    collective = collective_bytes / link_bw       (~50e9 B/s)

``cost_analysis`` provides per-device FLOPs/bytes; collective bytes are
parsed out of the post-SPMD HLO text (operand shapes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, asdict

TPU_PEAK_FLOPS = 197e12
TPU_HBM_BW = 819e9
TPU_ICI_BW = 50e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one 'dtype[dims]' string."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op, per kind.

    HLO lines look like:
      %ag = bf16[8,128]{1,0} all-gather(bf16[8,8]{1,0} %x), ...
    We count the op's *result* bytes (the traffic actually moved; for
    tuples, the sum of elements).
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for kind in _COLLECTIVES:
            # match result shape:  "%name = <shape> kind(" or tuple
            idx = ls.find(f" {kind}(")
            if idx < 0 or "=" not in ls[:idx]:
                continue
            lhs = ls[:idx]
            rhs = lhs.split("=", 1)[1].strip()
            total = 0
            if rhs.startswith("("):  # tuple shape
                for m in _SHAPE_RE.finditer(rhs):
                    total += _shape_bytes(m.group(0))
            else:
                m = _SHAPE_RE.match(rhs)
                if m:
                    total = _shape_bytes(m.group(0))
            out[kind] += total
            count[kind] += 1
            break
    out["_counts"] = count
    return out


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    coll_breakdown: dict

    @property
    def compute_s(self) -> float:
        return self.flops / TPU_PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / TPU_HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / TPU_ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def analyze(compiled) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # some versions return [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    text = compiled.as_text()
    cb = collective_bytes(text)
    counts = cb.pop("_counts")
    return Roofline(flops, byts, float(sum(cb.values())),
                    {"bytes": cb, "counts": counts})
