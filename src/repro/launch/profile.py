"""Profile a saved dry-run HLO: top computations by FLOPs / collective
bytes and the biggest individual collective ops — the evidence base for
each §Perf hypothesis.

    PYTHONPATH=src python -m repro.launch.profile \
        experiments/dryrun/mixtral-8x7b__train_4k__pod1.hlo.gz
"""

from __future__ import annotations

import argparse
import gzip
from pathlib import Path

from .hlo_analysis import (COLLECTIVES, analyze_hlo, parse_hlo,
                           _shape_bytes_all)
from .roofline import TPU_PEAK_FLOPS, TPU_HBM_BW, TPU_ICI_BW


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo", help=".hlo.gz (or plain text) file")
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()
    path = Path(args.hlo)
    text = (gzip.open(path, "rt").read() if path.suffix == ".gz"
            else path.read_text())

    census = analyze_hlo(text)
    print(f"totals: {census.flops:.3e} FLOPs "
          f"({census.flops/TPU_PEAK_FLOPS:.3f} s)   "
          f"{census.hbm_bytes:.3e} HBM B "
          f"({census.hbm_bytes/TPU_HBM_BW:.3f} s)   "
          f"{census.total_coll_bytes:.3e} coll B "
          f"({census.total_coll_bytes/TPU_ICI_BW:.3f} s)")
    print("\ncollectives by kind:")
    for k, v in sorted(census.coll_bytes.items(), key=lambda kv: -kv[1]):
        print(f"  {k:20s} {v:.3e} B  x{int(census.coll_counts[k])}")

    print(f"\ntop {args.top} computations by FLOPs:")
    rows = sorted(census.by_computation.items(),
                  key=lambda kv: -kv[1]["flops"])[:args.top]
    for n, d in rows:
        print(f"  {n[:56]:56s} mult={d['mult']:8.0f} "
              f"flops={d['flops']:.3e} coll={d['coll_bytes']:.3e}")

    print(f"\ntop {args.top} individual collective ops:")
    comps, _ = parse_hlo(text)
    ops = []
    for cn, comp in comps.items():
        for op in comp.ops:
            if op.opcode in COLLECTIVES:
                ops.append((_shape_bytes_all(op.result), cn, op))
    ops.sort(key=lambda t: -t[0])
    for b, cn, op in ops[:args.top]:
        meta = op.rest[op.rest.find("op_name="):][:110]
        print(f"  {b/1e9:8.3f} GB {op.opcode:18s} in {cn[:36]:36s} {meta}")


if __name__ == "__main__":
    main()
