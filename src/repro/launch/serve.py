"""Serving launcher: prefill + batched decode for any assigned arch on
whatever devices exist (use the dry-run for the 512-chip mesh).

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --batch 4 --prompt-len 16 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..models.transformer import model as M
from ..serving.lm import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="run the smoke-scale variant (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=2, d_model=128)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} takes embeddings; use the dry-run "
                         "for its serve_step")
    print(f"arch {cfg.name}: {cfg.param_count()/1e6:.1f} M params, "
          f"{len(jax.devices())} device(s)")
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    toks = generate(cfg, params, prompt, args.new_tokens,
                    temperature=args.temperature)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    print("sample:", toks[0].tolist())


if __name__ == "__main__":
    main()
