"""Training launcher: distributed train loop with the production
sharding rules on whatever mesh the host provides.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --steps 50 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..data.pipeline import TokenStream
from ..models.transformer import model as M
from ..training.optim import AdamW, cosine_schedule
from ..training.steps import make_train_step
from .mesh import make_test_mesh, batch_axes
from .sharding import param_pspecs, batch_pspecs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced(n_layers=2, d_model=128)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} takes embeddings; "
                         "train it via the dry-run path")
    mesh = make_test_mesh()
    daxes = batch_axes(mesh)
    print(f"arch {cfg.name} ({cfg.param_count()/1e6:.1f} M params) on "
          f"mesh {dict(mesh.shape)}")

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    pspec = param_pspecs(cfg, params, mesh)
    params = jax.device_put(params, jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec,
        is_leaf=lambda x: isinstance(x, P)))
    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps))
    state = opt.init(params)
    stream = iter(TokenStream(cfg.vocab_size, args.batch, args.seq,
                              seed=args.seed))
    with mesh:
        step = jax.jit(make_train_step(cfg, opt))
        t0 = time.time()
        for i in range(args.steps):
            batch = next(stream)
            params, state, loss = step(params, state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(loss):.4f}", flush=True)
    toks = args.steps * args.batch * args.seq
    print(f"done: {toks/(time.time()-t0):.0f} tok/s")


if __name__ == "__main__":
    main()
