import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

For each combination this builds the real step function (train_step for
train_4k, prefill for prefill_32k, serve/decode_step for decode shapes),
lowers it with ShapeDtypeStruct stand-ins under the production mesh,
compiles, and records memory_analysis + cost_analysis + roofline terms
to experiments/dryrun/*.json (resumable; one JSON per combo).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape decode_32k --multi-pod
"""

import argparse
import gzip
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..compat import set_mesh
from ..configs.shapes import SHAPES, input_specs, arch_for_shape
from ..models.transformer import model as M
from ..training.optim import AdamW
from ..training.steps import make_train_step
from .mesh import make_production_mesh, batch_axes
from .sharding import param_pspecs, batch_pspecs, cache_pspecs
from .roofline import Roofline
from .hlo_analysis import analyze_hlo

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

DTYPE = jnp.bfloat16


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_lowered(arch_name: str, shape_name: str, multi_pod: bool,
                  extra_opts: dict | None = None):
    """Build + lower the step for one combo; returns (lowered, meta).

    extra_opts (the §Perf levers, all default-off = paper-baseline):
      seqshard  — shard the (B, S, d) activations' sequence dim over
                  'model' (sequence parallelism)
      cacheseq  — shard the decode KV cache's sequence dim over 'model'
                  (flash-decoding-style split)
    """
    opts = extra_opts or {}
    cfg = configs.get(arch_name)
    shape = SHAPES[shape_name]
    cfg = arch_for_shape(cfg, shape)
    if opts.get("headpad") and cfg.n_heads and cfg.n_heads % 16:
        # Perf lever: pad attention heads to a multiple of the model
        # axis so GSPMD shards them fully instead of replicating.
        # Logically identity: the padded heads' wo rows are zero (here,
        # random-init dry-run, the layout is what matters).
        from dataclasses import replace as _rep
        pad = lambda h: ((h + 15) // 16) * 16
        cfg = _rep(cfg, n_heads=pad(cfg.n_heads),
                   n_kv_heads=pad(cfg.n_kv_heads),
                   head_dim=cfg.hd, name=f"{cfg.name}-headpad")

    mesh = make_production_mesh(multi_pod=multi_pod)
    daxes = batch_axes(mesh)
    specs = input_specs(cfg, shape, DTYPE)

    params_shape = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0), dtype=DTYPE))
    p_specs = param_pspecs(cfg, params_shape, mesh)
    p_shard = _named(mesh, p_specs)
    act_pspec = None
    if opts.get("seqshard") and shape.seq_len % mesh.shape["model"] == 0:
        act_pspec = P(daxes, "model", None)
    moe_pspec = P(daxes, None, None, None) if opts.get("moeshard") else None
    ring = ("model", mesh.shape["model"]) if opts.get("ring") else None

    with mesh, set_mesh(mesh):
        if shape.kind == "train":
            opt = AdamW(lr=1e-4)
            opt_shape = jax.eval_shape(lambda: opt.init(params_shape))
            # opt state mirrors params (m, v) + a scalar step
            from ..training.optim import AdamWState
            o_specs = AdamWState(P(), param_pspecs(cfg, opt_shape.m, mesh),
                                 param_pspecs(cfg, opt_shape.v, mesh))
            b_specs = batch_pspecs(cfg, specs, mesh, daxes)
            step = make_train_step(cfg, opt, act_pspec=act_pspec,
                                   moe_pspec=moe_pspec)
            jitted = jax.jit(step, in_shardings=(
                p_shard, _named(mesh, o_specs), _named(mesh, b_specs)))
            lowered = jitted.lower(params_shape, opt_shape, specs)
        elif shape.kind == "prefill":
            b_specs = batch_pspecs(cfg, specs, mesh, daxes)
            fn = lambda p, b: M.prefill(cfg, p, b, act_pspec=act_pspec,
                                        moe_pspec=moe_pspec, ring=ring)
            jitted = jax.jit(fn, in_shardings=(p_shard,
                                               _named(mesh, b_specs)))
            lowered = jitted.lower(params_shape, specs)
        else:  # decode
            c_specs = cache_pspecs(cfg, specs["cache"], mesh, daxes,
                                   mode="sequence" if opts.get("cacheseq")
                                   else "feature")
            i_specs = batch_pspecs(cfg, specs["inputs"], mesh, daxes)
            fn = lambda p, c, i: M.decode_step(cfg, p, c, i)
            jitted = jax.jit(fn, in_shardings=(
                p_shard, _named(mesh, c_specs), _named(mesh, i_specs)))
            lowered = jitted.lower(params_shape, specs["cache"],
                                   specs["inputs"])
    meta = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "variant": cfg.name,
    }
    return lowered, meta, mesh


def run_combo(arch_name: str, shape_name: str, multi_pod: bool,
              out_dir: Path = OUT_DIR, force: bool = False,
              save_hlo: bool = False, opts: dict | None = None) -> dict:
    mesh_tag = "pod2" if multi_pod else "pod1"
    opt_tag = ("__" + "+".join(sorted(k for k, v in (opts or {}).items()
                                      if v))) if opts else ""
    out = out_dir / f"{arch_name}__{shape_name}__{mesh_tag}{opt_tag}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())
    out_dir.mkdir(parents=True, exist_ok=True)
    rec: dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_tag,
                 "opts": sorted(k for k, v in (opts or {}).items() if v)}
    t0 = time.time()
    try:
        lowered, meta, mesh = build_lowered(arch_name, shape_name, multi_pod,
                                            extra_opts=opts)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        if save_hlo:
            with gzip.open(out.with_suffix(".hlo.gz"), "wt") as fh:
                fh.write(hlo_text)
        census = analyze_hlo(hlo_text)
        roof = Roofline(census.flops, census.hbm_bytes,
                        census.total_coll_bytes,
                        {"bytes": census.coll_bytes,
                         "counts": census.coll_counts})
        rec.update(meta)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", 0),
            },
            "roofline": roof.to_dict(),
        })
        # MODEL_FLOPS = 6 N D (dense) / 6 N_active D — per device
        shape = SHAPES[shape_name]
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind == "train" else
                                       shape.seq_len if shape.kind == "prefill"
                                       else 1)
        n_act = rec["active_params"]
        mult = 6 if shape.kind == "train" else 2
        rec["model_flops_per_device"] = mult * n_act * tokens / meta["n_devices"]
        hlo_flops = rec["roofline"]["flops"]
        rec["useful_flops_ratio"] = (rec["model_flops_per_device"] /
                                     hlo_flops if hlo_flops else 0.0)
    except Exception as e:  # record the failure; the sweep continues
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    rec["wall_s"] = round(time.time() - t0, 2)
    out.write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="sweep all arch x shape x {1,2} pods")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma list of perf levers: seqshard,cacheseq,moeshard,headpad,ring")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        combos = [(a, s, mp)
                  for a in configs.ARCH_NAMES
                  for s in SHAPES
                  for mp in (False, True)]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape, args.multi_pod)]

    n_ok = 0
    for a, s, mp in combos:
        opts = {k: True for k in args.opt.split(",") if k}
        rec = run_combo(a, s, mp, out_dir, force=args.force,
                        save_hlo=args.save_hlo, opts=opts)
        ok = rec.get("ok")
        n_ok += bool(ok)
        tag = "OK " if ok else "FAIL"
        extra = (f"flops={rec['roofline']['flops']:.3g} "
                 f"dom={rec['roofline']['dominant']}" if ok
                 else rec.get("error", ""))
        print(f"[{tag}] {a:22s} {s:12s} {'pod2' if mp else 'pod1'} "
              f"({rec['wall_s']}s) {extra}", flush=True)
    print(f"{n_ok}/{len(combos)} combos OK")


if __name__ == "__main__":
    main()
