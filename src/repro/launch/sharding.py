"""PartitionSpec assignment for the decoder substrate on the production
mesh (baseline tensor-parallel + data-parallel layout; DESIGN.md §5).

Rules (model axis = 'model', batch over ('pod','data') where divisible):
  * embedding/head: padded-vocab dim over 'model'
  * attention projections: flattened head*dim output over 'model'
  * FFN/expert hidden dim over 'model'
  * mamba inner projections: d_inner-derived dims over 'model' where
    divisible, else replicated
  * caches/activations: batch over data axes; head_dim or kv-heads over
    'model' where divisible
Dims that do not divide the axis size are replicated (a helper checks).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.transformer.config import ArchConfig
from ..models.transformer.layers import (AttnParams, MlpParams, MoeParams,
                                         MambaParams)


def _div(n: int, mesh, axis: str) -> bool:
    return axis in mesh.shape and n % mesh.shape[axis] == 0


def _spec(shapes, mesh, dim_axis: dict[int, str]) -> P:
    """P with axis per dim if divisible, else None."""
    parts = [None] * len(shapes)
    for d, ax in dim_axis.items():
        if _div(shapes[d], mesh, ax):
            parts[d] = ax
    return P(*parts)


def param_pspecs(cfg: ArchConfig, params_shape: Any, mesh) -> Any:
    """PartitionSpec pytree matching a params pytree (of ShapeDtypeStruct
    or arrays)."""

    def leaf_spec(path: tuple, leaf) -> P:
        sh = leaf.shape
        names = [getattr(p, "name", getattr(p, "key", None)) or str(p)
                 for p in path]
        key = "/".join(str(n) for n in names)
        nd = len(sh)
        if "embed" in key:
            return _spec(sh, mesh, {0: "model"})
        if "head" in key:
            return _spec(sh, mesh, {1: "model"})
        if "moe" in key:
            if key.endswith("router"):
                return P(*([None] * nd))
            if key.endswith("w2"):
                return _spec(sh, mesh, {nd - 2: "model"})
            return _spec(sh, mesh, {nd - 1: "model"})
        if "attn" in key:
            if key.endswith("wo"):
                return _spec(sh, mesh, {nd - 2: "model"})
            if any(key.endswith(s) for s in ("wq", "wk", "wv", "bq", "bk",
                                             "bv")):
                return _spec(sh, mesh, {nd - 1: "model"})
        if "mlp" in key:
            if key.endswith("w2"):
                return _spec(sh, mesh, {nd - 2: "model"})
            return _spec(sh, mesh, {nd - 1: "model"})
        if "mamba" in key:
            if key.endswith("w_in"):
                return _spec(sh, mesh, {nd - 1: "model"})
            if key.endswith("w_out"):
                return _spec(sh, mesh, {nd - 2: "model"})
            if key.endswith(("conv_w", "conv_b", "norm_w")):
                return _spec(sh, mesh, {nd - 1: "model"})
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def batch_pspecs(cfg: ArchConfig, batch_shape: Any, mesh,
                 data_axes: tuple[str, ...]) -> Any:
    """Specs for a train/prefill batch dict."""
    total = 1
    for a in data_axes:
        total *= mesh.shape[a]

    def leaf_spec(path, leaf):
        sh = leaf.shape
        b_ax = data_axes if sh and sh[0] % total == 0 else None
        if b_ax is None:
            return P(*([None] * len(sh)))
        return P(b_ax, *([None] * (len(sh) - 1)))

    return jax.tree_util.tree_map_with_path(leaf_spec, batch_shape)


def cache_pspecs(cfg: ArchConfig, cache_shape: Any, mesh,
                 data_axes: tuple[str, ...],
                 mode: str = "feature") -> Any:
    """Specs for a decode cache: batch on dim 1 (dim 0 is layers), plus

    mode='feature'  — shard the last (head_dim-ish) dim over 'model'
                      (baseline layout), or
    mode='sequence' — shard the KV cache's sequence dim (dim 2) over
                      'model' (§Perf iteration 3: flash-decoding-style
                      partitioning; attention contracts locally over the
                      sequence shard and all-reduces only the small
                      softmax stats/output instead of all-gathering the
                      multi-GB cache every layer).
    """
    total = 1
    for a in data_axes:
        total *= mesh.shape[a]

    def leaf_spec(path, leaf):
        sh = leaf.shape
        names = "/".join(str(getattr(p, "name", getattr(p, "key", p)))
                         for p in path)
        nd = len(sh)
        if nd == 0:
            return P()
        parts = [None] * nd
        if nd >= 2 and sh[1] % total == 0 and sh[1] >= total:
            parts[1] = data_axes
        is_kv = names in ("k", "v") or names.endswith(("/k", "/v")) \
            or "shared_k" in names or "shared_v" in names
        if mode == "sequence" and is_kv and nd >= 3 \
                and _div(sh[2], mesh, "model"):
            parts[2] = "model"
            return P(*parts)
        if "k" in names or "v" in names or "ssm" in names \
                or "conv" in names:
            # shard the last (feature) dims over 'model' where divisible
            for d in range(nd - 1, 1, -1):
                if _div(sh[d], mesh, "model"):
                    parts[d] = "model"
                    break
        return P(*parts)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)
