"""Deterministic discrete-event core for the cluster runtime.

A single virtual timeline: events are ordered by (time, seq) where
``seq`` is the insertion order, so two events at the same instant fire
in the order they were scheduled — runs are bit-reproducible for a
fixed seed regardless of host timing.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from enum import Enum, auto
from typing import Any


class EventKind(Enum):
    FRAME_ARRIVAL = auto()      # frame becomes available at a stage's input
    COMPUTE_DONE = auto()       # a stage finished the compute phase
    STAGE_DONE = auto()         # compute + comm done; stage frees, data moves
    CHURN = auto()              # injected cluster change (join/leave/...)
    MIGRATION_DONE = auto()     # re-plan state transfer finished
    # multi-tenant serving control plane (serving.scheduler)
    REQUEST_ARRIVAL = auto()    # a tenant request reaches admission control
    CONTROL_TICK = auto()       # periodic load / rebalance check
    TENANT_JOIN = auto()        # a tenant joins the serving fleet
    TENANT_LEAVE = auto()       # a tenant leaves; its devices are reclaimed
    REPARTITION_DONE = auto()   # cross-tenant device migration finished


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: EventKind = field(compare=False)
    payload: dict[str, Any] = field(compare=False, default_factory=dict)
    cancelled: bool = field(compare=False, default=False)


class EventQueue:
    """Min-heap of events with lazy cancellation."""

    def __init__(self):
        self._heap: list[Event] = []
        self._seq = itertools.count()

    def push(self, time: float, kind: EventKind, **payload) -> Event:
        ev = Event(time, next(self._seq), kind, payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Event | None:
        while self._heap:
            ev = heapq.heappop(self._heap)
            if not ev.cancelled:
                return ev
        return None

    def peek(self) -> Event | None:
        """Earliest live event without removing it (cancelled entries are
        discarded on the way — heap order is unaffected)."""
        while self._heap:
            if self._heap[0].cancelled:
                heapq.heappop(self._heap)
                continue
            return self._heap[0]
        return None

    def __len__(self) -> int:
        return sum(1 for e in self._heap if not e.cancelled)

    def __bool__(self) -> bool:
        return len(self) > 0
