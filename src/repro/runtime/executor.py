"""Event-driven heterogeneous-cluster runtime for PICO pipelines.

The closed-form simulator (``core.simulate``) answers "what *should*
this plan do"; this executor answers "what does it do when devices are
actors with their own clocks, queues and memory, transfers take time on
lossy links, and the cluster changes mid-run".  One virtual timeline
drives everything (``events.EventQueue``), so runs are deterministic
and seedable.

Execution semantics per stage ``s`` and frame ``f`` (matching the
pipeline recurrence of Eq. 12 when links are ideal and devices honest):

* ``FRAME_ARRIVAL``   — f's input is available at s;
* compute phase       — every member device runs its tile; the phase
                        lasts max_k of the devices' *true* times
                        (nominal cost / DVFS speed * noise);
* comm phase          — intra-stage scatter/gather (the plan's T_comm,
                        scaled by link degradation) plus the
                        inter-stage hand-off timed by ``LinkModel``;
* ``STAGE_DONE``      — the stage frees and f arrives at s+1.

The monitor records observed-vs-nominal time per device; churn events
(join/leave/DVFS/link) and monitor drift trigger ``core.planner.replan``
on the measured-calibrated cluster at a frame boundary: in-flight
frames drain, re-assigned stages pay a parameter-migration transfer,
then frames resume at the stage covering their next unfinished piece.
"""

from __future__ import annotations

import time as _time
from collections import deque
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from ..core.cost import Cluster, CostTable
from ..core.pipeline_dp import StagePlan
from ..core.planner import PicoPlan, plan as plan_full, recost, replan
from ..core.graph import Graph
from .actors import ActorPool
from .churn import (ChurnEvent, DeviceJoin, DeviceLeave, FreqScale,
                    LinkDegrade)
from .events import EventKind, EventQueue, Event
from .links import LinkMap, LinkModel
from .monitor import Monitor


@dataclass
class RuntimeConfig:
    """Knobs for the virtual cluster.  The default is *ideal* — no
    jitter, no noise, free inter-stage hand-off — and reproduces
    ``core.simulate`` exactly; turn knobs up for realism."""

    seed: int = 0
    compute_noise: float = 0.0          # max +/- fraction on true times
    inter_stage_bandwidth: float | None = None  # None = free hand-off
    link_latency_s: float = 0.0
    link_jitter_s: float = 0.0
    mem_budget_bytes: float = float("inf")
    replan_on_churn: bool = True
    replan_on_drift: bool = True
    drift_threshold: float = 0.25
    drift_cooldown: int = 24        # monitor samples between drift re-plans
    ewma_beta: float = 0.3
    migration_bandwidth: float | None = None    # None = cluster bandwidth
    trace: bool = False

    @classmethod
    def ideal(cls, seed: int = 0) -> "RuntimeConfig":
        return cls(seed=seed)


@dataclass
class Frame:
    fid: int
    arrival: float
    next_piece: int = 0
    done: float | None = None
    restarts: int = 0
    image: object = None                # real-compute input tensor
    produced: dict = field(default_factory=dict)


@dataclass
class ReplanRecord:
    time: float
    reason: str
    wall_s: float
    old_period: float
    new_period: float
    n_devices: int
    migration_bytes: float
    migration_s: float


@dataclass
class RuntimeDeviceReport:
    device: str
    utilization: float
    busy_s: float
    frames: int
    memory_peak_bytes: float
    mem_violations: int
    energy_j: float


@dataclass
class RuntimeReport:
    frames: int
    completed: int
    period: float
    latency_first: float
    latency_mean: float
    makespan: float
    throughput_per_min: float
    devices: list[RuntimeDeviceReport]
    replans: list[ReplanRecord]
    completions: list[tuple[int, float, float]]   # (fid, arrival, done)
    restarts: int = 0
    outputs: dict[int, dict] = field(default_factory=dict)
    trace: list[tuple] = field(default_factory=list)

    @property
    def avg_utilization(self) -> float:
        live = [d for d in self.devices if d.frames > 0]
        return sum(d.utilization for d in live) / len(live) if live else 0.0

    @property
    def total_energy_j(self) -> float:
        return sum(d.energy_j for d in self.devices)

    def windowed_throughput(self, t0: float, t1: float) -> float:
        """Completed frames/s inside virtual-time window [t0, t1).

        The window closes at t1 when t1 reaches the makespan — the last
        frame completes exactly at the makespan and must count.
        """
        hi_closed = t1 >= self.makespan
        n = sum(1 for _, _, d in self.completions
                if t0 <= d and (d < t1 or (hi_closed and d <= t1)))
        return n / (t1 - t0) if t1 > t0 else 0.0


@dataclass
class _StageState:
    plan: StagePlan
    index: int
    executor: object = None             # StageExecutor in real-compute mode
    queue: deque = field(default_factory=deque)
    active: Frame | None = None
    pending: Event | None = None


class PipelineRuntime:
    def __init__(
        self,
        g: Graph | None = None,
        cluster: Cluster | None = None,
        input_size: tuple[int, int] | None = None,
        pico: PicoPlan | None = None,
        config: RuntimeConfig | None = None,
        churn: Sequence[ChurnEvent] = (),
        model=None,                     # CNNDef: real JAX compute per stage
        params=None,
        t_lim: float = float("inf"),
        backend: str | None = None,     # conv lowering for real compute
        cost_table: CostTable | None = None,  # measured costs (exec.calibrate)
    ):
        if model is not None:
            g = model.graph
            input_size = model.input_size
        if g is None or cluster is None or input_size is None:
            raise ValueError("need (g, cluster, input_size) or model=")
        self.g = g
        self.input_size = input_size
        self.cluster = cluster
        self.t_lim = t_lim
        self.model = model
        self.params = params
        self.backend = backend
        self.cost_table = cost_table
        self.config = config or RuntimeConfig()
        self.rng = np.random.default_rng(self.config.seed)
        self.pico = pico or plan_full(g, cluster, input_size, t_lim,
                                      cost_table=cost_table)
        self.monitor = Monitor(beta=self.config.ewma_beta,
                               drift_threshold=self.config.drift_threshold)
        self.pool = ActorPool(cluster.devices,
                              mem_budget_bytes=self.config.mem_budget_bytes)
        self.links = LinkMap(LinkModel(
            bandwidth=self.config.inter_stage_bandwidth,
            latency_s=self.config.link_latency_s,
            jitter_s=self.config.link_jitter_s))
        self.churn = sorted(churn, key=lambda c: c.time)
        self.replans: list[ReplanRecord] = []
        self._trace: list[tuple] = []
        # alpha ratios the current plan was built with (drift baseline)
        self._plan_ratios: dict[str, float] = {}
        self._samples_at_replan = 0
        self._build_stages()

    # ------------------------------------------------------------------
    # plan -> executable stage states
    # ------------------------------------------------------------------

    def _build_stages(self) -> None:
        self.stages = [_StageState(st, i)
                       for i, st in enumerate(self.pico.pipeline.stages)]
        if self.model is not None:
            from ..pipeline.stage import executors_from_plan
            # compiled executors: across re-plans, stages whose segment +
            # tiling survive come straight from the executable cache
            execs = executors_from_plan(self.model, self.pico.pipeline.stages,
                                        backend=self.backend)
            for st, ex in zip(self.stages, execs):
                st.executor = ex

    def _stage_for_piece(self, piece: int) -> int:
        for st in self.stages:
            if st.plan.first_piece <= piece <= st.plan.last_piece:
                return st.index
        return len(self.stages) - 1

    # ------------------------------------------------------------------
    # run loop
    # ------------------------------------------------------------------

    def run(self, n_frames: int = 64, inputs: Sequence | None = None,
            interarrival: float = 0.0,
            arrivals: Sequence[float] | None = None) -> RuntimeReport:
        if inputs is not None:
            n_frames = len(inputs)
        if arrivals is not None:
            n_frames = len(arrivals)
            if inputs is not None and len(inputs) != n_frames:
                raise ValueError("len(arrivals) != len(inputs)")
        if self.model is not None and self.params is None:
            raise ValueError("real-compute mode needs params")
        if self.model is not None and inputs is None:
            raise ValueError("real-compute mode needs inputs=")
        if getattr(self, "_ran", False):
            raise RuntimeError("PipelineRuntime is single-use: actor clocks, "
                               "monitor state and the churn schedule are "
                               "consumed — build a fresh instance")
        self._ran = True
        self.q = EventQueue()
        self._draining = False
        self._drain_reason = ""
        self._deferred_replan: str | None = None
        self._completed = 0
        self._n_frames = n_frames
        self._outputs: dict[int, dict] = {}
        frames = [Frame(i, arrival=(arrivals[i] if arrivals is not None
                                    else i * interarrival),
                        image=None if inputs is None else inputs[i])
                  for i in range(n_frames)]
        self._all_frames = frames
        for fr in frames:
            self.q.push(fr.arrival, EventKind.FRAME_ARRIVAL,
                        stage=0, frame=fr)
        for ce in self.churn:
            self.q.push(ce.time, EventKind.CHURN, churn=ce)
        now = 0.0
        while self._completed < n_frames:
            ev = self.q.pop()
            if ev is None:
                raise RuntimeError(
                    f"runtime deadlock: {self._completed}/{n_frames} frames "
                    f"done, draining={self._draining}")
            now = ev.time
            self._dispatch(ev)
        return self._report(now)

    def _dispatch(self, ev: Event) -> None:
        k = ev.kind
        if k is EventKind.FRAME_ARRIVAL:
            self._on_arrival(ev.time, ev.payload["stage"],
                             ev.payload["frame"])
        elif k is EventKind.COMPUTE_DONE:
            self._on_compute_done(ev.time, ev.payload)
        elif k is EventKind.STAGE_DONE:
            self._on_stage_done(ev.time, ev.payload)
        elif k is EventKind.CHURN:
            self._on_churn(ev.time, ev.payload["churn"])
        elif k is EventKind.MIGRATION_DONE:
            self._on_migration_done(ev.time, ev.payload)

    # ------------------------------------------------------------------
    # handlers
    # ------------------------------------------------------------------

    def _on_arrival(self, t: float, s: int, frame: Frame) -> None:
        st = self.stages[s]
        st.queue.append(frame)
        for d in st.plan.devices:
            if d.name in self.pool:
                self.pool[d.name].enqueue()
        if self.config.trace:
            self._trace.append((t, "arrival", s, frame.fid))
        self._try_start(t, s)

    def _try_start(self, t: float, s: int) -> None:
        st = self.stages[s]
        if st.active is not None or not st.queue or self._draining:
            return
        frame = st.queue.popleft()
        st.active = frame
        seg = st.plan.cost.seg
        durs, modeled = [], []
        for k, dev in enumerate(st.plan.devices):
            act = self.pool[dev.name]
            nominal = act.device.t_comp(seg.per_device_flops[k])
            noise = (float(self.rng.uniform(-1.0, 1.0))
                     * self.config.compute_noise)
            true_dur = act.compute_time(nominal, noise)
            mem = seg.param_bytes + seg.feature_bytes[k]
            act.start_work(t, true_dur, mem)
            durs.append(true_dur)
            modeled.append(nominal)
        dur = max(durs)
        if st.executor is not None:
            outs = st.executor(self.params, frame.produced, frame.image)
            frame.produced.update(outs)
        st.pending = self.q.push(t + dur, EventKind.COMPUTE_DONE,
                                 stage=s, frame=frame,
                                 modeled=modeled, observed=durs)
        if self.config.trace:
            self._trace.append((t, "compute", s, frame.fid, dur))

    def _on_compute_done(self, t: float, payload: dict) -> None:
        s, frame = payload["stage"], payload["frame"]
        st = self.stages[s]
        for dev, m, o in zip(st.plan.devices, payload["modeled"],
                             payload["observed"]):
            self.monitor.record(s, dev.name, m, o)
        hop = self.links.hop(s)
        intra = st.plan.cost.t_comm * hop.degradation
        inter = hop.transfer_time(sum(st.plan.cost.seg.out_bytes), self.rng)
        st.pending = self.q.push(t + intra + inter, EventKind.STAGE_DONE,
                                 stage=s, frame=frame)

    def _on_stage_done(self, t: float, payload: dict) -> None:
        s, frame = payload["stage"], payload["frame"]
        st = self.stages[s]
        st.active = None
        st.pending = None
        frame.next_piece = st.plan.last_piece + 1
        if self.config.trace:
            self._trace.append((t, "done", s, frame.fid))
        if s + 1 < len(self.stages):
            self.q.push(t, EventKind.FRAME_ARRIVAL, stage=s + 1, frame=frame)
        else:
            frame.done = t
            self._completed += 1
            if frame.produced and self.model is not None:
                sinks = self.model.graph.sinks()
                self._outputs[frame.fid] = {k: frame.produced[k]
                                            for k in sinks}
        if self._draining:
            if self._all_idle():
                self._do_replan(t)
            return
        if (self.config.replan_on_drift and self.monitor.samples
                and self._drift_detected()):
            self._request_replan(t, "drift")
            return
        self._try_start(t, s)

    def _drift_detected(self) -> bool:
        # let the EWMA converge before (re-)acting on it
        if (self.monitor.samples - self._samples_at_replan
                < self.config.drift_cooldown):
            return False
        # drift is relative to the ratios the current plan was built
        # with — a device *recovering* to 1.0 after a throttled plan is
        # drift too, so check every measured device, not just those far
        # from nominal
        for name, ew in self.monitor.ratio.items():
            if not ew.n:
                continue
            base = self._plan_ratios.get(name, 1.0)
            if abs(ew.value / base - 1.0) > self.config.drift_threshold:
                return True
        return False

    def _on_churn(self, t: float, ce: ChurnEvent) -> None:
        if self.config.trace:
            self._trace.append((t, "churn", type(ce).__name__))
        if isinstance(ce, LinkDegrade):
            self.links.degrade(ce.factor, ce.hop)
            return                       # plan unchanged; costs just grew
        if isinstance(ce, FreqScale):
            self.pool[ce.device_name].speed = ce.factor
            return                       # monitor will notice the drift
        if isinstance(ce, DeviceJoin):
            self.pool.add(ce.device,
                          mem_budget_bytes=self.config.mem_budget_bytes)
            if self.config.replan_on_churn:
                self._request_replan(t, "join")
            return
        if isinstance(ce, DeviceLeave):
            self.pool.remove(ce.device_name)
            self.monitor.reset_device(ce.device_name)
            # abort any in-flight work that involved the dead device
            aborted: list[int] = []
            for st in self.stages:
                if st.active is not None and any(
                        d.name == ce.device_name for d in st.plan.devices):
                    if st.pending is not None:
                        st.pending.cancelled = True
                        st.pending = None
                    st.active.restarts += 1
                    st.queue.appendleft(st.active)
                    st.active = None
                    aborted.append(st.index)
            if not self.pool.live():
                raise RuntimeError("all devices left the cluster")
            if self.config.replan_on_churn:
                self._request_replan(t, "leave")
            else:
                # no re-plan: keep executing the stale plan (the dead
                # actor's slot still ticks at its modeled rate) — the
                # aborted frames must restart here or nothing ever will
                for s_idx in aborted:
                    self._try_start(t, s_idx)

    # ------------------------------------------------------------------
    # re-planning
    # ------------------------------------------------------------------

    def _all_idle(self) -> bool:
        return all(st.active is None for st in self.stages)

    def _request_replan(self, t: float, reason: str) -> None:
        if self._draining:
            # churn landed mid-drain/mid-migration: replay it afterwards
            self._deferred_replan = self._deferred_replan or reason
            return
        self._draining = True
        self._drain_reason = reason
        if self._all_idle():
            self._do_replan(t)

    def _do_replan(self, t: float) -> None:
        wall0 = _time.perf_counter()
        alive = self.pool.alive_devices()
        next_cluster = Cluster(alive, bandwidth=self.cluster.bandwidth,
                               pair_bandwidth=dict(self.cluster.pair_bandwidth))
        calibrated = self.monitor.calibrated_cluster(next_cluster)
        old = self.pico
        # which devices used to host each piece (for migration cost)
        old_hosts: dict[int, frozenset[str]] = {}
        for st in old.pipeline.stages:
            names = frozenset(d.name for d in st.devices)
            for p in range(st.first_piece, st.last_piece + 1):
                old_hosts[p] = names
        new = replan(self.g, calibrated, self.input_size, prev=old,
                     t_lim=self.t_lim, cost_table=self.cost_table)
        # keep the incumbent plan if it is still runnable and wins when
        # both are priced with measured costs (the DP must use every
        # device, so a fresh plan can lose — e.g. after a weak join)
        alive_names = {d.name for d in alive}
        incumbent_ok = all(d.name in alive_names
                           for st in old.pipeline.stages for d in st.devices)
        if incumbent_ok:
            old_rc = recost(old.pipeline, calibrated, self.g,
                            self.input_size, cost_table=self.cost_table)
            if old_rc.period <= new.period:
                new = PicoPlan(old.partition, old_rc)
        mig_bytes = 0.0
        for st in new.pipeline.stages:
            names = frozenset(d.name for d in st.devices)
            if old_hosts.get(st.first_piece) != names:
                mig_bytes += st.cost.seg.param_bytes
        bw = self.config.migration_bandwidth or self.cluster.bandwidth
        mig_s = mig_bytes / bw + self.config.link_latency_s
        wall = _time.perf_counter() - wall0
        self.replans.append(ReplanRecord(
            t, self._drain_reason, wall, old.period, new.period,
            len(alive), mig_bytes, mig_s))
        self.pico = new
        self._plan_ratios = {d.name: self.monitor.device_ratio(d.name)
                             for d in alive}
        self._samples_at_replan = self.monitor.samples
        self.q.push(t + mig_s, EventKind.MIGRATION_DONE)

    def _collect_inflight(self) -> list[Frame]:
        """Harvest queued frames from the old stage states.

        Must run at MIGRATION_DONE time (not at re-plan time): hand-off
        arrivals scheduled in the same instant as the drain's last
        STAGE_DONE land in the old queues first.
        """
        frames: list[Frame] = []
        for st in self.stages:
            frames.extend(st.queue)
            st.queue.clear()
        frames.sort(key=lambda f: (f.next_piece == 0, f.fid))
        return frames

    def _on_migration_done(self, t: float, payload: dict) -> None:
        inflight = self._collect_inflight()
        self._build_stages()
        self._draining = False
        for frame in inflight:
            s = self._stage_for_piece(frame.next_piece)
            self.q.push(t, EventKind.FRAME_ARRIVAL, stage=s, frame=frame)
        if self.config.trace:
            self._trace.append((t, "migrated", len(inflight)))
        if self._deferred_replan is not None:
            reason, self._deferred_replan = self._deferred_replan, None
            self._request_replan(t, reason)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def _report(self, now: float) -> RuntimeReport:
        done = sorted((f.fid, f.arrival, f.done) for f in self._all_frames
                      if f.done is not None)
        times = [d for _, _, d in done]
        makespan = max(times) if times else now
        if len(times) >= 2:
            period = (times[-1] - times[0]) / (len(times) - 1)
        else:
            period = times[0] if times else 0.0
        lat = [d - a for _, a, d in done]
        devs = [RuntimeDeviceReport(
            a.name, a.utilization(makespan), a.busy_s, a.frames_done,
            a.mem_peak_bytes, a.mem_violations, a.energy_j(makespan))
            for a in self.pool.actors.values()]
        return RuntimeReport(
            frames=self._n_frames,
            completed=self._completed,
            period=period,
            latency_first=lat[0] if lat else 0.0,
            latency_mean=sum(lat) / len(lat) if lat else 0.0,
            makespan=makespan,
            throughput_per_min=60.0 / period if period > 0 else 0.0,
            devices=devs,
            replans=list(self.replans),
            completions=done,
            restarts=sum(f.restarts for f in self._all_frames),
            outputs=self._outputs,
            trace=list(self._trace),
        )
