"""Event-driven heterogeneous-cluster runtime for PICO pipelines.

The closed-form simulator (``core.simulate``) answers "what *should*
this plan do"; this executor answers "what does it do when devices are
actors with their own clocks, queues and memory, transfers take time on
lossy links, and the cluster changes mid-run".  One virtual timeline
drives everything (``events.EventQueue``), so runs are deterministic
and seedable.

Execution semantics per stage ``s`` and frame ``f`` (matching the
pipeline recurrence of Eq. 12 when links are ideal and devices honest):

* ``FRAME_ARRIVAL``   — f's input is available at s;
* compute phase       — every member device runs its tile; the phase
                        lasts max_k of the devices' *true* times
                        (nominal cost / DVFS speed * noise);
* comm phase          — intra-stage scatter/gather (the plan's T_comm,
                        scaled by link degradation) plus the
                        inter-stage hand-off timed by ``LinkModel``;
* ``STAGE_DONE``      — the stage frees and f arrives at s+1.

The monitor records observed-vs-nominal time per device; churn events
(join/leave/DVFS/link) and monitor drift trigger ``core.planner.replan``
on the measured-calibrated cluster at a frame boundary: in-flight
frames drain, re-assigned stages pay a parameter-migration transfer,
then frames resume at the stage covering their next unfinished piece.

Two serving extensions (used by ``serving.scheduler``):

* **continuous micro-batching** — with ``RuntimeConfig.max_batch > 1``
  stage 0 coalesces its queued frames into one batch whenever it goes
  idle; the batch travels the pipeline as a unit, compute/comm phases
  scale with the batch size, and real numerics go through the compiled
  ``StageExecutor.run_frames`` scan path.  Queued frames whose
  ``deadline`` has passed are dropped at coalesce time.
* **stream mode** — ``begin_stream()`` + ``admit()`` + ``step()`` let an
  external driver (the multi-tenant scheduler) feed frames dynamically,
  interleave several runtimes on one virtual timeline, ``pause()``
  launches to drain, and ``harvest()`` queued frames for re-admission
  after a cross-tenant re-partition.
"""

from __future__ import annotations

import time as _time
from collections import deque
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from ..api._compat import _UNSET, pick, unset, warn_legacy
from ..api.specs import ExecSpec, PlanSpec
from ..core.cost import Cluster, CostTable
from ..core.pipeline_dp import PlannerCache, StagePlan
from ..core.planner import PicoPlan, plan_with_spec, recost
from ..core.graph import Graph
from ..obs import trace as obs_trace
from ..obs.metrics import MetricsRegistry, NULL_REGISTRY
from ..obs.trace import NULL_TRACER, Tracer
from .actors import ActorPool
from .churn import (ChurnEvent, DeviceJoin, DeviceLeave, FreqScale,
                    LinkDegrade)
from .events import EventKind, EventQueue, Event
from .links import LinkMap, LinkModel
from .monitor import Monitor


@dataclass
class RuntimeConfig:
    """Knobs for the virtual cluster.  The default is *ideal* — no
    jitter, no noise, free inter-stage hand-off — and reproduces
    ``core.simulate`` exactly; turn knobs up for realism."""

    seed: int = 0
    compute_noise: float = 0.0          # max +/- fraction on true times
    inter_stage_bandwidth: float | None = None  # None = free hand-off
    link_latency_s: float = 0.0
    link_jitter_s: float = 0.0
    mem_budget_bytes: float = float("inf")
    replan_on_churn: bool = True
    replan_on_drift: bool = True
    drift_threshold: float = 0.25
    drift_cooldown: int = 24        # monitor samples between drift re-plans
    ewma_beta: float = 0.3
    migration_bandwidth: float | None = None    # None = cluster bandwidth
    max_batch: int = 1              # stage-0 coalescing cap (1 = no batching)
    trace: bool = False             # record structured spans (repro.obs)
    metrics: bool = True            # publish runtime metrics (repro.obs)

    @classmethod
    def ideal(cls, seed: int = 0) -> "RuntimeConfig":
        return cls(seed=seed)


@dataclass
class Frame:
    fid: int
    arrival: float
    next_piece: int = 0
    done: float | None = None
    restarts: int = 0
    image: object = None                # real-compute input tensor
    produced: dict = field(default_factory=dict)
    deadline: float | None = None       # drop if still queued past this
    dropped: bool = False               # deadline expired before launch


def coalesce(queue: deque, now: float, max_batch: int):
    """Pop up to ``max_batch`` items off ``queue`` (FIFO), expiring any
    whose ``deadline`` attribute is set and already past ``now``.

    Returns ``(batch, expired)``.  Expired items do not count against
    ``max_batch``; arrival order is preserved in both lists.  This is
    the batch-formation primitive for stage-0 continuous batching;
    ``serving.queueing`` re-exports it for the policy-level API.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    batch, expired = [], []
    while queue and len(batch) < max_batch:
        item = queue.popleft()
        deadline = getattr(item, "deadline", None)
        if deadline is not None and now > deadline:
            expired.append(item)
        else:
            batch.append(item)
    return batch, expired


@dataclass
class _Batch:
    """A cohort of frames coalesced at stage 0 that travels the pipeline
    as one scheduling unit (one ``run_frames`` dispatch per stage)."""

    frames: list

    def __len__(self) -> int:
        return len(self.frames)


@dataclass
class ReplanRecord:
    time: float
    reason: str
    wall_s: float
    old_period: float
    new_period: float
    n_devices: int
    migration_bytes: float
    migration_s: float


@dataclass
class RuntimeDeviceReport:
    device: str
    utilization: float
    busy_s: float
    frames: int
    memory_peak_bytes: float
    mem_violations: int
    energy_j: float


@dataclass
class RuntimeReport:
    frames: int
    completed: int
    period: float
    latency_first: float
    latency_mean: float
    makespan: float
    throughput_per_min: float
    devices: list[RuntimeDeviceReport]
    replans: list[ReplanRecord]
    completions: list[tuple[int, float, float]]   # (fid, arrival, done)
    restarts: int = 0
    dropped: int = 0                # deadline-expired while queued
    outputs: dict[int, dict] = field(default_factory=dict)
    trace: list = field(default_factory=list)   # obs.Span records (if traced)

    @property
    def avg_utilization(self) -> float:
        live = [d for d in self.devices if d.frames > 0]
        return sum(d.utilization for d in live) / len(live) if live else 0.0

    @property
    def total_energy_j(self) -> float:
        return sum(d.energy_j for d in self.devices)

    def windowed_throughput(self, t0: float, t1: float) -> float:
        """Completed frames/s inside virtual-time window [t0, t1).

        The window closes at t1 when t1 reaches the makespan — the last
        frame completes exactly at the makespan and must count.
        """
        hi_closed = t1 >= self.makespan
        n = sum(1 for _, _, d in self.completions
                if t0 <= d and (d < t1 or (hi_closed and d <= t1)))
        return n / (t1 - t0) if t1 > t0 else 0.0


@dataclass
class _StageState:
    plan: StagePlan
    index: int
    executor: object = None             # StageExecutor in real-compute mode
    queue: deque = field(default_factory=deque)  # stage 0: Frames; else _Batch
    active: "_Batch | None" = None
    pending: Event | None = None


class PipelineRuntime:
    def __init__(
        self,
        g: Graph | None = None,
        cluster: Cluster | None = None,
        input_size: tuple[int, int] | None = None,
        pico: PicoPlan | None = None,
        config: RuntimeConfig | None = None,
        churn: Sequence[ChurnEvent] = (),
        model=None,                     # CNNDef: real JAX compute per stage
        params=None,
        t_lim: float = _UNSET,          # deprecated: use plan_spec=
        backend: str | None = _UNSET,   # deprecated: use exec_spec=
        cost_table: CostTable | None = None,  # measured costs (exec.calibrate)
        plan_spec: PlanSpec | None = None,
        exec_spec: ExecSpec | None = None,
        tracer: "Tracer | None" = None,       # shared span sink (repro.obs)
        metrics: "MetricsRegistry | None" = None,
        trace_labels: dict | None = None,     # attrs on every span (tenant=..)
    ):
        if model is not None:
            g = model.graph
            input_size = model.input_size
        if g is None or cluster is None or input_size is None:
            raise ValueError("need (g, cluster, input_size) or model=")
        if not unset(t_lim, backend):
            if plan_spec is not None or exec_spec is not None:
                raise TypeError("pass either specs or the legacy "
                                "t_lim=/backend= kwargs, not both")
            warn_legacy("repro.runtime.PipelineRuntime",
                        "PipelineRuntime(..., plan_spec=PlanSpec(...), "
                        "exec_spec=ExecSpec(...))")
        self.g = g
        self.input_size = input_size
        self.cluster = cluster
        self.plan_spec = plan_spec or PlanSpec(t_lim=pick(t_lim,
                                                          float("inf")))
        self.exec_spec = exec_spec or ExecSpec(backend=pick(backend, None))
        self.model = model
        self.params = params
        self.cost_table = cost_table
        self.config = config or RuntimeConfig()
        self.rng = np.random.default_rng(self.config.seed)
        # persistent incremental-planner state: churn/drift re-plans
        # reuse the segment geometry of every earlier plan of this model
        self.planner_cache = PlannerCache()
        self.pico = pico or plan_with_spec(g, cluster, input_size,
                                           self.plan_spec,
                                           cost_table=cost_table,
                                           planner_cache=self.planner_cache)
        self.tracer = tracer if tracer is not None else (
            Tracer() if self.config.trace else NULL_TRACER)
        self.metrics = metrics if metrics is not None else (
            MetricsRegistry() if self.config.metrics else NULL_REGISTRY)
        self._labels = dict(trace_labels or {})
        self.monitor = Monitor(beta=self.config.ewma_beta,
                               drift_threshold=self.config.drift_threshold,
                               metrics=self.metrics)
        self.pool = ActorPool(cluster.devices,
                              mem_budget_bytes=self.config.mem_budget_bytes)
        self.links = LinkMap(LinkModel(
            bandwidth=self.config.inter_stage_bandwidth,
            latency_s=self.config.link_latency_s,
            jitter_s=self.config.link_jitter_s))
        self.churn = sorted(churn, key=lambda c: c.time)
        self.replans: list[ReplanRecord] = []
        self._drain_started = 0.0
        # alpha ratios the current plan was built with (drift baseline)
        self._plan_ratios: dict[str, float] = {}
        self._samples_at_replan = 0
        self._build_stages()

    @property
    def t_lim(self) -> float:
        return self.plan_spec.t_lim

    @property
    def backend(self) -> str | None:
        return self.exec_spec.backend

    # ------------------------------------------------------------------
    # plan -> executable stage states
    # ------------------------------------------------------------------

    def _build_stages(self) -> None:
        self.stages = [_StageState(st, i)
                       for i, st in enumerate(self.pico.pipeline.stages)]
        if self.model is not None:
            from ..pipeline.stage import executors_from_plan
            # compiled executors: across re-plans, stages whose segment +
            # tiling survive come straight from the executable cache
            execs = executors_from_plan(self.model, self.pico.pipeline.stages,
                                        spec=self.exec_spec)
            for st, ex in zip(self.stages, execs):
                st.executor = ex

    def _stage_for_piece(self, piece: int) -> int:
        for st in self.stages:
            if st.plan.first_piece <= piece <= st.plan.last_piece:
                return st.index
        return len(self.stages) - 1

    # ------------------------------------------------------------------
    # run loop
    # ------------------------------------------------------------------

    def _begin(self) -> None:
        if getattr(self, "_ran", False):
            raise RuntimeError("PipelineRuntime is single-use: actor clocks, "
                               "monitor state and the churn schedule are "
                               "consumed — build a fresh instance")
        self._ran = True
        self.q = EventQueue()
        self._draining = False
        self._paused = False
        self._drain_reason = ""
        self._deferred_replan: str | None = None
        self._completed = 0
        self._dropped = 0
        self._n_frames = 0
        self._outputs: dict[int, dict] = {}
        self._all_frames: list[Frame] = []
        # stream-mode hooks (set by serving.scheduler): called as
        # on_complete(frame, t, output_dict) / on_drop(frame, t)
        self.on_complete = getattr(self, "on_complete", None)
        self.on_drop = getattr(self, "on_drop", None)

    def run(self, n_frames: int = 64, inputs: Sequence | None = None,
            interarrival: float = 0.0,
            arrivals: Sequence[float] | None = None) -> RuntimeReport:
        if inputs is not None:
            n_frames = len(inputs)
        if arrivals is not None:
            n_frames = len(arrivals)
            if inputs is not None and len(inputs) != n_frames:
                raise ValueError("len(arrivals) != len(inputs)")
        if self.model is not None and self.params is None:
            raise ValueError("real-compute mode needs params")
        if self.model is not None and inputs is None:
            raise ValueError("real-compute mode needs inputs=")
        self._begin()
        self._stream = False
        self._n_frames = n_frames
        frames = [Frame(i, arrival=(arrivals[i] if arrivals is not None
                                    else i * interarrival),
                        image=None if inputs is None else inputs[i])
                  for i in range(n_frames)]
        self._all_frames = frames
        for fr in frames:
            self.q.push(fr.arrival, EventKind.FRAME_ARRIVAL,
                        stage=0, frame=fr)
        for ce in self.churn:
            self.q.push(ce.time, EventKind.CHURN, churn=ce)
        now = 0.0
        # activate this run's tracer so library-level spans (plan
        # passes, executable-cache lookups/compiles) land on it too
        with obs_trace.scoped(self.tracer):
            while self._completed + self._dropped < n_frames:
                ev = self.step()
                if ev is None:
                    raise RuntimeError(
                        f"runtime deadlock: {self._completed}/{n_frames} "
                        f"frames done, draining={self._draining}")
                now = ev.time
        return self._report(now)

    # ------------------------------------------------------------------
    # stream mode: externally driven (serving.scheduler)
    # ------------------------------------------------------------------

    def begin_stream(self) -> "PipelineRuntime":
        """Open the runtime for external driving: frames are ``admit``-ed
        dynamically, the caller pops events via ``step()`` (interleaving
        several runtimes on one virtual timeline), and reads the report
        when it decides the stream is over."""
        if self.model is not None and self.params is None:
            raise ValueError("real-compute mode needs params")
        self._begin()
        self._stream = True
        for ce in self.churn:
            self.q.push(ce.time, EventKind.CHURN, churn=ce)
        return self

    def admit(self, frame: Frame, t: float | None = None) -> None:
        """Schedule a frame's arrival at the stage covering its next
        unfinished piece (stage 0 for fresh frames; mid-pipeline for
        frames harvested from a predecessor runtime)."""
        t = frame.arrival if t is None else t
        self._all_frames.append(frame)
        self._n_frames = len(self._all_frames)
        s = self._stage_for_piece(frame.next_piece) if frame.next_piece else 0
        if s == 0:
            self.q.push(t, EventKind.FRAME_ARRIVAL, stage=0, frame=frame)
        else:
            self.q.push(t, EventKind.FRAME_ARRIVAL, stage=s,
                        batch=_Batch([frame]))

    def step(self) -> Event | None:
        """Pop and dispatch the earliest event; None when the queue is
        dry.  ``run()`` is a loop over this."""
        ev = self.q.pop()
        if ev is not None:
            self._dispatch(ev)
        return ev

    def peek_time(self) -> float | None:
        ev = self.q.peek()
        return ev.time if ev is not None else None

    @property
    def idle(self) -> bool:
        """No batch is in flight on any stage (queued frames may remain)."""
        return all(st.active is None for st in self.stages)

    @property
    def completed(self) -> int:
        return self._completed

    def pause(self) -> None:
        """Stop launching new batches; in-flight batches run to
        completion.  Used to drain before a cross-tenant re-partition."""
        self._paused = True

    def resume(self, t: float) -> None:
        self._paused = False
        for s in range(len(self.stages)):
            self._try_start(t, s)

    def harvest(self) -> list[Frame]:
        """Remove and return every queued (not in-flight) frame — stage
        queues AND not-yet-dispatched arrival events — for re-admission
        into a successor runtime.  Requires ``idle``."""
        if not self.idle:
            raise RuntimeError("harvest() while batches are in flight")
        frames = self._collect_inflight()
        ev = self.q.pop()
        while ev is not None:
            if ev.kind is EventKind.FRAME_ARRIVAL:
                item = ev.payload.get("frame") or ev.payload.get("batch")
                frames.extend([item] if isinstance(item, Frame)
                              else item.frames)
            ev = self.q.pop()
        frames.sort(key=lambda f: (f.next_piece == 0, f.fid))
        return frames

    def report(self, now: float | None = None) -> RuntimeReport:
        done = [f.done for f in self._all_frames if f.done is not None]
        return self._report(now if now is not None
                            else (max(done) if done else 0.0))

    def _dispatch(self, ev: Event) -> None:
        k = ev.kind
        if k is EventKind.FRAME_ARRIVAL:
            self._on_arrival(ev.time, ev.payload["stage"],
                             ev.payload.get("frame")
                             or ev.payload.get("batch"))
        elif k is EventKind.COMPUTE_DONE:
            self._on_compute_done(ev.time, ev.payload)
        elif k is EventKind.STAGE_DONE:
            self._on_stage_done(ev.time, ev.payload)
        elif k is EventKind.CHURN:
            self._on_churn(ev.time, ev.payload["churn"])
        elif k is EventKind.MIGRATION_DONE:
            self._on_migration_done(ev.time, ev.payload)

    # ------------------------------------------------------------------
    # handlers
    # ------------------------------------------------------------------

    def _on_arrival(self, t: float, s: int, item) -> None:
        st = self.stages[s]
        st.queue.append(item)
        for d in st.plan.devices:
            if d.name in self.pool:
                self.pool[d.name].enqueue()
        if self.tracer and s == 0:
            fids = ([item.fid] if isinstance(item, Frame)
                    else [f.fid for f in item.frames])
            self.tracer.instant("sched.admit", t, track="pipeline",
                                frames=fids, **self._labels)
        self._try_start(t, s)

    def _coalesce(self, t: float, queue: deque) -> "_Batch | None":
        """Stage-0 continuous batching: pop up to ``max_batch`` queued
        frames, dropping those whose deadline already passed."""
        frames, expired = coalesce(queue, t, self.config.max_batch)
        for fr in expired:
            fr.dropped = True
            self._dropped += 1
            self.metrics.counter("runtime.frames_dropped").inc()
            if self.tracer:
                self.tracer.instant("frame.expired", t, track="pipeline",
                                    frame=fr.fid, **self._labels)
            if self.on_drop is not None:
                self.on_drop(fr, t)
        if self.tracer and len(frames) > 1:
            self.tracer.instant("sched.coalesce", t, track="pipeline",
                                frames=[f.fid for f in frames],
                                **self._labels)
        return _Batch(frames) if frames else None

    def _try_start(self, t: float, s: int) -> None:
        st = self.stages[s]
        if (st.active is not None or not st.queue or self._draining
                or self._paused):
            return
        if s == 0:
            batch = self._coalesce(t, st.queue)
            if batch is None:
                return
        else:
            batch = st.queue.popleft()
        st.active = batch
        b = len(batch)
        seg = st.plan.cost.seg
        durs, modeled = [], []
        for k, dev in enumerate(st.plan.devices):
            act = self.pool[dev.name]
            nominal = act.device.t_comp(seg.per_device_flops[k]) * b
            noise = (float(self.rng.uniform(-1.0, 1.0))
                     * self.config.compute_noise)
            true_dur = act.compute_time(nominal, noise)
            mem = seg.param_bytes + seg.feature_bytes[k]
            act.start_work(t, true_dur, mem)
            durs.append(true_dur)
            modeled.append(nominal)
            if self.tracer:
                self.tracer.emit("stage.compute", t, true_dur,
                                 track=dev.name, stage=s, frames=b,
                                 modeled_s=nominal, observed_s=true_dur,
                                 **self._labels)
        dur = max(durs)
        if st.executor is not None:
            self._exec_batch(st, batch)
        st.pending = self.q.push(t + dur, EventKind.COMPUTE_DONE,
                                 stage=s, batch=batch,
                                 modeled=modeled, observed=durs)

    def _exec_batch(self, st: _StageState, batch: "_Batch") -> None:
        """Real numerics for one batch: single frames keep the seed's
        bit-exact ``__call__`` path; larger batches stack the boundary
        tensors and go through the compiled ``run_frames`` scan (unless
        ``ExecSpec.scan_batch`` turned the scan path off)."""
        if len(batch) == 1 or not self.exec_spec.scan_batch:
            for fr in batch.frames:
                outs = st.executor(self.params, fr.produced, fr.image)
                fr.produced.update(outs)
            return
        import jax.numpy as jnp
        frames = batch.frames
        produced: dict[str, object] = {}
        images = None
        for (_, p) in st.executor.needs:
            if p is None:
                if images is None:
                    images = jnp.stack([fr.image for fr in frames])
            elif p not in produced:
                produced[p] = jnp.stack([fr.produced[p] for fr in frames])
        outs = st.executor.run_frames(self.params, produced, images)
        for i, fr in enumerate(frames):
            fr.produced.update({k: v[i] for k, v in outs.items()})

    def _on_compute_done(self, t: float, payload: dict) -> None:
        s, batch = payload["stage"], payload["batch"]
        st = self.stages[s]
        for dev, m, o in zip(st.plan.devices, payload["modeled"],
                             payload["observed"]):
            self.monitor.record(s, dev.name, m, o)
        hop = self.links.hop(s)
        b = len(batch)
        intra = st.plan.cost.t_comm * hop.degradation * b
        inter = hop.transfer_time(sum(st.plan.cost.seg.out_bytes) * b,
                                  self.rng)
        if self.tracer:
            if intra > 0:
                self.tracer.emit("halo.exchange", t, intra,
                                 track=st.plan.devices[0].name, stage=s,
                                 **self._labels)
            if inter > 0:
                self.tracer.emit("stage.comm", t + intra, inter,
                                 track=f"link:{s}", stage=s,
                                 frames=b, **self._labels)
        st.pending = self.q.push(t + intra + inter, EventKind.STAGE_DONE,
                                 stage=s, batch=batch)

    def _on_stage_done(self, t: float, payload: dict) -> None:
        s, batch = payload["stage"], payload["batch"]
        st = self.stages[s]
        st.active = None
        st.pending = None
        for frame in batch.frames:
            frame.next_piece = st.plan.last_piece + 1
        if s + 1 < len(self.stages):
            self.q.push(t, EventKind.FRAME_ARRIVAL, stage=s + 1, batch=batch)
        else:
            sinks = (self.model.graph.sinks() if self.model is not None
                     else ())
            for frame in batch.frames:
                frame.done = t
                self._completed += 1
                self.metrics.counter("runtime.frames_completed").inc()
                self.metrics.histogram("frame.latency_s").observe(
                    t - frame.arrival)
                if self.tracer:
                    self.tracer.emit("frame", frame.arrival,
                                     t - frame.arrival, track="pipeline",
                                     frame=frame.fid, **self._labels)
                out = None
                if frame.produced and self.model is not None:
                    out = {k: frame.produced[k] for k in sinks}
                    self._outputs[frame.fid] = out
                if self.on_complete is not None:
                    self.on_complete(frame, t, out)
        if self._draining:
            if self._all_idle():
                self._do_replan(t)
            return
        if (self.config.replan_on_drift and self.monitor.samples
                and self._drift_detected()):
            self._request_replan(t, "drift")
            return
        self._try_start(t, s)

    def _drift_detected(self) -> bool:
        # let the EWMA converge before (re-)acting on it
        if (self.monitor.samples - self._samples_at_replan
                < self.config.drift_cooldown):
            return False
        # drift is relative to the ratios the current plan was built
        # with — a device *recovering* to 1.0 after a throttled plan is
        # drift too, so check every measured device, not just those far
        # from nominal
        for name, ew in self.monitor.ratio.items():
            if not ew.n:
                continue
            base = self._plan_ratios.get(name, 1.0)
            if abs(ew.value / base - 1.0) > self.config.drift_threshold:
                return True
        return False

    def _on_churn(self, t: float, ce: ChurnEvent) -> None:
        self.metrics.counter("runtime.churn_events",
                             kind=type(ce).__name__).inc()
        if self.tracer:
            self.tracer.instant("churn", t, track="control",
                                kind=type(ce).__name__, **self._labels)
        if isinstance(ce, LinkDegrade):
            self.links.degrade(ce.factor, ce.hop)
            return                       # plan unchanged; costs just grew
        if isinstance(ce, FreqScale):
            self.pool[ce.device_name].speed = ce.factor
            return                       # monitor will notice the drift
        if isinstance(ce, DeviceJoin):
            self.pool.add(ce.device,
                          mem_budget_bytes=self.config.mem_budget_bytes)
            if self.config.replan_on_churn:
                self._request_replan(t, "join")
            return
        if isinstance(ce, DeviceLeave):
            self.pool.remove(ce.device_name)
            self.monitor.reset_device(ce.device_name)
            # abort any in-flight work that involved the dead device
            aborted: list[int] = []
            for st in self.stages:
                if st.active is not None and any(
                        d.name == ce.device_name for d in st.plan.devices):
                    if st.pending is not None:
                        st.pending.cancelled = True
                        st.pending = None
                    for fr in st.active.frames:
                        fr.restarts += 1
                    if st.index == 0:
                        for fr in reversed(st.active.frames):
                            st.queue.appendleft(fr)
                    else:
                        st.queue.appendleft(st.active)
                    st.active = None
                    aborted.append(st.index)
            if not self.pool.live():
                raise RuntimeError("all devices left the cluster")
            if self.config.replan_on_churn:
                self._request_replan(t, "leave")
            else:
                # no re-plan: keep executing the stale plan (the dead
                # actor's slot still ticks at its modeled rate) — the
                # aborted frames must restart here or nothing ever will
                for s_idx in aborted:
                    self._try_start(t, s_idx)

    # ------------------------------------------------------------------
    # re-planning
    # ------------------------------------------------------------------

    def _all_idle(self) -> bool:
        return all(st.active is None for st in self.stages)

    def _request_replan(self, t: float, reason: str) -> None:
        if self._draining:
            # churn landed mid-drain/mid-migration: replay it afterwards
            self._deferred_replan = self._deferred_replan or reason
            return
        self._draining = True
        self._drain_reason = reason
        self._drain_started = t
        if self._all_idle():
            self._do_replan(t)

    def _do_replan(self, t: float) -> None:
        wall0 = _time.perf_counter()
        alive = self.pool.alive_devices()
        next_cluster = Cluster(alive, bandwidth=self.cluster.bandwidth,
                               pair_bandwidth=dict(self.cluster.pair_bandwidth))
        calibrated = self.monitor.calibrated_cluster(next_cluster)
        old = self.pico
        # which devices used to host each piece (for migration cost)
        old_hosts: dict[int, frozenset[str]] = {}
        for st in old.pipeline.stages:
            names = frozenset(d.name for d in st.devices)
            for p in range(st.first_piece, st.last_piece + 1):
                old_hosts[p] = names
        with obs_trace.scoped(self.tracer):
            new = plan_with_spec(self.g, calibrated, self.input_size,
                                 self.plan_spec, partition=old.partition,
                                 cost_table=self.cost_table,
                                 planner_cache=self.planner_cache)
            # keep the incumbent plan if it is still runnable and wins
            # when both are priced with measured costs (the DP must use
            # every device, so a fresh plan can lose — e.g. after a
            # weak join)
            alive_names = {d.name for d in alive}
            incumbent_ok = all(
                d.name in alive_names
                for st in old.pipeline.stages for d in st.devices)
            if incumbent_ok:
                old_rc = recost(old.pipeline, calibrated, self.g,
                                self.input_size, cost_table=self.cost_table)
                if old_rc.period <= new.period:
                    new = PicoPlan(old.partition, old_rc)
        mig_bytes = 0.0
        for st in new.pipeline.stages:
            names = frozenset(d.name for d in st.devices)
            if old_hosts.get(st.first_piece) != names:
                mig_bytes += st.cost.seg.param_bytes
        bw = self.config.migration_bandwidth or self.cluster.bandwidth
        mig_s = mig_bytes / bw + self.config.link_latency_s
        wall = _time.perf_counter() - wall0
        self.replans.append(ReplanRecord(
            t, self._drain_reason, wall, old.period, new.period,
            len(alive), mig_bytes, mig_s))
        self.metrics.counter("runtime.replans",
                             reason=self._drain_reason).inc()
        if self.tracer:
            if t > self._drain_started:
                self.tracer.emit("sched.drain", self._drain_started,
                                 t - self._drain_started, track="control",
                                 reason=self._drain_reason, **self._labels)
            self.tracer.emit("replan", t, mig_s, track="control",
                             reason=self._drain_reason, wall_s=wall,
                             old_period=old.period, new_period=new.period,
                             migration_bytes=mig_bytes, **self._labels)
        self.pico = new
        self._plan_ratios = {d.name: self.monitor.device_ratio(d.name)
                             for d in alive}
        self._samples_at_replan = self.monitor.samples
        self.q.push(t + mig_s, EventKind.MIGRATION_DONE)

    def _collect_inflight(self) -> list[Frame]:
        """Harvest queued frames from the old stage states.

        Must run at MIGRATION_DONE time (not at re-plan time): hand-off
        arrivals scheduled in the same instant as the drain's last
        STAGE_DONE land in the old queues first.
        """
        frames: list[Frame] = []
        for st in self.stages:
            for item in st.queue:
                frames.extend([item] if isinstance(item, Frame)
                              else item.frames)
            st.queue.clear()
        frames.sort(key=lambda f: (f.next_piece == 0, f.fid))
        return frames

    def _on_migration_done(self, t: float, payload: dict) -> None:
        inflight = self._collect_inflight()
        self._build_stages()
        self._draining = False
        for frame in inflight:
            s = self._stage_for_piece(frame.next_piece)
            if s == 0:
                self.q.push(t, EventKind.FRAME_ARRIVAL, stage=0, frame=frame)
            else:
                self.q.push(t, EventKind.FRAME_ARRIVAL, stage=s,
                            batch=_Batch([frame]))
        if self._deferred_replan is not None:
            reason, self._deferred_replan = self._deferred_replan, None
            self._request_replan(t, reason)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def _report(self, now: float) -> RuntimeReport:
        done = sorted((f.fid, f.arrival, f.done) for f in self._all_frames
                      if f.done is not None)
        times = [d for _, _, d in done]
        makespan = max(times) if times else now
        if len(times) >= 2:
            period = (times[-1] - times[0]) / (len(times) - 1)
        else:
            period = times[0] if times else 0.0
        lat = [d - a for _, a, d in done]
        devs = [RuntimeDeviceReport(
            a.name, a.utilization(makespan), a.busy_s, a.frames_done,
            a.mem_peak_bytes, a.mem_violations, a.energy_j(makespan))
            for a in self.pool.actors.values()]
        return RuntimeReport(
            frames=self._n_frames,
            completed=self._completed,
            period=period,
            latency_first=lat[0] if lat else 0.0,
            latency_mean=sum(lat) / len(lat) if lat else 0.0,
            makespan=makespan,
            throughput_per_min=60.0 / period if period > 0 else 0.0,
            devices=devs,
            replans=list(self.replans),
            completions=done,
            restarts=sum(f.restarts for f in self._all_frames),
            dropped=self._dropped,
            outputs=self._outputs,
            trace=list(self.tracer.spans),
        )
