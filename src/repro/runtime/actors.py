"""Device actors: per-device virtual clock, queues, memory and energy.

Each physical device in the cluster becomes a :class:`DeviceActor`.
Actors do not run threads — the event loop advances their virtual
clocks — but they own all per-device state: dynamic speed (DVFS churn
scales it), accumulated busy time, queue occupancy, peak memory against
a budget, and the energy integral of the paper's Fig. 16.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.cost import Device


@dataclass
class DeviceActor:
    device: Device
    speed: float = 1.0                  # DVFS factor: 0.5 = half clock
    mem_budget_bytes: float = float("inf")
    alive: bool = True

    clock: float = 0.0                  # virtual time this actor is free
    busy_s: float = 0.0
    frames_done: int = 0
    mem_peak_bytes: float = 0.0
    mem_violations: int = 0
    queue_peak: int = 0
    _queued: int = 0

    @property
    def name(self) -> str:
        return self.device.name

    @property
    def effective_capacity(self) -> float:
        return self.device.capacity * self.speed

    def compute_time(self, modeled_s: float, noise: float = 0.0) -> float:
        """Wall time for work modeled at nominal speed.

        ``noise`` is a multiplicative perturbation (measured vs modeled
        mismatch); the monitor's EWMA recovers ``(1 + noise) / speed``.
        """
        return modeled_s / max(self.speed, 1e-12) * (1.0 + noise)

    def enqueue(self) -> None:
        self._queued += 1
        self.queue_peak = max(self.queue_peak, self._queued)

    def start_work(self, start: float, duration: float,
                   mem_bytes: float) -> None:
        self._queued = max(0, self._queued - 1)
        self.busy_s += duration
        self.clock = start + duration
        self.frames_done += 1
        self.mem_peak_bytes = max(self.mem_peak_bytes, mem_bytes)
        if mem_bytes > self.mem_budget_bytes:
            self.mem_violations += 1

    def energy_j(self, makespan: float) -> float:
        return (self.device.active_power * self.busy_s
                + self.device.idle_power * max(0.0, makespan - self.busy_s))

    def utilization(self, makespan: float) -> float:
        return self.busy_s / makespan if makespan > 0 else 0.0


class ActorPool:
    """All actors of the cluster, live and departed, keyed by name."""

    def __init__(self, devices: list[Device],
                 mem_budget_bytes: float = float("inf")):
        self.actors: dict[str, DeviceActor] = {
            d.name: DeviceActor(d, mem_budget_bytes=mem_budget_bytes)
            for d in devices}

    def __getitem__(self, name: str) -> DeviceActor:
        return self.actors[name]

    def __contains__(self, name: str) -> bool:
        return name in self.actors

    def add(self, device: Device,
            mem_budget_bytes: float = float("inf")) -> DeviceActor:
        prev = self.actors.get(device.name)
        if prev is not None and prev.alive:
            raise ValueError(f"device {device.name!r} already in pool")
        if prev is not None:
            # rejoin after a leave: revive, keep accumulated stats
            prev.device = device
            prev.alive = True
            prev.speed = 1.0
            prev.mem_budget_bytes = mem_budget_bytes
            return prev
        act = DeviceActor(device, mem_budget_bytes=mem_budget_bytes)
        self.actors[device.name] = act
        return act

    def remove(self, name: str) -> DeviceActor:
        if name not in self.actors:
            raise KeyError(f"unknown device {name!r} "
                           f"(have: {sorted(self.actors)})")
        act = self.actors[name]
        act.alive = False
        return act

    def alive_devices(self) -> list[Device]:
        """Live devices at *nominal* capacity.

        DVFS drift is deliberately not applied here: the re-planner must
        see measured costs through the monitor's alpha calibration, not
        the actor's ground-truth speed (which a real deployment cannot
        read directly).
        """
        return [a.device for a in self.actors.values() if a.alive]

    def live(self) -> list[DeviceActor]:
        return [a for a in self.actors.values() if a.alive]
