"""Injected cluster-condition changes (the paper testbed's failure modes).

A churn schedule is a list of timestamped events; the executor applies
each at its virtual time.  DeviceLeave/DeviceJoin change membership and
force a re-plan at the next frame boundary; FreqScale models DVFS or
thermal throttling (the monitor detects the drift and triggers a
re-plan once its EWMA crosses the threshold); LinkDegrade models a
congested/lossy WLAN hop.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.cost import Device


@dataclass(frozen=True)
class ChurnEvent:
    time: float


@dataclass(frozen=True)
class DeviceLeave(ChurnEvent):
    device_name: str


@dataclass(frozen=True)
class DeviceJoin(ChurnEvent):
    device: Device


@dataclass(frozen=True)
class FreqScale(ChurnEvent):
    """Scale a device's clock: ``factor`` 0.5 = throttled to half speed."""
    device_name: str
    factor: float


@dataclass(frozen=True)
class LinkDegrade(ChurnEvent):
    """Multiply transfer times on one hop (or all, hop=None)."""
    factor: float
    hop: int | None = None
