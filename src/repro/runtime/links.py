"""Wireless-link models: bandwidth + latency + jitter per stage hop.

The planner's cost model (Eq. 9-10) charges each stage for its *intra*
stage scatter/gather from the stage head device d_f; the hand-off of
the gathered output to the next stage's head is what these links time.
The closed-form simulator treats that hand-off as free, so the default
("ideal") link reproduces the simulator exactly; realistic links expose
the cost the analytic model hides — jitter on a lossy WLAN, per-hop
latency, and mid-run degradation (churn).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class LinkModel:
    """One stage-to-stage hop.

    ``bandwidth`` is bytes/s for the inter-stage tensor transfer
    (``None`` = ideal hand-off, matching ``core.simulate``);
    ``latency_s`` is the fixed per-transfer cost; ``jitter_s`` the max
    of a uniform random extra delay.  ``degradation`` multiplies every
    transfer time (1.0 = healthy link); churn events raise it.
    """

    bandwidth: float | None = None
    latency_s: float = 0.0
    jitter_s: float = 0.0
    degradation: float = 1.0

    def transfer_time(self, nbytes: float, rng: np.random.Generator) -> float:
        t = self.latency_s
        if self.bandwidth:
            t += nbytes / self.bandwidth
        if self.jitter_s > 0.0:
            t += float(rng.uniform(0.0, self.jitter_s))
        return t * self.degradation


@dataclass
class LinkMap:
    """Per-hop link table with a shared default.

    Hop ``s`` connects stage ``s`` to stage ``s+1``; hop ``-1`` is the
    source -> stage 0 ingress (free by default, like the simulator).
    """

    default: LinkModel = field(default_factory=LinkModel)
    hops: dict[int, LinkModel] = field(default_factory=dict)

    def hop(self, s: int) -> LinkModel:
        return self.hops.get(s, self.default)

    def degrade(self, factor: float, hop: int | None = None) -> None:
        """Multiply transfer times by ``factor`` on one hop or all."""
        if hop is not None:
            lm = self.hops.setdefault(
                hop, LinkModel(self.default.bandwidth, self.default.latency_s,
                               self.default.jitter_s, self.default.degradation))
            lm.degradation *= factor
        else:
            self.default.degradation *= factor
            for lm in self.hops.values():
                lm.degradation *= factor
