"""Cross-check the event runtime against the closed-form simulator.

Runs the same PicoPlan through ``core.simulate`` (the paper's analytic
Figs. 13-16 quantities) and through :class:`PipelineRuntime` under the
ideal config, and reports relative errors on period, latency and
per-device utilization.  Agreement certifies that the executor's event
machinery implements the pipeline recurrence of Eq. 12; divergence
under non-ideal configs *measures* what the analytic model hides.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..core.cost import Cluster
from ..core.graph import Graph
from ..core.planner import PicoPlan, plan as plan_full
from ..core.simulate import SimReport, simulate
from .executor import PipelineRuntime, RuntimeConfig, RuntimeReport


def _rel(a: float, b: float) -> float:
    return abs(a - b) / abs(b) if b else abs(a)


@dataclass
class ValidationReport:
    sim: SimReport
    run: RuntimeReport
    period_rel_err: float
    latency_rel_err: float
    utilization_abs_err: float
    tol: float

    @property
    def ok(self) -> bool:
        return (self.period_rel_err <= self.tol
                and self.latency_rel_err <= self.tol
                and self.utilization_abs_err <= self.tol)

    def __str__(self) -> str:
        return (f"period {self.run.period:.4f}s vs {self.sim.period:.4f}s "
                f"({self.period_rel_err:.2%}); "
                f"latency {self.run.latency_first:.4f}s vs "
                f"{self.sim.latency:.4f}s ({self.latency_rel_err:.2%}); "
                f"max util err {self.utilization_abs_err:.2%}; "
                f"{'OK' if self.ok else 'MISMATCH'} (tol {self.tol:.0%})")


def validate(
    g: Graph | None = None,
    cluster: Cluster | None = None,
    input_size: tuple[int, int] | None = None,
    model=None,
    pico: PicoPlan | None = None,
    frames: int = 64,
    tol: float = 0.10,
    config: RuntimeConfig | None = None,
) -> ValidationReport:
    """Measured (runtime) vs predicted (simulator) pipeline metrics."""
    if model is not None:
        g, input_size = model.graph, model.input_size
    if pico is None:
        pico = plan_full(g, cluster, input_size)
    sim = simulate(pico.pipeline, frames=frames, cluster=cluster)
    rt = PipelineRuntime(g, cluster, input_size, pico=pico,
                         config=config or RuntimeConfig.ideal())
    run = rt.run(frames)
    sim_util = {(d.device, d.stage): d.utilization for d in sim.devices}
    util_err = 0.0
    for dr in run.devices:
        match = [u for (name, _), u in sim_util.items() if name == dr.device]
        if match:
            util_err = max(util_err, abs(dr.utilization - max(match)))
    return ValidationReport(
        sim, run,
        period_rel_err=_rel(run.period, sim.period),
        latency_rel_err=_rel(run.latency_first, sim.latency),
        utilization_abs_err=util_err,
        tol=tol,
    )
