"""Event-driven heterogeneous-cluster runtime with dynamic re-planning.

The executable counterpart of ``core.simulate``: device actors with
virtual clocks, queues, memory budgets and DVFS; timed stage-to-stage
links with latency/jitter/degradation; an EWMA monitor feeding measured
costs back into the planner; and churn-triggered re-planning that
migrates in-flight frames at stage boundaries.
"""

from .events import Event, EventKind, EventQueue
from .links import LinkMap, LinkModel
from .actors import ActorPool, DeviceActor
from .monitor import EWMA, Monitor
from .churn import (ChurnEvent, DeviceJoin, DeviceLeave, FreqScale,
                    LinkDegrade)
from .executor import (Frame, PipelineRuntime, ReplanRecord, RuntimeConfig,
                       RuntimeDeviceReport, RuntimeReport)
from .validate import ValidationReport, validate

__all__ = [
    "Event", "EventKind", "EventQueue",
    "LinkMap", "LinkModel",
    "ActorPool", "DeviceActor",
    "EWMA", "Monitor",
    "ChurnEvent", "DeviceJoin", "DeviceLeave", "FreqScale", "LinkDegrade",
    "Frame", "PipelineRuntime", "ReplanRecord", "RuntimeConfig",
    "RuntimeDeviceReport", "RuntimeReport",
    "ValidationReport", "validate",
]
