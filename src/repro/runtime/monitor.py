"""Runtime monitor: EWMA of observed stage times -> calibrated cluster.

Every finished compute phase reports (device, modeled seconds, observed
seconds).  The per-device EWMA of observed/modeled is exactly the
correction the cost model's regression coefficient alpha_k (Eq. 7)
should absorb: ``calibrated_cluster`` returns a cluster whose devices
carry ``alpha * ewma`` so that the *next* ``planner.plan`` call
optimizes against measured, not assumed, compute rates — the DynO-style
feedback loop (PAPERS.md).

The monitor publishes every sample into a
:class:`~repro.obs.metrics.MetricsRegistry` (``monitor.samples``
counter, per-stage ``stage.observed_s`` histograms, per-device
``monitor.ratio`` gauges) instead of keeping the numbers to itself —
the EWMA cells stay as the planner-facing view, the registry is the
export surface.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from ..core.cost import Cluster
from ..obs.metrics import NULL_REGISTRY


@dataclass
class EWMA:
    beta: float = 0.3           # weight of the newest sample
    value: float = 1.0
    n: int = 0

    def update(self, x: float) -> float:
        self.value = x if self.n == 0 else (
            self.beta * x + (1.0 - self.beta) * self.value)
        self.n += 1
        return self.value


@dataclass
class Monitor:
    beta: float = 0.3
    drift_threshold: float = 0.25   # |ewma - 1| beyond this = re-plan signal
    ratio: dict[str, EWMA] = field(default_factory=dict)
    stage_time: dict[int, EWMA] = field(default_factory=dict)
    samples: int = 0
    metrics: object = NULL_REGISTRY     # MetricsRegistry (or the no-op)

    def record(self, stage: int, device_name: str,
               modeled_s: float, observed_s: float) -> None:
        """Fold one (modeled, observed) compute sample into the EWMAs
        and publish it to the metrics registry.  ``modeled_s <= 0``
        contributes no ratio (there is nothing to normalize by) but
        still counts as a sample and a stage-time observation."""
        self.samples += 1
        if modeled_s > 0:
            ew = self.ratio.setdefault(device_name, EWMA(self.beta))
            ew.update(observed_s / modeled_s)
            self.metrics.gauge("monitor.ratio", device=device_name).set(
                ew.value)
        self.stage_time.setdefault(stage, EWMA(self.beta)).update(observed_s)
        m = self.metrics
        if m:
            m.counter("monitor.samples").inc()
            m.histogram("stage.observed_s", stage=stage).observe(observed_s)
            m.histogram("stage.modeled_s", stage=stage).observe(modeled_s)

    def device_ratio(self, name: str) -> float:
        ew = self.ratio.get(name)
        return ew.value if ew and ew.n else 1.0

    def drifted_devices(self) -> list[str]:
        return [n for n, ew in self.ratio.items()
                if ew.n and abs(ew.value - 1.0) > self.drift_threshold]

    def calibrated_cluster(self, cluster: Cluster) -> Cluster:
        """Cluster with alpha_k scaled by each device's measured ratio."""
        devs = [replace(d, alpha=d.alpha * self.device_ratio(d.name))
                for d in cluster.devices]
        return Cluster(devs, bandwidth=cluster.bandwidth,
                       pair_bandwidth=dict(cluster.pair_bandwidth))

    def reset_device(self, name: str) -> None:
        self.ratio.pop(name, None)
