"""Config registry: the 10 assigned architectures + paper CNNs + shapes."""

from __future__ import annotations

import importlib

from ..models.transformer.config import ArchConfig
from .shapes import SHAPES, InputShape, input_specs, arch_for_shape

_MODULES = {
    "qwen1.5-4b": "qwen1_5_4b",
    "mamba2-370m": "mamba2_370m",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "command-r-35b": "command_r_35b",
    "llama3.2-1b": "llama3_2_1b",
    "llava-next-34b": "llava_next_34b",
    "musicgen-medium": "musicgen_medium",
    "mixtral-8x7b": "mixtral_8x7b",
}

ARCH_NAMES = list(_MODULES)


def get(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    return mod.CONFIG


def all_archs() -> dict[str, ArchConfig]:
    return {n: get(n) for n in ARCH_NAMES}


__all__ = ["ArchConfig", "SHAPES", "InputShape", "input_specs",
           "arch_for_shape", "ARCH_NAMES", "get", "all_archs"]
