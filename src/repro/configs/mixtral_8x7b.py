"""mixtral-8x7b [moe] — 8 experts top-2, native sliding-window attention
[arXiv:2401.04088]."""
from ..models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    n_experts=8, moe_top_k=2, sliding_window=4096,
    source="arXiv:2401.04088",
)
