"""llava-next-34b [vlm] — anyres tiling; the ViT/SigLIP vision encoder +
projector are STUBS: input_specs() provides precomputed patch embeddings
(B, S, d_model) and this config is the language backbone
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from ..models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000, input_mode="embeds",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
