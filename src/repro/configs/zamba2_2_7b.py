"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242]."""
from ..models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000, layer_pattern="mamba",
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    shared_attn_every=6,     # 9 shared-block applications over 54 layers
    source="arXiv:2411.15242",
)
