"""The four assigned input shapes + ShapeDtypeStruct input builders.

Decode shapes lower ``serve_step`` (ONE new token against a seq_len KV
cache), not ``train_step``.  ``long_500k`` requires sub-quadratic
decode: SSM/hybrid run natively, Mixtral uses its native sliding window,
and pure full-attention archs run an explicit sliding-window variant
(``ArchConfig.with_sliding_window``) — see DESIGN.md §4.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models.transformer.config import ArchConfig
from ..models.transformer.model import init_cache

LONG_CONTEXT_WINDOW = 8192   # SWA window used by dense archs on long_500k


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # 'train' | 'prefill' | 'decode'


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def arch_for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Swap in the sliding-window variant for quadratic archs on 500k."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return cfg.with_sliding_window(LONG_CONTEXT_WINDOW)
    return cfg


def input_specs(cfg: ArchConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train   -> {'tokens'|'embeds', 'labels'}
    prefill -> {'tokens'|'embeds'}
    decode  -> {'inputs': {'token'|'embed'}, 'cache': pytree}
    """
    cfg = arch_for_shape(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct

    def token_batch(with_labels: bool):
        if cfg.input_mode == "tokens":
            b = {"tokens": sds((B, S), jnp.int32)}
        else:
            b = {"embeds": sds((B, S, cfg.d_model), dtype)}
        if with_labels:
            b["labels"] = sds((B, S), jnp.int32)
        return b

    if shape.kind == "train":
        return token_batch(True)
    if shape.kind == "prefill":
        return token_batch(False)
    # decode
    if cfg.input_mode == "tokens":
        inputs = {"token": sds((B,), jnp.int32)}
    else:
        inputs = {"embed": sds((B, cfg.d_model), dtype)}
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, dtype=dtype))
    return {"inputs": inputs, "cache": cache}
