"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060]."""
from ..models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, layer_pattern="mamba",
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    source="arXiv:2405.21060",
)
