"""musicgen-medium [audio] — decoder-only over EnCodec tokens; the
mel-spectrogram/EnCodec conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S, d_model) [arXiv:2306.05284]."""
from ..models.transformer.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048, input_mode="embeds",
    source="arXiv:2306.05284",
)
