"""Request queues, admission control and tenant arbitration.

Pure scheduling-policy building blocks shared by the multi-tenant
:mod:`repro.serving.scheduler`, the runtime's stage-0 continuous
batching, and the time-sliced baseline:

* :func:`coalesce` — pop up to ``max_batch`` items from a FIFO deque,
  dropping the ones whose ``deadline`` already passed (single source of
  truth for batch formation + deadline expiry);
* :class:`TenantQueue` — per-tenant admission control (bounded
  in-system occupancy) plus a standalone pending queue for drivers that
  do their own batching;
* :class:`WeightedArbiter` — stride scheduler: starvation-free,
  deterministic weighted selection across tenants;
* :class:`OpenLoopGenerator` — seeded open-loop arrival process
  (Poisson, optionally bursty) for serving-under-load experiments.

No JAX imports here: everything is host-side and cheap enough to sit on
the event loop's hot path.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from ..data.pipeline import Request
# the batch-formation primitive lives with the event executor (runtime
# must not import serving); this module is its policy-facing home
from ..runtime.executor import coalesce

__all__ = ["coalesce", "TenantQueue", "WeightedArbiter",
           "OpenLoopGenerator"]


@dataclass
class TenantQueue:
    """Admission-controlled request queue for one tenant.

    ``in_system`` counts requests admitted but not yet completed or
    expired (queued *or* in flight); :meth:`offer` rejects when it would
    exceed ``max_queue``.  The ``pending`` deque is for standalone
    drivers (the time-sliced baseline, property tests) that pop batches
    themselves — the event scheduler instead admits straight into its
    runtime and only uses the occupancy accounting.
    """

    max_queue: float = float("inf")
    in_system: int = 0
    admitted: int = 0
    rejected: int = 0
    expired: int = 0
    completed: int = 0
    pending: deque = field(default_factory=deque)

    def offer(self, item=None) -> bool:
        """Admit or reject one request; admitted requests (when given)
        are appended to ``pending``."""
        if self.in_system >= self.max_queue:
            self.rejected += 1
            return False
        self.in_system += 1
        self.admitted += 1
        if item is not None:
            self.pending.append(item)
        return True

    def complete(self) -> None:
        assert self.in_system > 0, "complete() without a matching offer()"
        self.in_system -= 1
        self.completed += 1

    def expire(self) -> None:
        assert self.in_system > 0, "expire() without a matching offer()"
        self.in_system -= 1
        self.expired += 1

    def pop_batch(self, now: float, max_batch: int):
        """Standalone-mode batch formation over ``pending`` (admission
        accounting updated for the expired items)."""
        batch, expired = coalesce(self.pending, now, max_batch)
        for _ in expired:
            self.expire()
        return batch, expired

    def __len__(self) -> int:
        return len(self.pending)


class WeightedArbiter:
    """Stride scheduler over a set of named tenants.

    Each tenant advances a virtual ``pass`` by ``1/weight`` per grant;
    :meth:`pick` selects the eligible tenant with the lowest pass, so
    grants converge to weight proportions and every eligible tenant with
    positive weight is granted within a bounded interval (no
    starvation).  Deterministic: ties break by registration order.
    """

    def __init__(self, weights: dict[str, float] | None = None):
        self._stride: dict[str, float] = {}
        self._pass: dict[str, float] = {}
        self._order: dict[str, int] = {}
        self.grants: dict[str, int] = {}
        for name, w in (weights or {}).items():
            self.add(name, w)

    def add(self, name: str, weight: float) -> None:
        if weight <= 0 or not math.isfinite(weight):
            raise ValueError(f"weight for {name!r} must be finite > 0")
        self._stride[name] = 1.0 / weight
        # join at the current minimum pass so a new tenant neither
        # monopolizes nor waits out everyone else's accumulated credit
        floor = min(self._pass.values(), default=0.0)
        self._pass[name] = max(self._pass.get(name, floor), floor)
        self._order.setdefault(name, len(self._order))
        self.grants.setdefault(name, 0)

    def remove(self, name: str) -> None:
        self._stride.pop(name, None)
        self._pass.pop(name, None)

    def pick(self, eligible=None) -> str | None:
        names = [n for n in self._stride
                 if eligible is None or n in eligible]
        if not names:
            return None
        name = min(names, key=lambda n: (self._pass[n], self._order[n]))
        self._pass[name] += self._stride[name]
        self.grants[name] = self.grants.get(name, 0) + 1
        return name


@dataclass
class OpenLoopGenerator:
    """Seeded open-loop arrival process (arrivals do not wait for
    completions — the load the paper's camera would offer).

    Base process is Poisson at ``rate_per_s``; with ``burst_period_s``
    set, the first ``burst_duty`` fraction of each period runs at
    ``rate_per_s * burst_factor`` (bursty traffic for admission-control
    and rebalance experiments).
    """

    rate_per_s: float
    seed: int = 0
    burst_factor: float = 1.0
    burst_period_s: float = 0.0
    burst_duty: float = 0.5

    def _rate_at(self, t: float) -> float:
        if self.burst_period_s <= 0.0 or self.burst_factor == 1.0:
            return self.rate_per_s
        phase = (t % self.burst_period_s) / self.burst_period_s
        return self.rate_per_s * (self.burst_factor
                                  if phase < self.burst_duty else 1.0)

    def arrivals(self, n: int, start: float = 0.0) -> list[float]:
        rng = np.random.default_rng(self.seed)
        t, out = start, []
        for _ in range(n):
            t += rng.exponential(1.0 / self._rate_at(t))
            out.append(t)
        return out

    def generate(self, n: int, make_payload=None,
                 start: float = 0.0) -> list[Request]:
        rng = np.random.default_rng(self.seed + 1)
        return [Request(i, t, None if make_payload is None
                        else make_payload(rng, i))
                for i, t in enumerate(self.arrivals(n, start))]
