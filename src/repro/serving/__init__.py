"""Serving: pipelined CNN inference server + LM decode loop."""

from .server import PipelineServer, ServeStats, StreamingPipelineServer
from .lm import generate

__all__ = ["PipelineServer", "ServeStats", "StreamingPipelineServer",
           "generate"]
