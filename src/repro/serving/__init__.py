"""Serving: pipelined CNN inference servers, the multi-tenant
asynchronous scheduler, and the LM decode loop."""

from .server import PipelineServer, ServeStats, StreamingPipelineServer
from .queueing import (OpenLoopGenerator, TenantQueue, WeightedArbiter,
                       coalesce)
from .scheduler import (RepartitionRecord, SchedulerConfig, ServeReport,
                        ServingScheduler, TenantConfig, TenantJoin,
                        TenantLeave, serve_time_sliced)
from .lm import generate

__all__ = ["PipelineServer", "ServeStats", "StreamingPipelineServer",
           "OpenLoopGenerator", "TenantQueue", "WeightedArbiter", "coalesce",
           "RepartitionRecord", "SchedulerConfig", "ServeReport",
           "ServingScheduler", "TenantConfig", "TenantJoin", "TenantLeave",
           "serve_time_sliced",
           "generate"]
