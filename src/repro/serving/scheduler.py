"""Multi-tenant asynchronous serving scheduler.

Co-hosts several CNN tenants on one heterogeneous cluster: the device
fleet is split across tenants by :func:`core.planner.partition_cluster`
(weighted by tenant priority x observed load), each tenant's sub-cluster
runs its own PICO-planned pipeline through the deterministic
event-driven runtime, and one shared virtual timeline interleaves all
of them plus the control plane:

* **admission control** — per-tenant bounded in-system occupancy
  (:class:`~repro.serving.queueing.TenantQueue`); overflow requests are
  rejected at arrival;
* **deadlines / SLO** — requests carry ``arrival + slo_s`` deadlines;
  queued requests that expire are dropped at batch-formation time,
  served-but-late requests count as deadline misses;
* **continuous batching** — each tenant's stage 0 coalesces queued
  requests into ``run_frames`` micro-batches on the compiled ``exec``
  path (``RuntimeConfig.max_batch``);
* **re-partitioning** — periodic control ticks track per-tenant load
  (EWMA of offered FLOP/s); when the load split diverges from the
  device split, or on device churn / tenant join/leave, every pipeline
  drains its in-flight batches (nothing is dropped), devices are
  re-split, each sub-cluster is re-planned (``replan`` reuses the piece
  chain; ``exec.cache`` reuses executables for unchanged stages), and
  queued frames resume after a parameter-migration delay.

Everything is virtual-time and seeded, so serving-under-load scenarios
(bursty arrivals, churn mid-traffic) are reproducible and testable.
"""

from __future__ import annotations

import time as _time
import zlib
from dataclasses import dataclass, field, replace
from typing import Mapping, Sequence

from ..api._compat import _UNSET, pick, unset, warn_legacy
from ..api.specs import ExecSpec, PlanSpec
from ..core.cost import Cluster, CostTable
from ..core.pipeline_dp import PlannerCache
from ..core.planner import (PicoPlan, partition_cluster, plan_with_spec,
                            split_devices)
from ..data.pipeline import Request
from ..exec.cache import CacheStats, cache_stats
from ..obs import trace as obs_trace
from ..obs.metrics import MetricsRegistry, NULL_REGISTRY
from ..obs.trace import NULL_TRACER, Tracer
from ..runtime import (DeviceJoin, DeviceLeave, PipelineRuntime,
                       RuntimeConfig)
from ..runtime.events import EventKind, EventQueue
from ..runtime.executor import Frame
from .queueing import TenantQueue, WeightedArbiter
from .server import ServeStats


@dataclass
class TenantConfig:
    """One co-hosted model and its serving policy."""

    name: str
    model: object                   # CNNDef (duck-typed: .graph/.input_size)
    weight: float = 1.0             # relative device entitlement
    slo_s: float = float("inf")     # per-request deadline after arrival
    max_queue: int = 256            # admission bound on in-system requests
    max_batch: int = 4              # stage-0 micro-batch cap
    t_lim: float = float("inf")     # planner latency limit (legacy surface)
    plan_spec: PlanSpec | None = None   # full planner spec; wins over t_lim

    def planner_spec(self) -> PlanSpec:
        return self.plan_spec or PlanSpec(t_lim=self.t_lim)


@dataclass
class SchedulerConfig:
    seed: int = 0
    control_interval_s: float = 0.25    # load-tracking tick
    rebalance_threshold: float = 0.2    # max |desired - actual| device share
    rebalance_cooldown_s: float = 1.0   # min spacing of load re-partitions
    load_beta: float = 0.5              # EWMA on per-tenant offered load
    min_load_frac: float = 0.05         # idle tenants keep this load share
    migration_bandwidth: float | None = None   # None = cluster bandwidth
    runtime: RuntimeConfig = field(default_factory=RuntimeConfig)


@dataclass
class TenantJoin:
    """Tenant joins the fleet mid-traffic (devices are re-split)."""

    time: float
    config: TenantConfig
    params: object = None


@dataclass
class TenantLeave:
    time: float
    name: str


@dataclass
class RepartitionRecord:
    time: float
    reason: str
    wall_s: float
    migration_bytes: float
    migration_s: float
    assignment: dict[str, tuple[str, ...]]
    periods: dict[str, float]
    # honest per-tenant plan provenance for this repartition:
    # scratch | incremental | registry (see core.planner.PLAN_SOURCES)
    plan_sources: dict[str, str] = field(default_factory=dict)


@dataclass
class ServeReport:
    tenants: dict[str, ServeStats]
    outputs: dict[str, dict]        # tenant -> request id -> sink tensors
    completions: list[tuple[str, int, float, float]]  # (tenant, rid, arr, done)
    repartitions: list[RepartitionRecord]
    makespan: float
    wall_s: float
    dropped_inflight: int           # admitted frames lost mid-flight (== 0)
    device_busy_s: dict[str, float]
    device_frames: dict[str, int]
    cache: CacheStats               # compile hits/misses during this serve
    metrics: object = None          # shared MetricsRegistry (if enabled)
    trace: list = field(default_factory=list)   # obs.Span records (if traced)

    @property
    def served(self) -> int:
        return sum(s.served for s in self.tenants.values())

    def metrics_snapshot(self, meta: Mapping | None = None) -> dict:
        """Versioned metrics-snapshot document for this serve.

        Merges the scheduler's shared runtime registry (if metrics were
        enabled), per-tenant :meth:`ServeStats.publish` series, report
        scalars, and the process-default registry (executable-cache and
        conv-fallback counters) into one
        :meth:`~repro.obs.metrics.MetricsRegistry.snapshot` envelope.
        """
        from ..obs.metrics import default_registry
        reg = MetricsRegistry()
        if isinstance(self.metrics, MetricsRegistry):
            reg.merge(self.metrics)
        for name, st in self.tenants.items():
            st.publish(reg, tenant=name)
        reg.gauge("serve.makespan_s").set(self.makespan)
        reg.gauge("serve.dropped_inflight").set(self.dropped_inflight)
        reg.gauge("serve.repartitions").set(len(self.repartitions))
        reg.merge(default_registry())
        return reg.snapshot(meta=meta)

    @property
    def throughput_per_min(self) -> float:
        if self.makespan <= 0.0:
            return 0.0
        return 60.0 * self.served / self.makespan

    def windowed_throughput(self, t0: float, t1: float) -> float:
        """Completed requests/s (all tenants) in [t0, t1); the window
        closes at t1 when t1 reaches the makespan."""
        hi_closed = t1 >= self.makespan
        n = sum(1 for _, _, _, d in self.completions
                if t0 <= d and (d < t1 or (hi_closed and d <= t1)))
        return n / (t1 - t0) if t1 > t0 else 0.0

    def utilization(self, device: str) -> float:
        if self.makespan <= 0.0:
            return 0.0
        return self.device_busy_s.get(device, 0.0) / self.makespan


@dataclass
class _TenantState:
    cfg: TenantConfig
    params: object = None
    queue: TenantQueue = None
    share: object = None            # core.planner.TenantShare
    rt: PipelineRuntime | None = None
    stats: ServeStats = field(default_factory=ServeStats)
    outputs: dict = field(default_factory=dict)
    request_of: dict = field(default_factory=dict)   # fid -> Request
    backlog: list = field(default_factory=list)      # frames awaiting a rt
    load_ewma: float | None = None
    arrivals_since_tick: int = 0
    work_per_frame: float = 0.0     # exact FLOPs of one frame
    next_fid: int = 0
    leaving: bool = False

    def __post_init__(self):
        if self.queue is None:
            self.queue = TenantQueue(max_queue=self.cfg.max_queue)
        g = self.cfg.model.graph
        nodes = frozenset(g.layers)
        full = g.forward_sizes(self.cfg.model.input_size)
        out, _ = g.required_sizes(nodes, {}, full, self.cfg.model.input_size)
        self.work_per_frame = g.segment_flops(nodes, out)


class ServingScheduler:
    """Serve several tenants' request streams on one cluster."""

    def __init__(self, tenants: Sequence[TenantConfig], cluster: Cluster,
                 config: SchedulerConfig | None = None,
                 backend: str | None = _UNSET,
                 cost_table: CostTable | None = None,
                 exec_spec: ExecSpec | None = None,
                 registry=None):
        names = [t.name for t in tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names in {names}")
        if not tenants:
            raise ValueError("need at least one tenant")
        if not unset(backend):
            if exec_spec is not None:
                raise TypeError("pass either exec_spec= or the legacy "
                                "backend= kwarg, not both")
            warn_legacy("repro.serving.ServingScheduler",
                        "ServingScheduler(..., exec_spec=ExecSpec(...))")
        self.cluster = cluster
        self.config = config or SchedulerConfig()
        self.exec_spec = exec_spec or ExecSpec(backend=pick(backend, None))
        self.cost_table = cost_table
        # optional fleet PlanRegistry: repartitions consult it before
        # planning, and publish fresh plans back for the rest of the
        # fleet.  Per-tenant PlannerCaches keep even registry misses on
        # the incremental hot path.
        self.registry = registry
        self._planner_caches: dict[str, PlannerCache] = {}
        rc = self.config.runtime
        # one shared span sink + registry across every tenant runtime,
        # so the whole serve renders on a single Perfetto timeline
        self.tracer = Tracer() if rc.trace else NULL_TRACER
        self.metrics = MetricsRegistry() if rc.metrics else NULL_REGISTRY
        self._devices = list(cluster.devices)
        self._tenants: dict[str, _TenantState] = {
            t.name: _TenantState(t) for t in tenants}
        self._retired: dict[str, _TenantState] = {}
        self.partition = partition_cluster(
            [t.model for t in tenants], cluster,
            weights=[t.weight for t in tenants],
            plan_specs=[t.planner_spec() for t in tenants],
            cost_table=cost_table,
            plan_fn=self._make_plan_fn([t.name for t in tenants]))
        for share, ts in zip(self.partition.shares, self._tenants.values()):
            ts.share = share
        self._loaded = False
        self._served = False

    @property
    def backend(self) -> str | None:
        return self.exec_spec.backend

    def _cache_for(self, name: str) -> PlannerCache:
        return self._planner_caches.setdefault(name, PlannerCache())

    def _make_plan_fn(self, names: Sequence[str]):
        """The :func:`~repro.core.planner.partition_cluster` hook:
        registry-first (an identical sub-cluster anywhere in the fleet
        already has this plan), else the incremental planner with the
        tenant's persistent :class:`PlannerCache` and prior piece
        chain.  Fresh plans are published back to the registry."""
        def plan_fn(i, model, sub, spec, prev_i):
            if self.registry is not None:
                hit = self.registry.get(model, sub, spec, self.cost_table)
                if hit is not None:
                    return hit
            pico = plan_with_spec(
                model.graph, sub, model.input_size, spec,
                partition=prev_i.partition if prev_i is not None else None,
                cost_table=self.cost_table,
                planner_cache=self._cache_for(names[i]))
            if self.registry is not None:
                self.registry.put(model, sub, spec, pico, self.cost_table)
            return pico
        return plan_fn

    # ------------------------------------------------------------------

    def load(self, key=None) -> "ServingScheduler":
        """Initialize every tenant's parameters (real-numerics mode);
        skip to serve in timing-only mode."""
        import jax
        key = key if key is not None else jax.random.PRNGKey(0)
        for ts in self._tenants.values():
            key, sub = jax.random.split(key)
            ts.params = ts.cfg.model.init(sub)
        self._loaded = True
        return self

    # ------------------------------------------------------------------
    # runtime (re)construction
    # ------------------------------------------------------------------

    def _runtime_config(self, ts: _TenantState, generation: int
                        ) -> RuntimeConfig:
        return replace(self.config.runtime,
                       seed=(self.config.seed * 1_000_003
                             + zlib.crc32(ts.cfg.name.encode()) % 65_537
                             + generation),
                       max_batch=ts.cfg.max_batch,
                       replan_on_churn=False, replan_on_drift=False)

    def _build_runtime(self, ts: _TenantState, generation: int,
                       paused: bool) -> None:
        kw = dict(cluster=ts.share.cluster, pico=ts.share.pico,
                  plan_spec=ts.cfg.planner_spec(), exec_spec=self.exec_spec,
                  cost_table=self.cost_table,
                  config=self._runtime_config(ts, generation),
                  tracer=self.tracer, metrics=self.metrics,
                  trace_labels={"tenant": ts.cfg.name})
        if ts.params is not None:
            rt = PipelineRuntime(model=ts.cfg.model, params=ts.params, **kw)
        else:
            rt = PipelineRuntime(g=ts.cfg.model.graph,
                                 input_size=ts.cfg.model.input_size, **kw)
        rt.begin_stream()
        rt.on_complete = self._on_complete_hook(ts)
        rt.on_drop = self._on_drop_hook(ts)
        if paused:
            rt.pause()
        ts.rt = rt
        ts.stats.period_model_s = ts.share.pico.period

    def _on_complete_hook(self, ts: _TenantState):
        def hook(frame: Frame, t: float, out) -> None:
            req = ts.request_of[frame.fid]
            missed = (frame.deadline is not None
                      and t > frame.deadline + 1e-12)
            ts.stats.record(t - frame.arrival, missed_deadline=missed)
            ts.queue.complete()
            if out is not None:
                ts.outputs[req.rid] = out
            self._completions.append((ts.cfg.name, req.rid, frame.arrival, t))
        return hook

    def _on_drop_hook(self, ts: _TenantState):
        def hook(frame: Frame, t: float) -> None:
            ts.queue.expire()
        return hook

    # ------------------------------------------------------------------
    # serve loop
    # ------------------------------------------------------------------

    def serve(self, workload: Mapping[str, Sequence[Request]],
              churn: Sequence = ()) -> ServeReport:
        """Run the full multi-tenant stream to completion.

        ``workload`` maps tenant name -> requests (any order; arrivals
        define the open-loop schedule).  ``churn`` mixes runtime device
        events (:class:`DeviceJoin`/:class:`DeviceLeave`) with
        :class:`TenantJoin`/:class:`TenantLeave`.
        """
        if self._served:
            raise RuntimeError("ServingScheduler.serve is single-use — "
                               "build a fresh scheduler")
        self._served = True
        wall0 = _time.perf_counter()
        cache_mark = cache_stats().snapshot()
        self._completions: list[tuple[str, int, float, float]] = []
        self.repartitions: list[RepartitionRecord] = []
        self._drain_pending: str | None = None
        self._generation = 0
        self._last_rebalance_t = -float("inf")
        self._busy: dict[str, float] = {}
        self._devframes: dict[str, int] = {}
        self._now = 0.0

        control = self._control = EventQueue()
        for name, reqs in workload.items():
            if name not in self._tenants:
                raise KeyError(f"workload for unknown tenant {name!r}")
            for r in reqs:
                control.push(r.arrival, EventKind.REQUEST_ARRIVAL,
                             tenant=name, request=r)
        for ce in churn:
            if isinstance(ce, TenantJoin):
                control.push(ce.time, EventKind.TENANT_JOIN, join=ce)
            elif isinstance(ce, TenantLeave):
                control.push(ce.time, EventKind.TENANT_LEAVE, leave=ce)
            else:
                control.push(ce.time, EventKind.CHURN, churn=ce)
        control.push(self.config.control_interval_s, EventKind.CONTROL_TICK)

        # scope the shared tracer over the whole serve so library-level
        # spans (plan passes, executable-cache lookups/compiles) from
        # every tenant land on this serve's timeline
        with obs_trace.scoped(self.tracer):
            for ts in self._tenants.values():
                self._build_runtime(ts, self._generation, paused=False)

            while True:
                pick = self._next_source()
                if pick is None:
                    if self._drain_pending and self._all_idle():
                        self._finish_repartition(self._now)
                        continue
                    break
                t, _, ts = pick
                self._now = t
                if ts is None:
                    self._handle_control(self._control.pop())
                else:
                    ts.rt.step()
                if self._drain_pending and self._all_idle():
                    self._finish_repartition(self._now)

        return self._report(wall0, cache_mark)

    def _active(self):
        return [ts for ts in self._tenants.values() if not ts.leaving]

    def _next_source(self):
        best = None
        ev = self._control.peek()
        if ev is not None:
            best = (ev.time, -1, None)
        for i, ts in enumerate(self._tenants.values()):
            if ts.rt is None:
                continue
            pt = ts.rt.peek_time()
            if pt is not None and (best is None or (pt, i) < best[:2]):
                best = (pt, i, ts)
        return best

    def _all_idle(self) -> bool:
        return all(ts.rt is None or ts.rt.idle
                   for ts in self._tenants.values())

    # ------------------------------------------------------------------
    # control-plane handlers
    # ------------------------------------------------------------------

    def _handle_control(self, ev) -> None:
        t, k = ev.time, ev.kind
        if k is EventKind.REQUEST_ARRIVAL:
            self._on_request(t, ev.payload["tenant"], ev.payload["request"])
        elif k is EventKind.CONTROL_TICK:
            self._on_tick(t)
        elif k is EventKind.CHURN:
            self._on_device_churn(t, ev.payload["churn"])
        elif k is EventKind.TENANT_JOIN:
            self._on_tenant_join(t, ev.payload["join"])
        elif k is EventKind.TENANT_LEAVE:
            self._on_tenant_leave(t, ev.payload["leave"])
        elif k is EventKind.REPARTITION_DONE:
            # a newer repartition supersedes this event's migration
            # window — resuming early would bypass its migration delay
            if ev.payload.get("generation") == self._generation:
                for ts in self._active():
                    if ts.rt is not None:
                        ts.rt.resume(t)

    def _on_request(self, t: float, name: str, req: Request) -> None:
        ts = self._tenants.get(name) or self._retired.get(name)
        if ts is None or ts.leaving:
            if ts is not None:           # tenant gone: refuse, but account
                ts.queue.rejected += 1
            return
        ts.arrivals_since_tick += 1
        if not ts.queue.offer():
            return                       # admission control: rejected
        fid = ts.next_fid
        ts.next_fid += 1
        deadline = (t + ts.cfg.slo_s
                    if ts.cfg.slo_s != float("inf") else None)
        frame = Frame(fid, arrival=t,
                      image=req.payload if ts.params is not None else None,
                      deadline=deadline)
        ts.request_of[fid] = req
        if ts.rt is None or self._drain_pending:
            ts.backlog.append(frame)
        else:
            ts.rt.admit(frame, t=t)

    def _on_tick(self, t: float) -> None:
        beta = self.config.load_beta
        dt = self.config.control_interval_s
        for ts in self._active():
            inst = ts.arrivals_since_tick / dt * ts.work_per_frame
            ts.arrivals_since_tick = 0
            ts.load_ewma = (inst if ts.load_ewma is None
                            else beta * inst + (1.0 - beta) * ts.load_ewma)
        if (self._drain_pending is None and len(self._active()) > 1
                and t - self._last_rebalance_t
                >= self.config.rebalance_cooldown_s
                and self._load_shift_detected()):
            self._request_repartition(t, "load")
        # keep ticking while there is anything left to schedule
        if self._control.peek() is not None or self._drain_pending \
                or any(ts.queue.in_system > 0 for ts in
                       self._tenants.values()):
            self._control.push(t + dt, EventKind.CONTROL_TICK)

    def _desired_shares(self) -> dict[str, float]:
        active = self._active()
        known = [ts.load_ewma for ts in active if ts.load_ewma is not None]
        peak_known = max(known, default=0.0)
        # a tenant with no EWMA yet (it just joined) gets the peak
        # observed load — i.e. its full weight entitlement — until its
        # own measurements arrive; raw work_per_frame would mix FLOPs
        # into a FLOP/s comparison and collapse it to the floor
        loads = {ts.cfg.name: (ts.load_ewma if ts.load_ewma is not None
                               else peak_known) for ts in active}
        peak = max(loads.values())
        if peak <= 0.0:                 # fleet fully idle: back to weights
            total = sum(ts.cfg.weight for ts in active)
            return {ts.cfg.name: ts.cfg.weight / total for ts in active}
        # normalize by the peak before flooring: the EWMA decays toward
        # denormals on long-idle tenants and 0.05 * denormal underflows
        raw = {ts.cfg.name: ts.cfg.weight
               * max(loads[ts.cfg.name] / peak, self.config.min_load_frac)
               for ts in active}
        total = sum(raw.values())
        return {n: v / total for n, v in raw.items()}

    def _load_shift_detected(self) -> bool:
        desired = self._desired_shares()
        total_cap = sum(d.capacity for d in self._devices)
        shifted = False
        for ts in self._active():
            have = ts.share.capacity / total_cap if ts.share else 0.0
            if abs(desired[ts.cfg.name] - have) \
                    > self.config.rebalance_threshold:
                shifted = True
                break
        if not shifted:
            return False
        # device granularity may make the desired split unreachable —
        # only drain the fleet if the re-split actually changes hands
        active = self._active()
        buckets = split_devices(
            Cluster(self._devices, bandwidth=self.cluster.bandwidth),
            [desired[ts.cfg.name] for ts in active])
        for bucket, ts in zip(buckets, active):
            names = frozenset(d.name for d in bucket)
            if ts.share is None or names != ts.share.device_names:
                return True
        return False

    def _on_device_churn(self, t: float, ce) -> None:
        if isinstance(ce, DeviceLeave):
            survivors = [d for d in self._devices if d.name != ce.device_name]
            if len(survivors) < len(self._active()):
                raise RuntimeError(
                    f"device {ce.device_name} leaving strands "
                    f"{len(self._active())} tenants on {len(survivors)} "
                    f"devices")
            self._devices = survivors
            self._request_repartition(t, "leave")
        elif isinstance(ce, DeviceJoin):
            self._devices.append(ce.device)
            self._request_repartition(t, "join")
        else:
            raise TypeError(f"unsupported churn event for the scheduler: "
                            f"{type(ce).__name__}")

    def _on_tenant_join(self, t: float, ev: TenantJoin) -> None:
        cfg = ev.config
        if cfg.name in self._tenants:
            raise ValueError(f"tenant {cfg.name!r} already active")
        if cfg.name in self._retired:
            raise ValueError(f"tenant {cfg.name!r} already served and left "
                             f"during this serve — rejoin under a fresh "
                             f"name so its stats are not shadowed")
        if len(self._devices) < len(self._active()) + 1:
            raise RuntimeError(f"no device available for joining tenant "
                               f"{cfg.name!r}")
        ts = _TenantState(cfg, params=ev.params)
        if ts.params is None and self._loaded:
            import jax
            ts.params = cfg.model.init(
                jax.random.PRNGKey(zlib.crc32(cfg.name.encode()) % (2 ** 31)))
        self._tenants[cfg.name] = ts
        self._request_repartition(t, "tenant-join")

    def _on_tenant_leave(self, t: float, ev: TenantLeave) -> None:
        ts = self._tenants.get(ev.name)
        if ts is None:
            return
        ts.leaving = True
        self._request_repartition(t, "tenant-leave")

    # ------------------------------------------------------------------
    # re-partitioning
    # ------------------------------------------------------------------

    def _request_repartition(self, t: float, reason: str) -> None:
        if self._drain_pending is not None:
            return                       # already draining; one pass covers it
        self._drain_pending = reason
        self._drain_started_t = t
        for ts in self._tenants.values():
            if ts.rt is not None:
                ts.rt.pause()
        if self._all_idle():
            self._finish_repartition(t)

    def _absorb(self, rt: PipelineRuntime) -> None:
        for a in rt.pool.actors.values():
            self._busy[a.name] = self._busy.get(a.name, 0.0) + a.busy_s
            self._devframes[a.name] = (self._devframes.get(a.name, 0)
                                       + a.frames_done)

    def _finish_repartition(self, t: float) -> None:
        reason, self._drain_pending = self._drain_pending, None
        wall0 = _time.perf_counter()
        harvested: dict[str, list[Frame]] = {}
        old_hosts: dict[str, dict[int, frozenset[str]]] = {}
        for name, ts in self._tenants.items():
            frames: list[Frame] = []
            if ts.rt is not None:
                self._absorb(ts.rt)
                frames = ts.rt.harvest()
                ts.rt = None
            if ts.share is not None:
                hosts: dict[int, frozenset[str]] = {}
                for st in ts.share.pico.pipeline.stages:
                    names = frozenset(d.name for d in st.devices)
                    for p in range(st.first_piece, st.last_piece + 1):
                        hosts[p] = names
                old_hosts[name] = hosts
            frames += ts.backlog
            ts.backlog = []
            harvested[name] = frames

        # retire leaving tenants; their queued frames will never be served
        for name in [n for n, ts in self._tenants.items() if ts.leaving]:
            ts = self._tenants.pop(name)
            for _ in harvested.pop(name):
                ts.queue.expire()
            self._retired[name] = ts

        active = list(self._tenants.values())
        if not active:
            return
        shares = self._desired_shares()
        self._generation += 1
        partition = partition_cluster(
            [ts.cfg.model for ts in active],
            Cluster(self._devices, bandwidth=self.cluster.bandwidth,
                    pair_bandwidth=dict(self.cluster.pair_bandwidth)),
            weights=[shares[ts.cfg.name] for ts in active],
            plan_specs=[ts.cfg.planner_spec() for ts in active],
            cost_table=self.cost_table,
            prev=[ts.share.pico if ts.share is not None else None
                  for ts in active],
            plan_fn=self._make_plan_fn([ts.cfg.name for ts in active]))
        # migration: only stages whose host set actually changed push
        # their parameters (same rule as the runtime's internal re-plan)
        mig_bytes = 0.0
        for share, ts in zip(partition.shares, active):
            hosts = old_hosts.get(ts.cfg.name, {})
            for st in share.pico.pipeline.stages:
                names = frozenset(d.name for d in st.devices)
                if hosts.get(st.first_piece) != names:
                    mig_bytes += st.cost.seg.param_bytes
            ts.share = share
        bw = self.config.migration_bandwidth or self.cluster.bandwidth
        mig_s = mig_bytes / bw
        resume_t = t + mig_s
        for ts in active:
            self._build_runtime(ts, self._generation, paused=True)
            for frame in harvested[ts.cfg.name]:
                ts.rt.admit(frame, t=resume_t)
        self._control.push(resume_t, EventKind.REPARTITION_DONE,
                           generation=self._generation)
        self._last_rebalance_t = t
        self.partition = partition
        if self.tracer:
            drain0 = getattr(self, "_drain_started_t", t)
            if t > drain0:
                self.tracer.emit("sched.drain", drain0, t - drain0,
                                 track="scheduler", reason=reason)
            self.tracer.emit("sched.repartition", t, mig_s,
                             track="scheduler", reason=reason,
                             generation=self._generation,
                             migration_bytes=mig_bytes,
                             tenants=[ts.cfg.name for ts in active])
        for ts in active:
            self.metrics.counter("serve.replans",
                                 source=ts.share.pico.source).inc()
        self.repartitions.append(RepartitionRecord(
            time=t, reason=reason, wall_s=_time.perf_counter() - wall0,
            migration_bytes=mig_bytes, migration_s=mig_s,
            assignment={ts.cfg.name: tuple(d.name for d in
                                           ts.share.cluster.devices)
                        for ts in active},
            periods={ts.cfg.name: ts.share.pico.period for ts in active},
            plan_sources={ts.cfg.name: ts.share.pico.source
                          for ts in active}))

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    def _report(self, wall0: float, cache_mark: CacheStats) -> ServeReport:
        for ts in self._tenants.values():
            if ts.rt is not None:
                self._absorb(ts.rt)
        everyone = {**self._retired, **self._tenants}
        dropped_inflight = 0
        for ts in everyone.values():
            ts.stats.rejected = ts.queue.rejected
            ts.stats.expired = ts.queue.expired
            dropped_inflight += ts.queue.in_system
        makespan = max((d for _, _, _, d in self._completions),
                       default=self._now)
        return ServeReport(
            tenants={n: ts.stats for n, ts in everyone.items()},
            outputs={n: ts.outputs for n, ts in everyone.items()},
            completions=list(self._completions),
            repartitions=list(self.repartitions),
            makespan=makespan,
            wall_s=_time.perf_counter() - wall0,
            dropped_inflight=dropped_inflight,
            device_busy_s=dict(self._busy),
            device_frames=dict(self._devframes),
            cache=cache_stats().since(cache_mark),
            metrics=self.metrics if self.metrics else None,
            trace=list(self.tracer.spans),
        )


# ---------------------------------------------------------------------------
# naive baseline: time-sliced single-tenant serving
# ---------------------------------------------------------------------------

def serve_time_sliced(tenants: Sequence[TenantConfig], cluster: Cluster,
                      workload: Mapping[str, Sequence[Request]],
                      quantum_periods: float = 50.0,
                      reload_params: bool = False,
                      cost_table: CostTable | None = None) -> ServeReport:
    """The naive baseline: one tenant at a time owns the WHOLE cluster
    for a quantum (weighted round-robin via the stride arbiter), paying
    a pipeline refill before each slice's steady state — and, with
    ``reload_params=True``, a parameter re-upload over the cluster link
    on every switch (the deployment that cannot keep all tenants
    resident).  Admission control and deadline handling match the
    scheduler, so the comparison isolates the device-partitioning
    decision: whole-cluster pipelines scale sublinearly (WLAN comm), so
    serving every tenant on all devices loses to right-sized
    sub-clusters even before the switching overhead.
    """
    from ..core.planner import plan_with_spec

    plans: dict[str, PicoPlan] = {}
    for tc in tenants:
        plans[tc.name] = plan_with_spec(tc.model.graph, cluster,
                                        tc.model.input_size,
                                        tc.planner_spec(),
                                        cost_table=cost_table)
    arb = WeightedArbiter({tc.name: tc.weight for tc in tenants})
    queues = {tc.name: TenantQueue(max_queue=tc.max_queue)
              for tc in tenants}
    stats = {tc.name: ServeStats(period_model_s=plans[tc.name].period)
             for tc in tenants}
    slos = {tc.name: tc.slo_s for tc in tenants}
    pending = {tc.name: sorted(workload.get(tc.name, ()),
                               key=lambda r: r.arrival)
               for tc in tenants}
    idx = {tc.name: 0 for tc in tenants}
    completions: list[tuple[str, int, float, float]] = []

    @dataclass
    class _Job:
        rid: int
        arrival: float
        deadline: float | None

    def admit_up_to(t: float) -> None:
        for name, reqs in pending.items():
            i = idx[name]
            while i < len(reqs) and reqs[i].arrival <= t:
                r = reqs[i]
                dl = r.arrival + slos[name] \
                    if slos[name] != float("inf") else None
                queues[name].offer(_Job(r.rid, r.arrival, dl))
                i += 1
            idx[name] = i

    def next_arrival() -> float | None:
        times = [pending[n][i].arrival for n, i in idx.items()
                 if i < len(pending[n])]
        return min(times) if times else None

    t = 0.0
    wall0 = _time.perf_counter()
    while True:
        admit_up_to(t)
        eligible = {n for n, q in queues.items() if len(q)}
        if not eligible:
            na = next_arrival()
            if na is None:
                break
            t = na
            continue
        name = arb.pick(eligible)
        pl = plans[name]
        # switch cost: optionally push this tenant's parameters to the
        # cluster, then refill the pipeline (latency - period) before
        # the first steady-state completion
        switch_s = (sum(st.cost.seg.param_bytes
                        for st in pl.pipeline.stages) / cluster.bandwidth
                    if reload_params else 0.0)
        fill_s = max(0.0, pl.latency - pl.period)
        # the slice must fit at least one completion or no tenant with a
        # long pipeline would ever make progress
        t_slice_end = t + switch_s + max(quantum_periods * pl.period,
                                         fill_s + pl.period)
        cur = t + switch_s + fill_s
        while True:
            done_at = cur + pl.period
            if done_at > t_slice_end:
                break
            admit_up_to(cur)
            batch, _ = queues[name].pop_batch(cur, 1)
            if not batch:
                break
            job = batch[0]
            missed = job.deadline is not None and done_at > job.deadline
            stats[name].record(done_at - job.arrival, missed_deadline=missed)
            queues[name].complete()
            completions.append((name, job.rid, job.arrival, done_at))
            cur = done_at
        t = t_slice_end
    for name, q in queues.items():
        stats[name].rejected = q.rejected
        stats[name].expired = q.expired
    makespan = max((d for _, _, _, d in completions), default=t)
    return ServeReport(
        tenants=stats, outputs={n: {} for n in stats},
        completions=completions, repartitions=[], makespan=makespan,
        wall_s=_time.perf_counter() - wall0,
        dropped_inflight=sum(q.in_system for q in queues.values()),
        device_busy_s={}, device_frames={}, cache=CacheStats())
