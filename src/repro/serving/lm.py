"""Autoregressive decode loop for the transformer substrate."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models.transformer.config import ArchConfig
from ..models.transformer import model as M


def generate(cfg: ArchConfig, params, prompt: jax.Array, n_new: int,
             temperature: float = 0.0, key=None):
    """Greedy/temperature decode.  prompt: (B, S) int32.

    Returns (B, n_new) generated tokens.  Prefill once, then one
    decode_step per token (the cache is pre-padded with n_new slots).
    """
    B, S = prompt.shape
    assert S >= 2, "prompt must have at least 2 tokens"
    # prefill all but the last prompt token; the decode loop then feeds
    # the last token and each generated token in turn
    _, cache = M.prefill(cfg, params, {"tokens": prompt[:, :-1]})
    if not cfg.sliding_window:
        # grow kv capacity for the new tokens
        def grow(k, v):
            pad = [(0, 0)] * k.ndim
            pad[2] = (0, n_new + 1)   # +1 for the fed-back last token
            return jnp.pad(k, pad), jnp.pad(v, pad)
        if "k" in cache:
            cache["k"], cache["v"] = grow(cache["k"], cache["v"])
        if "shared_k" in cache:
            cache["shared_k"], cache["shared_v"] = grow(
                cache["shared_k"], cache["shared_v"])

    last = prompt[:, -1]
    step = jax.jit(lambda p, c, t: M.decode_step(cfg, p, c, {"token": t}))
    toks = []
    tok = last
    if key is None:
        key = jax.random.PRNGKey(0)
    for i in range(n_new):
        logits, cache = step(params, cache, tok)
        if temperature > 0:
            key, sk = jax.random.split(key)
            tok = jax.random.categorical(sk, logits / temperature, axis=-1)
        else:
            tok = jnp.argmax(logits, axis=-1)
        tok = tok.astype(jnp.int32)
        toks.append(tok)
    return jnp.stack(toks, axis=1)
