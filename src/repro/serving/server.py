"""Pipelined CNN inference server — PICO's deployment form.

Plans the pipeline with the PICO optimizer, builds per-stage executors,
and serves a stream of frame requests with dynamic batching.  The
scheduler is event-driven: each stage is busy for its modeled time
T(S); the executor computes the true numerics (bit-exact with the
monolithic network).  Throughput/latency statistics reproduce the
paper's runtime metrics on simulated clusters, while the numerics prove
the deployment artifact is correct.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..api._compat import _UNSET, pick, unset, warn_legacy
from ..api.specs import DeploySpec, ExecSpec, PlanSpec
from ..core import Cluster, plan_with_spec
from ..obs.metrics import quantile
from ..models.cnn.builder import CNNDef
from ..pipeline.runner import PipelineRunner
from ..data.pipeline import Request


def _resolve_specs(who: str, t_lim, backend, plan_spec, exec_spec
                   ) -> tuple["PlanSpec", "ExecSpec"]:
    """Map a server's legacy ``t_lim=``/``backend=`` kwargs onto specs,
    warning once per entry point; reject mixing the two surfaces."""
    if not unset(t_lim, backend):
        if plan_spec is not None or exec_spec is not None:
            raise TypeError(f"{who}: pass either specs or the legacy "
                            "t_lim=/backend= kwargs, not both")
        # one extra frame (this helper) between warn and the user's call
        warn_legacy(who, f"{who}(..., plan_spec=PlanSpec(...), "
                         "exec_spec=ExecSpec(...)) or repro.compile()",
                    stacklevel=4)
    plan_spec = plan_spec or PlanSpec(t_lim=pick(t_lim, float("inf")))
    exec_spec = exec_spec or ExecSpec(backend=pick(backend, None))
    return plan_spec, exec_spec


def _load_params_idempotent(srv, key):
    """Shared server ``load()`` body: params attached beforehand (e.g.
    by ``Deployment.server()``) survive unless ``key`` forces a
    re-init.  Delegates the actual init (default key included) to the
    facade's one implementation so servers and deployments cannot
    drift."""
    if srv.params is None or key is not None:
        from ..api.deployment import _init_params
        srv.params = _init_params(srv.model, key)
    return srv


@dataclass
class ServeStats:
    """Shared serving accounting: every server front-end (closed-form
    replay, runtime-backed streaming, multi-tenant scheduler) records
    per-request completions through :meth:`record` instead of keeping
    its own accumulation loop."""

    served: int = 0
    total_latency_model_s: float = 0.0
    period_model_s: float = 0.0
    wall_s: float = 0.0
    per_request: list = field(default_factory=list)
    # admission / SLO accounting (multi-tenant scheduler)
    rejected: int = 0           # refused at admission (queue full)
    expired: int = 0            # deadline passed while still queued
    deadline_misses: int = 0    # served, but past the deadline

    def record(self, latency_s: float, missed_deadline: bool = False) -> None:
        """Account one served request."""
        self.served += 1
        self.total_latency_model_s += latency_s
        self.per_request.append(latency_s)
        if missed_deadline:
            self.deadline_misses += 1

    @property
    def offered(self) -> int:
        return self.served + self.rejected + self.expired

    @property
    def model_throughput_per_min(self) -> float:
        """Steady-state throughput from the modeled pipeline period;
        robust to zero-duration serves (empty streams, single-request
        serves, degenerate plans) instead of dividing by zero."""
        if self.period_model_s and self.period_model_s > 0.0:
            return 60.0 / self.period_model_s
        return 0.0

    @property
    def mean_latency_s(self) -> float:
        return (self.total_latency_model_s / self.served
                if self.served else 0.0)

    def latency_percentile(self, q: float) -> float:
        """Nearest-rank percentile of per-request latency — well-defined
        for any window size (``np.percentile``'s linear interpolation
        degenerates below three samples: p50 of ``[a, b]`` lands between
        the order statistics instead of on one).  Shares the estimator
        with :class:`repro.obs.metrics.Histogram` so server stats and
        metrics snapshots quote identical numbers."""
        return quantile(self.per_request, q)

    @property
    def p50_latency_s(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95_latency_s(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99_latency_s(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def deadline_miss_rate(self) -> float:
        """Fraction of admitted requests that blew their deadline —
        expired-in-queue requests count as misses too."""
        admitted = self.served + self.expired
        if not admitted:
            return 0.0
        return (self.deadline_misses + self.expired) / admitted

    def publish(self, registry, **labels) -> None:
        """Mirror this accounting into a
        :class:`~repro.obs.metrics.MetricsRegistry` (``serve.*`` gauges
        plus a ``serve.latency_s`` histogram), labelled e.g. by tenant.
        Idempotent per registry: gauges overwrite, the histogram is
        rebuilt from ``per_request`` only when its count lags."""
        for name, v in (("serve.served", self.served),
                        ("serve.rejected", self.rejected),
                        ("serve.expired", self.expired),
                        ("serve.deadline_misses", self.deadline_misses),
                        ("serve.deadline_miss_rate",
                         self.deadline_miss_rate),
                        ("serve.mean_latency_s", self.mean_latency_s),
                        ("serve.period_model_s", self.period_model_s),
                        ("serve.wall_s", self.wall_s)):
            registry.gauge(name, **labels).set(v)
        h = registry.histogram("serve.latency_s", **labels)
        for lat in self.per_request[h.count:]:
            h.observe(lat)


class PipelineServer:
    def __init__(self, model: CNNDef, cluster: Cluster,
                 t_lim: float = _UNSET, backend: str | None = _UNSET,
                 cost_table=None, plan_spec: PlanSpec | None = None,
                 exec_spec: ExecSpec | None = None, pico=None):
        plan_spec, exec_spec = _resolve_specs(
            "repro.serving.PipelineServer", t_lim, backend,
            plan_spec, exec_spec)
        self.model = model
        self.cluster = cluster
        self.exec_spec = exec_spec
        self.pico = pico or plan_with_spec(model.graph, cluster,
                                           model.input_size, plan_spec,
                                           cost_table=cost_table)
        self.runner = PipelineRunner(model, self.pico.pipeline,
                                     backend=exec_spec.backend,
                                     mode=exec_spec.mode)
        self.params = None

    def load(self, key=None):
        """Initialize weights (idempotent — see
        :func:`_load_params_idempotent`)."""
        return _load_params_idempotent(self, key)

    def serve(self, requests: list[Request]) -> tuple[list, ServeStats]:
        """Run the request stream through the pipeline.

        Returns (outputs, stats).  Completion times follow the pipeline
        model (stage s starts request i when stage s finished i-1 and
        stage s-1 finished i); numerics come from the real executors.
        """
        assert self.params is not None, "call load() first"
        t0 = time.perf_counter()
        stages = self.runner.stages
        T = [st.cost.total for st in self.pico.pipeline.stages]
        S = len(stages)
        finish = np.zeros((len(requests), S))
        outputs = []
        stats = ServeStats(period_model_s=max(T) if T else 0.0)
        for i, req in enumerate(requests):
            produced = {}
            for s, ex in enumerate(stages):
                prev_stage = finish[i][s - 1] if s > 0 else req.arrival
                prev_req = finish[i - 1][s] if i > 0 else 0.0
                finish[i][s] = max(prev_stage, prev_req) + T[s]
                outs = ex(self.params, produced, req.payload)
                produced.update(outs)
            sinks = self.model.graph.sinks()
            outputs.append({k: produced[k] for k in sinks})
            stats.record(finish[i][-1] - req.arrival)
        stats.wall_s = time.perf_counter() - t0
        return outputs, stats


class StreamingPipelineServer:
    """Serving front-end over the event-driven cluster runtime.

    Where :class:`PipelineServer` replays the closed-form pipeline
    recurrence, this feeds a request stream through
    ``runtime.PipelineRuntime``: per-device virtual clocks, timed
    links, optional churn injection and dynamic re-planning — with the
    real per-stage JAX numerics.  The deployment form of the paper's
    testbed runs.
    """

    def __init__(self, model: CNNDef, cluster: Cluster,
                 t_lim: float = _UNSET, config=None, churn=(),
                 backend: str | None = _UNSET, cost_table=None,
                 plan_spec: PlanSpec | None = None,
                 exec_spec: ExecSpec | None = None,
                 deploy_spec: DeploySpec | None = None, pico=None):
        from ..runtime import RuntimeConfig
        plan_spec, exec_spec = _resolve_specs(
            "repro.serving.StreamingPipelineServer", t_lim, backend,
            plan_spec, exec_spec)
        if deploy_spec is not None and config is not None:
            raise TypeError("pass either deploy_spec= or config=, not both")
        if deploy_spec is not None:
            config = deploy_spec.to_runtime_config()
        self.model = model
        self.cluster = cluster
        self._runtime_kw = dict(
            cluster=cluster, plan_spec=plan_spec, exec_spec=exec_spec,
            config=config or RuntimeConfig(), churn=churn,
            cost_table=cost_table, pico=pico)
        self.params = None

    def load(self, key=None):
        """Initialize weights (idempotent — see
        :func:`_load_params_idempotent`)."""
        return _load_params_idempotent(self, key)

    def serve(self, requests: list[Request]) -> tuple[list, ServeStats]:
        assert self.params is not None, "call load() first"
        from ..runtime import PipelineRuntime
        t0 = time.perf_counter()
        rt = PipelineRuntime(model=self.model, params=self.params,
                             **self._runtime_kw)
        # the runtime admits frames in arrival order; remember which
        # original request each frame id maps to so outputs/latencies
        # come back in the caller's order (same contract as
        # PipelineServer.serve: outputs[i] answers requests[i])
        order = sorted(range(len(requests)),
                       key=lambda i: requests[i].arrival)
        rep = rt.run(inputs=[requests[i].payload for i in order],
                     arrivals=[requests[i].arrival for i in order])
        done_at = {fid: done for fid, _, done in rep.completions}
        stats = ServeStats(period_model_s=rep.period)
        outputs = [{} for _ in requests]
        fid_of = {orig: fid for fid, orig in enumerate(order)}
        for orig, req in enumerate(requests):
            fid = fid_of[orig]
            outputs[orig] = rep.outputs.get(fid, {})
            stats.record(max(0.0, done_at[fid] - req.arrival))
        stats.wall_s = time.perf_counter() - t0
        return outputs, stats
