"""Wire transports for :mod:`repro.dist` — how stage tensors move.

Both transports share **one codec**: a length-prefixed frame carrying a
strict-JSON header (message kind, frame ids, tensor dtype/shape specs,
metadata) followed by the raw tensor bytes::

    u64 frame_len | b"PICO" | u32 header_len | header JSON | tensor bytes

The in-memory transport passes the *encoded bytes* through a queue pair
rather than the Python objects, so the memory and TCP paths exercise
the identical serialization — results are byte-identical by
construction, and a test can assert it.  Sends are chunked
(``chunk_bytes``) with per-link byte counters and send-latency
histograms published to ``repro.obs``
(``dist.link.bytes_sent`` / ``dist.link.bytes_recv`` /
``dist.link.send_s``).

Messages are plain data (:class:`Message`): ``kind`` is the protocol
verb (``frame``/``result``/``stop``/``hello``/``ready``/``heartbeat``/
``stats``/``die``/``wire``), ``fids`` the frame ids a data message
carries (len > 1 = micro-batch with a leading frame axis), ``tensors``
named ndarrays, ``meta`` a JSON-safe dict.
"""

from __future__ import annotations

import json
import queue
import socket
import struct
import time
from dataclasses import dataclass, field

import numpy as np

from ..obs import metrics as obs_metrics

MAGIC = b"PICO"
_LEN = struct.Struct("<Q")
_HLEN = struct.Struct("<I")

#: Message kinds understood by the launcher/worker protocol.
KINDS = ("frame", "result", "stop", "hello", "ready", "heartbeat",
         "stats", "die", "wire", "error")


@dataclass
class Message:
    """One protocol message: verb + frame ids + named tensors + meta."""

    kind: str
    fids: list[int] = field(default_factory=list)
    tensors: dict[str, np.ndarray] = field(default_factory=dict)
    meta: dict = field(default_factory=dict)


def encode(msg: Message) -> bytes:
    """Message -> one framed byte string (header JSON + tensor bytes)."""
    specs, blobs = [], []
    for name, arr in msg.tensors.items():
        a = np.asarray(arr)
        if not a.flags["C_CONTIGUOUS"]:
            # NOT ascontiguousarray: that promotes 0-d arrays to 1-d,
            # silently changing the tensor's shape on the wire
            a = np.ascontiguousarray(a).reshape(a.shape)
        specs.append({"name": name, "dtype": str(a.dtype),
                      "shape": list(a.shape)})
        blobs.append(a.tobytes())
    header = json.dumps({"kind": msg.kind, "fids": list(msg.fids),
                         "meta": msg.meta, "tensors": specs},
                        sort_keys=True).encode()
    body = MAGIC + _HLEN.pack(len(header)) + header + b"".join(blobs)
    return _LEN.pack(len(body)) + body


def decode(body: bytes) -> Message:
    """Inverse of :func:`encode` (body excludes the u64 length prefix)."""
    if body[:4] != MAGIC:
        raise ValueError(f"bad frame magic {body[:4]!r}")
    hlen, = _HLEN.unpack_from(body, 4)
    header = json.loads(body[8:8 + hlen].decode())
    off = 8 + hlen
    tensors = {}
    for spec in header["tensors"]:
        dt = np.dtype(spec["dtype"])
        n = int(np.prod(spec["shape"], dtype=np.int64)) if spec["shape"] \
            else 1
        nbytes = n * dt.itemsize
        tensors[spec["name"]] = np.frombuffer(
            body[off:off + nbytes], dtype=dt).reshape(spec["shape"])
        off += nbytes
    if off != len(body):
        raise ValueError(f"frame length mismatch: consumed {off} of "
                         f"{len(body)} bytes")
    return Message(header["kind"], list(header["fids"]), tensors,
                   header["meta"])


class Transport:
    """One directed link endpoint.  Concrete transports implement
    ``_send_bytes``/``_recv_bytes``; accounting and the codec are
    shared here."""

    def __init__(self, link: str = "link", chunk_bytes: int = 1 << 20,
                 metrics=None):
        self.link = link
        self.chunk_bytes = int(chunk_bytes)
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.sends = 0
        self.recvs = 0
        self.send_s = 0.0
        self._metrics = (metrics if metrics is not None
                         else obs_metrics.default_registry())

    # -- public API ------------------------------------------------------
    def send(self, msg: Message) -> int:
        """Encode and ship one message; returns bytes put on the wire."""
        wire = encode(msg)
        t0 = time.perf_counter()
        self._send_bytes(wire)
        dt = time.perf_counter() - t0
        self.bytes_sent += len(wire)
        self.sends += 1
        self.send_s += dt
        self._metrics.counter("dist.link.bytes_sent", link=self.link).inc(
            len(wire))
        self._metrics.histogram("dist.link.send_s", link=self.link).observe(
            dt)
        return len(wire)

    def recv(self, timeout: float | None = None) -> Message | None:
        """Next message, or ``None`` on timeout.  A timeout never
        corrupts framing: partially received frames are buffered and
        completed by the next call."""
        body = self._recv_bytes(timeout)
        if body is None:
            return None
        self.bytes_recv += len(body) + _LEN.size
        self.recvs += 1
        self._metrics.counter("dist.link.bytes_recv", link=self.link).inc(
            len(body) + _LEN.size)
        return decode(body)

    def close(self) -> None:  # pragma: no cover - overridden
        pass

    # -- to implement ----------------------------------------------------
    def _send_bytes(self, wire: bytes) -> None:
        raise NotImplementedError

    def _recv_bytes(self, timeout: float | None) -> bytes | None:
        raise NotImplementedError


class MemoryTransport(Transport):
    """Queue-backed link endpoint carrying *encoded* frames, so the
    in-memory path shares the TCP codec byte-for-byte.  One queue is a
    directed link: build both ends with :func:`memory_pair`."""

    def __init__(self, q: "queue.Queue[bytes]", link: str = "mem",
                 chunk_bytes: int = 1 << 20, metrics=None):
        super().__init__(link=link, chunk_bytes=chunk_bytes, metrics=metrics)
        self._q = q
        self._closed = False

    def _send_bytes(self, wire: bytes) -> None:
        if self._closed:
            raise ConnectionError(f"link {self.link} is closed")
        # chunked like TCP so per-chunk accounting matches; the receiver
        # end reassembles from the length prefix
        for off in range(0, len(wire), self.chunk_bytes):
            self._q.put(wire[off:off + self.chunk_bytes])

    def _recv_bytes(self, timeout: float | None) -> bytes | None:
        deadline = None if timeout is None else time.monotonic() + timeout
        buf = getattr(self, "_buf", b"")
        while True:
            if len(buf) >= _LEN.size:
                total, = _LEN.unpack_from(buf)
                if len(buf) >= _LEN.size + total:
                    body = buf[_LEN.size:_LEN.size + total]
                    self._buf = buf[_LEN.size + total:]
                    return body
            try:
                remaining = (None if deadline is None
                             else max(0.0, deadline - time.monotonic()))
                chunk = self._q.get(timeout=remaining)
            except queue.Empty:
                self._buf = buf
                return None
            if chunk is None:           # close sentinel
                self._buf = buf
                raise ConnectionError(f"link {self.link} closed by peer")
            buf += chunk

    def close(self) -> None:
        self._closed = True
        self._q.put(None)


def memory_pair(link: str = "mem", chunk_bytes: int = 1 << 20,
                metrics=None) -> tuple[MemoryTransport, MemoryTransport]:
    """(sender, receiver) endpoints over one directed in-memory link."""
    q: "queue.Queue[bytes]" = queue.Queue()
    return (MemoryTransport(q, link=link, chunk_bytes=chunk_bytes,
                            metrics=metrics),
            MemoryTransport(q, link=link, chunk_bytes=chunk_bytes,
                            metrics=metrics))


class TCPTransport(Transport):
    """A connected TCP stream endpoint (length-prefixed frames,
    chunked ``sendall``).  Safe for one sender thread plus one receiver
    thread; a recv timeout leaves any partial frame buffered."""

    def __init__(self, sock: socket.socket, link: str = "tcp",
                 chunk_bytes: int = 1 << 20, metrics=None):
        super().__init__(link=link, chunk_bytes=chunk_bytes, metrics=metrics)
        self._sock = sock
        self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._buf = b""
        self._want = None        # frame length being accumulated

    @classmethod
    def connect(cls, addr: tuple[str, int], link: str = "tcp",
                chunk_bytes: int = 1 << 20, metrics=None,
                timeout: float = 30.0) -> "TCPTransport":
        """Connect with retry until ``timeout`` (peers race to bind)."""
        deadline = time.monotonic() + timeout
        last: Exception | None = None
        while time.monotonic() < deadline:
            try:
                sock = socket.create_connection(addr, timeout=timeout)
                return cls(sock, link=link, chunk_bytes=chunk_bytes,
                           metrics=metrics)
            except OSError as e:        # peer not listening yet
                last = e
                time.sleep(0.02)
        raise ConnectionError(f"cannot connect {link} to {addr}: {last}")

    def _send_bytes(self, wire: bytes) -> None:
        for off in range(0, len(wire), self.chunk_bytes):
            self._sock.sendall(wire[off:off + self.chunk_bytes])

    def _recv_bytes(self, timeout: float | None) -> bytes | None:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self._want is None and len(self._buf) >= _LEN.size:
                self._want, = _LEN.unpack_from(self._buf)
                self._buf = self._buf[_LEN.size:]
            if self._want is not None and len(self._buf) >= self._want:
                body = self._buf[:self._want]
                self._buf = self._buf[self._want:]
                self._want = None
                return body
            remaining = (None if deadline is None
                         else max(0.0, deadline - time.monotonic()))
            expired = remaining is not None and remaining <= 0
            # timeout 0 degrades to one non-blocking poll, so buffered
            # kernel bytes are still drained before giving up
            self._sock.settimeout(remaining if not expired else 0.0)
            try:
                chunk = self._sock.recv(self.chunk_bytes)
            except (BlockingIOError, socket.timeout, TimeoutError):
                return None
            except OSError as e:
                raise ConnectionError(
                    f"link {self.link} recv failed: {e}") from e
            if not chunk:
                raise ConnectionError(f"link {self.link} closed by peer")
            self._buf += chunk

    def close(self) -> None:
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()


class TCPListener:
    """Bound listening socket (``port=0`` = ephemeral); accepts peers
    as :class:`TCPTransport` endpoints."""

    def __init__(self, host: str = "127.0.0.1", port: int = 0):
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(16)
        self.addr: tuple[str, int] = self._sock.getsockname()

    @property
    def port(self) -> int:
        return self.addr[1]

    def accept(self, link: str = "tcp", chunk_bytes: int = 1 << 20,
               metrics=None, timeout: float = 30.0) -> TCPTransport:
        self._sock.settimeout(timeout)
        try:
            sock, _ = self._sock.accept()
        except (socket.timeout, TimeoutError):
            raise TimeoutError(f"no peer connected to {self.addr} within "
                               f"{timeout}s") from None
        return TCPTransport(sock, link=link, chunk_bytes=chunk_bytes,
                            metrics=metrics)

    def close(self) -> None:
        self._sock.close()
