"""Distributed stage worker: one pipeline stage, one persistent loop.

A worker never sees live Python objects from the launcher: its whole
configuration is one JSON *worker payload* — the versioned Deployment
artifact (plan + specs + model graph + CostTable, exactly what
``Deployment.save`` writes) plus this worker's stage index and link
roles.  Rebuilding from the artifact is the hand-off contract:
``Deployment.from_json`` re-installs the CostTable's autotuned kernel
winners process-wide (the executable-cache warmup), and model weights
are re-initialized deterministically from the payload seed, so every
worker — thread or spawned process — holds bit-identical state.

The loop is ``recv -> StageExecutor compiled segment -> send``:
micro-batched messages go through the ``lax.scan`` ``run_frames`` path,
heartbeats are emitted on the control link between frames, and a
``stop`` received from upstream is forwarded downstream *after* all
data messages (links are FIFO), which is what makes the launcher's
drain lossless.  ``die`` simulates a crash: the worker exits silently
— no stop forwarded, no stats, links left dangling — so peer-timeout
detection can be drilled.
"""

from __future__ import annotations

import json
import time
import traceback

import numpy as np

from .transport import Message, TCPListener, TCPTransport


def build_payload(deployment_json: str, stage: int, *, worker: str,
                  devices: list[str], recv_nodes: list[str],
                  recv_image: bool, forward: list[str], forward_image: bool,
                  last: bool, seed: int, heartbeat_s: float,
                  start_timeout_s: float, chunk_bytes: int,
                  epoch_wall: float, trace: bool) -> dict:
    """The JSON-safe worker payload (see module docstring)."""
    return {"deployment": deployment_json, "stage": stage, "worker": worker,
            "devices": list(devices), "recv_nodes": list(recv_nodes),
            "recv_image": bool(recv_image), "forward": list(forward),
            "forward_image": bool(forward_image), "last": bool(last),
            "seed": int(seed), "heartbeat_s": float(heartbeat_s),
            "start_timeout_s": float(start_timeout_s),
            "chunk_bytes": int(chunk_bytes),
            "epoch_wall": float(epoch_wall), "trace": bool(trace)}


class StageWorker:
    """Persistent stage loop over abstract transports (thread or
    process substrate — the code path is identical)."""

    def __init__(self, payload: dict, upstream, downstream,
                 control_out, control_in=None):
        self.payload = payload
        self.upstream = upstream
        self.downstream = downstream
        self.control_out = control_out
        self.control_in = control_in
        self.name = payload["worker"]
        self.stage_index = payload["stage"]
        self.frames = 0
        self.compute_s = 0.0
        self.spans: list[list] = []
        self._silent = False          # die received: simulate a crash

    # -- lifecycle -------------------------------------------------------
    def run(self) -> None:
        try:
            self._setup()
            self._send_ctrl("ready")
            self._loop()
        except ConnectionError as e:
            self._send_error(f"link failure: {e}")
        except Exception:
            self._send_error(traceback.format_exc())
        finally:
            if not self._silent:
                self._close()

    def _setup(self) -> None:
        import jax

        from ..api.deployment import Deployment
        from ..pipeline.stage import StageExecutor

        p = self.payload
        # the artifact round-trip IS the hand-off: from_json re-applies
        # the exec-spec cache bound and installs the shipped CostTable's
        # autotuned kernel winners (per-worker executable warmup)
        dep = Deployment.from_json(p["deployment"])
        st = dep.pico.pipeline.stages[self.stage_index]
        spec = dep.exec_spec
        # built exactly the way PipelineRunner builds its executors
        # (backend/mode only), so the executable-cache key — and the
        # numerics — match the single-process compiled path bit-for-bit
        self.executor = StageExecutor(
            dep.model, st.nodes, list(st.fractions),
            name=f"stage{self.stage_index}", backend=spec.backend,
            mode=spec.mode)
        self.params = dep.model.init(jax.random.PRNGKey(p["seed"]))
        self.heartbeat_s = p["heartbeat_s"]
        self.epoch = p["epoch_wall"]
        self.trace = p["trace"]
        self.forward = list(p["forward"])
        self.forward_image = p["forward_image"]
        self.last = p["last"]
        self._last_hb = 0.0

    def _loop(self) -> None:
        while True:
            self._heartbeat()
            if self._poll_control():
                return                          # die: simulated crash
            msg = self.upstream.recv(timeout=self.heartbeat_s)
            if msg is None:
                continue
            if msg.kind == "stop":
                # FIFO links: every data message is already behind us,
                # so forwarding stop completes the lossless drain
                self.downstream.send(msg)
                self._send_stats()
                return
            if msg.kind == "frame":
                self._frame(msg)

    def _frame(self, msg: Message) -> None:
        produced = {k: v for k, v in msg.tensors.items()
                    if k != "__image__"}
        image = msg.tensors.get("__image__")
        t_wall = time.time()
        t0 = time.perf_counter()
        if len(msg.fids) > 1:
            outs = self.executor.run_frames(self.params, produced, image)
        else:
            outs = self.executor(self.params, produced, image)
        outs = {k: np.asarray(v) for k, v in outs.items()}   # blocks
        dt = time.perf_counter() - t0
        if not msg.meta.get("warmup"):
            # the probe's wall is dominated by the stage compile — keep
            # it out of the steady-state compute stats validate() rates
            self.frames += len(msg.fids)
            self.compute_s += dt
        if self.trace:
            self.spans.append(["stage.compute", t_wall - self.epoch, dt,
                               {"stage": self.stage_index,
                                "worker": self.name,
                                "frames": len(msg.fids),
                                "fid": msg.fids[0]}])
        avail = dict(produced)
        avail.update(outs)
        out = {n: avail[n] for n in self.forward}
        if self.forward_image:
            out["__image__"] = image
        self.downstream.send(Message("result" if self.last else "frame",
                                     msg.fids, out, msg.meta))

    # -- control ---------------------------------------------------------
    def _heartbeat(self) -> None:
        now = time.monotonic()
        if now - self._last_hb >= self.heartbeat_s:
            self._last_hb = now
            self._send_ctrl("heartbeat")

    def _poll_control(self) -> bool:
        if self.control_in is None:
            return False
        try:
            msg = self.control_in.recv(timeout=0.0)
        except ConnectionError:
            return False
        if msg is not None and msg.kind == "die":
            self._silent = True
            return True
        return False

    def _send_ctrl(self, kind: str, **meta) -> None:
        meta.setdefault("worker", self.name)
        meta.setdefault("stage", self.stage_index)
        try:
            self.control_out.send(Message(kind, meta=meta))
        except (ConnectionError, OSError):
            pass                    # launcher gone: nothing to tell

    def _send_stats(self) -> None:
        self._send_ctrl(
            "stats", frames=self.frames, compute_s=self.compute_s,
            bytes_in=self.upstream.bytes_recv,
            bytes_out=self.downstream.bytes_sent,
            send_s=self.downstream.send_s, spans=self.spans)

    def _send_error(self, detail: str) -> None:
        self._send_ctrl("error", detail=detail, frames=self.frames)

    def _close(self) -> None:
        for t in (self.upstream, self.downstream):
            try:
                t.close()
            except Exception:
                pass


def worker_main(payload_path: str, control_host: str,
                control_port: int) -> None:
    """Spawned-process entry point: handshake over the control link,
    wire up the data links, then run the stage loop.

    Protocol: bind an ephemeral data listener -> connect the control
    socket -> ``hello`` (carrying the data port) -> receive ``wire``
    (the downstream address) -> connect downstream -> accept upstream
    -> :meth:`StageWorker.run`.
    """
    with open(payload_path) as f:
        payload = json.load(f)
    chunk = payload["chunk_bytes"]
    start_timeout = payload["start_timeout_s"]
    name = payload["worker"]
    listener = TCPListener()
    control = TCPTransport.connect((control_host, control_port),
                                   link=f"ctrl:{name}", chunk_bytes=chunk,
                                   timeout=start_timeout)
    control.send(Message("hello", meta={"worker": name,
                                        "stage": payload["stage"],
                                        "data_port": listener.port}))
    wire = control.recv(timeout=start_timeout)
    if wire is None or wire.kind != "wire":
        raise TimeoutError(f"worker {name}: no wiring from launcher")
    host, port = wire.meta["downstream"]
    downstream = TCPTransport.connect((host, int(port)),
                                      link=wire.meta["link_out"],
                                      chunk_bytes=chunk,
                                      timeout=start_timeout)
    upstream = listener.accept(link=wire.meta["link_in"], chunk_bytes=chunk,
                               timeout=start_timeout)
    listener.close()
    StageWorker(payload, upstream, downstream, control, control).run()
