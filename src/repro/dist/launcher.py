"""``DistLauncher`` — real pipeline execution of a shipped Deployment.

The launcher turns one :class:`~repro.api.deployment.Deployment` into a
running pipeline of real workers (one per planned stage — the stage's
device tiles execute inside its compiled segment, exactly as in the
single-process path), wires them into a chain of
:mod:`~repro.dist.transport` links, feeds frames in at the head and
collects sink tensors at the tail::

    launcher -> w0(stage0) -> w1(stage1) -> ... -> launcher(sink)

Workers get *no* live Python state: each receives a JSON worker payload
embedding the full versioned Deployment artifact (``dep.to_json()``)
plus its stage index and link roles, and rebuilds model/plan/params
from it (:mod:`repro.dist.worker`).  ``DistSpec.workers`` picks the
substrate — persistent threads (CI mode) or real OS processes via the
multiprocessing *spawn* context — and ``DistSpec.transport`` the link
kind; every combination moves the identical encoded bytes.

Loss accounting mirrors the runtime's zero-dropped-in-flight
guarantee: every submitted frame ends in ``report.outputs`` or in
``report.dropped`` with a reason.  A clean :meth:`shutdown` drains by
sending ``stop`` behind the last data message (FIFO links), so nothing
is lost; a dead worker (heartbeat silence past ``peer_timeout_s``,
control-link EOF, or a worker-reported error) is surfaced as
:class:`~repro.runtime.churn.DeviceLeave` churn events — the same
vocabulary the runtime's drain-and-repartition path reacts to — and
the frames it stranded are reported dropped, ready for resubmission on
a re-planned deployment (``dep.replan(cluster.restricted(alive))``).
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import tempfile
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from ..api.specs import DistSpec
from ..obs import metrics as obs_metrics
from ..obs.trace import Tracer
from ..runtime.churn import DeviceLeave
from .transport import (Message, TCPListener, TCPTransport, memory_pair)
from .worker import StageWorker, build_payload, worker_main


@dataclass
class DistReport:
    """Outcome of one distributed run: every submitted frame is in
    ``outputs`` or in ``dropped`` (fid, reason) — never silently lost."""

    outputs: dict[int, dict[str, np.ndarray]]
    dropped: list[tuple[int, str]]
    submitted: int
    churn_events: list = field(default_factory=list)
    worker_stats: dict[str, dict] = field(default_factory=dict)
    link_stats: dict[str, dict] = field(default_factory=dict)
    wall_s: float = 0.0
    transport: str = "memory"
    workers_mode: str = "thread"
    n_stages: int = 0

    @property
    def completed(self) -> int:
        return len(self.outputs)

    def stage_compute_s(self) -> dict[int, float]:
        """Observed mean compute seconds per frame, by stage index."""
        out = {}
        for st in self.worker_stats.values():
            if st.get("frames"):
                out[st["stage"]] = st["compute_s"] / st["frames"]
        return out

    def utilization(self) -> float:
        """Mean worker busy fraction over the run wall-clock — the
        telemetry sample :meth:`FleetRouter.observe_report` feeds into
        the load-EWMA."""
        if not self.worker_stats or self.wall_s <= 0:
            return 0.0
        busy = sum(st.get("compute_s", 0.0)
                   for st in self.worker_stats.values())
        return min(1.0, busy / (len(self.worker_stats) * self.wall_s))


class _Worker:
    """Launcher-side handle for one worker (either substrate)."""

    def __init__(self, name: str, stage: int, devices: list[str]):
        self.name = name
        self.stage = stage
        self.devices = devices
        self.thread: threading.Thread | None = None
        self.proc = None
        self.ctrl_out = None          # worker -> launcher transport
        self.ctrl_in = None           # launcher -> worker transport
        self.data_port: int | None = None
        self.last_seen: float | None = None
        self.ready = False
        self.stats: dict | None = None
        self.dead_reason: str | None = None

    @property
    def dead(self) -> bool:
        return self.dead_reason is not None


class DistLauncher:
    """Real multi-worker pipeline execution of one Deployment.

    Usage::

        launcher = dep.fleet(DistSpec(workers="thread"))
        report = launcher.run(frames)        # start + execute + drain

    or incrementally: :meth:`start`, :meth:`submit`, then
    :meth:`shutdown` (which returns the :class:`DistReport`).
    """

    def __init__(self, deployment, spec: DistSpec | None = None, *,
                 metrics=None, tracer=None):
        self.dep = deployment
        self.spec = spec or DistSpec()
        self.metrics = (metrics if metrics is not None
                        else getattr(deployment, "metrics", None)
                        or obs_metrics.default_registry())
        self.tracer = (tracer if tracer is not None
                       else getattr(deployment, "tracer", None) or Tracer())
        self.stages = deployment.pico.pipeline.stages
        self.model = deployment.model
        self.churn_events: list[DeviceLeave] = []
        self.workers: list[_Worker] = [
            _Worker(f"w{i}", i, [d.name for d in st.devices])
            for i, st in enumerate(self.stages)]
        self._routing()
        self._feed = None
        self._sink = None
        self._ctrl_q: "queue.Queue[tuple]" = queue.Queue()
        self._reader_threads: list[threading.Thread] = []
        self._stop_readers = False
        self._started = False
        self._closed = False
        self._epoch = None
        self._t_start = None
        self._tmpdir = None
        self._next_fid = 0
        self._pending: dict[int, np.ndarray] = {}   # submitted, unresolved
        self._submit_ts: dict[int, float] = {}
        self.outputs: dict[int, dict[str, np.ndarray]] = {}
        self.dropped: list[tuple[int, str]] = []
        self._submitted = 0
        self._report: DistReport | None = None

    # ------------------------------------------------------------------
    # routing: which tensors each inter-stage link must carry
    # ------------------------------------------------------------------
    def _routing(self) -> None:
        model, stages = self.model, self.stages
        n = len(stages)
        sinks = list(model.graph.sinks())
        needs = [model.boundary_needs(st.nodes) for st in stages]
        owner = {nd: i for i, st in enumerate(stages) for nd in st.nodes}
        # recv[i] = tensors the link *entering* stage i must carry: every
        # boundary pred some stage >= i still needs but an earlier stage
        # produced, plus early-produced graph sinks riding through to the
        # collector; recv[n] is the sink link (final outputs only).
        recv: list[set] = [set() for _ in range(n + 1)]
        recv_img = [False] * (n + 1)
        for i in range(n):
            for j in range(i, n):
                for _, p in needs[j]:
                    if p is None:
                        recv_img[i] = True
                    elif owner[p] < i:
                        recv[i].add(p)
            for s in sinks:
                if owner[s] < i:
                    recv[i].add(s)
        recv[n] = set(sinks)
        recv_img[0] = True              # the head link always feeds frames
        self._recv = [sorted(r) for r in recv]
        self._recv_img = recv_img

    # ------------------------------------------------------------------
    # startup
    # ------------------------------------------------------------------
    def start(self) -> "DistLauncher":
        if self._started:
            return self
        spec = self.spec
        self._epoch = time.time()
        self._t_start = time.perf_counter()
        dep_json = self.dep.to_json()
        payloads = [
            build_payload(
                dep_json, i, worker=w.name, devices=w.devices,
                recv_nodes=self._recv[i], recv_image=self._recv_img[i],
                forward=self._recv[i + 1],
                forward_image=self._recv_img[i + 1],
                last=(i == len(self.stages) - 1), seed=spec.seed,
                heartbeat_s=spec.heartbeat_s,
                start_timeout_s=spec.start_timeout_s,
                chunk_bytes=spec.chunk_bytes, epoch_wall=self._epoch,
                trace=spec.trace)
            for i, w in enumerate(self.workers)]
        with self.tracer.wall_span("dist.launch", track="dist:launcher",
                                   workers=len(self.workers),
                                   mode=spec.workers,
                                   transport=spec.transport):
            if spec.workers == "process":
                self._start_processes(payloads)
            else:
                self._start_threads(payloads)
            self._started = True
            for w in self.workers:
                self._spawn_reader(w)
            self._await_ready()
            self._probe()
        return self

    def _start_threads(self, payloads: list[dict]) -> None:
        spec = self.spec
        n = len(self.workers)
        if spec.transport == "tcp":
            listeners = [TCPListener() for _ in range(n)]
            sink_l = TCPListener()

            def pair(i):
                # sender connects, receiver accepts — same as process mode
                to = (listeners[i].addr if i < n else sink_l.addr)
                label = self._link_label(i)
                s = TCPTransport.connect(to, link=label,
                                         chunk_bytes=spec.chunk_bytes,
                                         metrics=self.metrics)
                lst = listeners[i] if i < n else sink_l
                r = lst.accept(link=label, chunk_bytes=spec.chunk_bytes,
                               metrics=self.metrics)
                lst.close()
                return s, r
        else:
            def pair(i):
                return memory_pair(self._link_label(i),
                                   chunk_bytes=spec.chunk_bytes,
                                   metrics=self.metrics)
        sends, recvs = [], []
        for i in range(n + 1):
            s, r = pair(i)
            sends.append(s)
            recvs.append(r)
        self._feed, self._sink = sends[0], recvs[n]
        for i, w in enumerate(self.workers):
            co_s, co_r = memory_pair(f"ctrl:{w.name}")
            ci_s, ci_r = memory_pair(f"ctrl-in:{w.name}")
            w.ctrl_out, w.ctrl_in = co_r, ci_s
            # the worker parses the payload back from JSON — even on
            # threads, only serialized artifacts cross the boundary
            sw = StageWorker(json.loads(json.dumps(payloads[i])),
                             recvs[i], sends[i + 1], co_s, ci_r)
            w.thread = threading.Thread(target=sw.run, daemon=True,
                                        name=f"dist-{w.name}")
            w.thread.start()

    def _start_processes(self, payloads: list[dict]) -> None:
        import multiprocessing as mp
        spec = self.spec
        ctx = mp.get_context("spawn")
        ctrl_l = TCPListener()
        sink_l = TCPListener()
        self._tmpdir = tempfile.mkdtemp(prefix="repro-dist-")
        for w, payload in zip(self.workers, payloads):
            path = os.path.join(self._tmpdir, f"{w.name}.json")
            with open(path, "w") as f:
                json.dump(payload, f)
            w.proc = ctx.Process(target=worker_main,
                                 args=(path, ctrl_l.addr[0], ctrl_l.port),
                                 name=f"dist-{w.name}", daemon=True)
            w.proc.start()
        deadline = time.monotonic() + spec.start_timeout_s
        hellos = 0
        by_name = {w.name: w for w in self.workers}
        while hellos < len(self.workers):
            ctrl = ctrl_l.accept(link="ctrl",
                                 timeout=max(0.1,
                                             deadline - time.monotonic()))
            msg = ctrl.recv(timeout=max(0.1, deadline - time.monotonic()))
            if msg is None or msg.kind != "hello":
                raise TimeoutError("dist: worker handshake failed "
                                   f"(got {msg and msg.kind!r})")
            w = by_name[msg.meta["worker"]]
            w.ctrl_out = w.ctrl_in = ctrl
            ctrl.link = f"ctrl:{w.name}"
            w.data_port = int(msg.meta["data_port"])
            hellos += 1
        ctrl_l.close()
        host = "127.0.0.1"
        for i, w in enumerate(self.workers):
            if i + 1 < len(self.workers):
                down = [host, self.workers[i + 1].data_port]
            else:
                down = [host, sink_l.port]
            w.ctrl_in.send(Message("wire", meta={
                "downstream": down, "link_in": self._link_label(i),
                "link_out": self._link_label(i + 1)}))
        self._feed = TCPTransport.connect((host, self.workers[0].data_port),
                                          link=self._link_label(0),
                                          chunk_bytes=spec.chunk_bytes,
                                          metrics=self.metrics,
                                          timeout=spec.start_timeout_s)
        self._sink = sink_l.accept(link=self._link_label(len(self.workers)),
                                   chunk_bytes=spec.chunk_bytes,
                                   metrics=self.metrics,
                                   timeout=spec.start_timeout_s)
        sink_l.close()

    def _link_label(self, i: int) -> str:
        n = len(self.workers)
        if i == 0:
            return "feed"
        if i == n:
            return "sink"
        return f"s{i - 1}->s{i}"

    def _spawn_reader(self, w: _Worker) -> None:
        def read():
            while not self._stop_readers:
                try:
                    msg = w.ctrl_out.recv(timeout=0.2)
                except ConnectionError as e:
                    if not self._stop_readers:
                        self._ctrl_q.put((w.name, "gone", str(e)))
                    return
                if msg is not None:
                    self._ctrl_q.put((w.name, "msg", msg))
        t = threading.Thread(target=read, daemon=True,
                             name=f"dist-ctrl-{w.name}")
        t.start()
        self._reader_threads.append(t)

    def _await_ready(self) -> None:
        deadline = time.monotonic() + self.spec.start_timeout_s
        while not all(w.ready for w in self.workers):
            if time.monotonic() > deadline:
                missing = [w.name for w in self.workers if not w.ready]
                raise TimeoutError(f"dist: workers {missing} not ready "
                                   f"within {self.spec.start_timeout_s}s")
            self._drain_control(block_s=0.1)
            self._raise_if_dead("startup")

    def _probe(self) -> None:
        """Push one all-zeros frame (fid -1) through the whole pipeline
        so every worker compiles its stage executable before real
        traffic — end of start() means warm caches everywhere."""
        h, wdt = self.model.input_size[1], self.model.input_size[0]
        ch = getattr(self.model, "in_channels", 3)
        nb = self.spec.micro_batch
        zeros = np.zeros((h, wdt, ch), np.float32)[None]
        fids = list(range(-nb, 0))
        frames = (zeros if nb == 1
                  else np.stack([zeros] * nb))
        self._feed.send(Message("frame", fids, {"__image__": frames},
                                {"warmup": True}))
        deadline = time.monotonic() + self.spec.start_timeout_s
        while True:
            if time.monotonic() > deadline:
                raise TimeoutError("dist: warmup probe did not complete "
                                   f"within {self.spec.start_timeout_s}s")
            self._drain_control(block_s=0.0)
            self._raise_if_dead("warmup")
            msg = self._sink.recv(timeout=0.1)
            if msg is not None and msg.meta.get("warmup"):
                return

    def _raise_if_dead(self, phase: str) -> None:
        for w in self.workers:
            if w.dead:
                raise RuntimeError(f"dist: worker {w.name} died during "
                                   f"{phase}: {w.dead_reason}")

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def submit(self, frame: np.ndarray) -> int:
        """Queue one frame; returns its fid.  Applies back-pressure via
        ``DistSpec.max_inflight`` (collects while the pipe is full)."""
        self.start()
        while len(self._pending) >= self.spec.max_inflight:
            if not self._step(timeout=0.2):
                break                   # a worker died; run() will abort
        fid = self._next_fid
        self._next_fid += 1
        arr = np.asarray(frame)
        self._pending[fid] = arr
        self._submit_ts[fid] = time.time()
        self._submitted += 1
        self._feed.send(Message("frame", [fid], {"__image__": arr}))
        return fid

    def run(self, frames) -> DistReport:
        """Start, execute ``frames`` end-to-end, drain, and report.

        Frames are submitted in ``micro_batch`` cohorts with
        ``max_inflight`` back-pressure; the returned report accounts
        for every frame (outputs or dropped-with-reason)."""
        self.start()
        frames = [np.asarray(f) for f in frames]
        nb = self.spec.micro_batch
        i = 0
        alive = True
        while i < len(frames) and alive:
            batch = frames[i:i + nb]
            while (len(self._pending) >= max(self.spec.max_inflight,
                                             len(batch))
                   and (alive := self._step(timeout=0.2))):
                pass
            if not alive:
                break
            fids = list(range(self._next_fid, self._next_fid + len(batch)))
            self._next_fid += len(batch)
            now = time.time()
            for fid, f in zip(fids, batch):
                self._pending[fid] = f
                self._submit_ts[fid] = now
            self._submitted += len(batch)
            arr = batch[0] if len(batch) == 1 else np.stack(batch)
            self._feed.send(Message("frame", fids, {"__image__": arr}))
            i += len(batch)
        return self.shutdown()

    def _step(self, timeout: float = 0.2) -> bool:
        """One collect iteration: drain control, check liveness, pull
        at most one sink message.  Returns False once any worker is
        dead (the pipeline cannot complete)."""
        self._drain_control(block_s=0.0)
        self._check_liveness()
        if any(w.dead for w in self.workers):
            return False
        try:
            msg = self._sink.recv(timeout=timeout)
        except ConnectionError as e:
            last = self.workers[-1]
            self._mark_dead(last, f"sink link failed: {e}")
            return False
        if msg is None:
            return True
        if msg.kind == "result" and not msg.meta.get("warmup"):
            self._resolve(msg)
        return msg.kind != "stop"

    def _resolve(self, msg: Message) -> None:
        n = len(msg.fids)
        for k, fid in enumerate(msg.fids):
            if fid < 0 or fid not in self._pending:
                continue
            self.outputs[fid] = {name: np.asarray(t[k] if n > 1 else t)
                                 for name, t in msg.tensors.items()}
            self._pending.pop(fid)
            t0 = self._submit_ts.pop(fid, None)
            if t0 is not None and self.spec.trace:
                now = time.time()
                self.tracer.emit("frame", t0 - self._epoch, now - t0,
                                 track="dist:launcher", fid=fid)

    def _drain_control(self, block_s: float = 0.0) -> None:
        deadline = time.monotonic() + block_s
        by_name = {w.name: w for w in self.workers}
        while True:
            try:
                remaining = max(0.0, deadline - time.monotonic())
                item = self._ctrl_q.get(block=remaining > 0,
                                        timeout=remaining or None)
            except queue.Empty:
                return
            name, kind, payload = item
            w = by_name[name]
            if kind == "gone":
                if w.stats is None and not w.dead:
                    self._mark_dead(w, f"control link lost: {payload}")
                continue
            msg: Message = payload
            w.last_seen = time.monotonic()
            if msg.kind == "ready":
                w.ready = True
            elif msg.kind == "stats":
                w.stats = dict(msg.meta)
            elif msg.kind == "error":
                self._mark_dead(w, f"worker error: "
                                   f"{msg.meta.get('detail', '?')}")
            if self._ctrl_q.empty() and time.monotonic() >= deadline:
                return

    def _check_liveness(self) -> None:
        now = time.monotonic()
        for w in self.workers:
            if w.dead or w.stats is not None or w.last_seen is None:
                continue
            if now - w.last_seen > self.spec.peer_timeout_s:
                self._mark_dead(w, f"heartbeat silent for "
                                   f"{self.spec.peer_timeout_s}s")

    def _mark_dead(self, w: _Worker, reason: str) -> None:
        if w.dead:
            return
        w.dead_reason = reason
        t = time.time() - (self._epoch or time.time())
        for dev in w.devices:
            self.churn_events.append(DeviceLeave(t, dev))
            self.metrics.counter("dist.churn.device_leave").inc()
        self.tracer.instant("dist.churn", t, track="dist:launcher",
                            worker=w.name, reason=reason)

    def kill_worker(self, index: int) -> None:
        """Churn drill: make one worker crash *silently* (no stop, no
        stats) so peer-timeout detection and drop accounting can be
        exercised.  Thread workers honor a ``die`` control message;
        process workers are killed outright."""
        w = self.workers[index]
        if w.proc is not None:
            w.proc.terminate()
        elif w.ctrl_in is not None:
            w.ctrl_in.send(Message("die"))

    # ------------------------------------------------------------------
    # teardown
    # ------------------------------------------------------------------
    def shutdown(self, abort: bool = False) -> DistReport:
        """Drain and stop the pipeline; every in-flight frame either
        completes during the drain or is reported dropped with a
        reason.  Idempotent; returns the final :class:`DistReport`."""
        if self._report is not None:
            return self._report
        if not self._started:
            self._report = self._build_report()
            return self._report
        anyone_dead = any(w.dead for w in self.workers)
        if not abort and not anyone_dead:
            try:
                self._feed.send(Message("stop"))
            except (ConnectionError, OSError):
                anyone_dead = True
            deadline = time.monotonic() + self.spec.shutdown_timeout_s
            draining = not anyone_dead
            while draining and time.monotonic() < deadline:
                self._drain_control(block_s=0.0)
                self._check_liveness()
                if any(w.dead for w in self.workers):
                    break
                try:
                    msg = self._sink.recv(timeout=0.2)
                except ConnectionError:
                    break
                if msg is None:
                    continue
                if msg.kind == "stop":
                    draining = False    # every data message was ahead of it
                elif msg.kind == "result" and not msg.meta.get("warmup"):
                    self._resolve(msg)
            if draining and not any(w.dead for w in self.workers):
                # deadline hit with frames still unresolved
                for fid in sorted(self._pending):
                    self.dropped.append(
                        (fid, f"shutdown drain timed out after "
                              f"{self.spec.shutdown_timeout_s}s"))
                self._pending.clear()
            # stats messages trail the forwarded stop; give them a beat
            stats_deadline = time.monotonic() + 2.0
            while (any(w.stats is None and not w.dead
                       for w in self.workers)
                   and time.monotonic() < stats_deadline):
                self._drain_control(block_s=0.05)
        for w in self.workers:
            if w.dead:
                for fid in sorted(self._pending):
                    self.dropped.append(
                        (fid, f"worker {w.name} dead: {w.dead_reason}"))
                self._pending.clear()
                break
        if abort:
            for fid in sorted(self._pending):
                self.dropped.append((fid, "aborted by shutdown(abort=True)"))
            self._pending.clear()
        self._teardown()
        self._report = self._build_report()
        return self._report

    def _teardown(self) -> None:
        self._stop_readers = True
        for t in (self._feed, self._sink):
            if t is not None:
                try:
                    t.close()
                except Exception:
                    pass
        for w in self.workers:
            for t in (w.ctrl_in, w.ctrl_out):
                if t is not None:
                    try:
                        t.close()
                    except Exception:
                        pass
            if w.thread is not None:
                w.thread.join(timeout=5.0)
            if w.proc is not None:
                w.proc.join(timeout=5.0)
                if w.proc.is_alive():
                    w.proc.kill()
                    w.proc.join(timeout=5.0)
        for t in self._reader_threads:
            t.join(timeout=2.0)
        if self._tmpdir is not None:
            shutil.rmtree(self._tmpdir, ignore_errors=True)
        self._closed = True

    def _build_report(self) -> DistReport:
        wall = (time.perf_counter() - self._t_start
                if self._t_start is not None else 0.0)
        worker_stats = {}
        for w in self.workers:
            st = {"stage": w.stage, "devices": w.devices,
                  "dead": w.dead_reason}
            if w.stats is not None:
                st.update({k: w.stats[k] for k in
                           ("frames", "compute_s", "bytes_in", "bytes_out",
                            "send_s") if k in w.stats})
                self._merge_spans(w, w.stats.get("spans") or [])
                self.metrics.gauge("dist.worker.compute_s",
                                   worker=w.name).set(
                    st.get("compute_s", 0.0))
                self.metrics.gauge("dist.worker.frames", worker=w.name).set(
                    st.get("frames", 0))
            worker_stats[w.name] = st
        link_stats = {}
        for t in (self._feed, self._sink):
            if t is not None:
                link_stats[t.link] = {"bytes_sent": t.bytes_sent,
                                      "bytes_recv": t.bytes_recv,
                                      "sends": t.sends, "recvs": t.recvs,
                                      "send_s": t.send_s}
        self.metrics.counter("dist.frames.completed").inc(len(self.outputs))
        self.metrics.counter("dist.frames.dropped").inc(len(self.dropped))
        return DistReport(
            outputs=self.outputs, dropped=self.dropped,
            submitted=self._submitted,
            churn_events=list(self.churn_events),
            worker_stats=worker_stats, link_stats=link_stats,
            wall_s=wall, transport=self.spec.transport,
            workers_mode=self.spec.workers, n_stages=len(self.stages))

    def _merge_spans(self, w: _Worker, spans: list) -> None:
        """Re-emit worker-side spans on this launcher's tracer, one
        track (= Perfetto process row) per real worker."""
        if not self.spec.trace:
            return
        for name, ts, dur, attrs in spans:
            self.tracer.emit(name, ts, dur, track=f"dist:{w.name}",
                             **{str(k): v for k, v in attrs.items()})
