"""``repro.dist`` — real multi-worker pipeline execution.

PICO's premise is an offline-plan / online-execute split; this package
is the online half made real.  A :class:`~repro.dist.launcher.
DistLauncher` turns a shipped :class:`~repro.api.deployment.Deployment`
artifact into a chain of persistent stage workers — threads locally,
real OS processes via the multiprocessing *spawn* context — moving
length-prefixed framed tensors over pluggable transports
(:mod:`~repro.dist.transport`: in-memory queue pairs and TCP sockets,
one shared codec).  Workers receive only the versioned JSON artifact
(the round-trip is the hand-off; no pickled objects), rebuild
model/plan/params deterministically, and run ``recv -> compiled
StageExecutor -> send`` loops with heartbeats; dead peers surface as
:class:`~repro.runtime.churn.DeviceLeave` churn events and every
submitted frame ends either completed or dropped-with-reason.

The simulator stays the oracle: :func:`~repro.dist.validate.validate`
pins distributed outputs bit-identical to the single-process compiled
path and sanity-checks observed-vs-modeled per-stage cost ratios.

Entry points::

    launcher = dep.fleet(repro.DistSpec())       # public entry point
    report = launcher.run(frames)
    from repro.dist import validate
    assert validate(dep).ok
"""

from .launcher import DistLauncher, DistReport
from .transport import (Message, MemoryTransport, TCPListener, TCPTransport,
                        Transport, decode, encode, memory_pair)
from .validate import DistValidation, make_frames, validate
from .worker import StageWorker, worker_main

__all__ = [
    "DistLauncher", "DistReport", "DistValidation", "MemoryTransport",
    "Message", "StageWorker", "TCPListener", "TCPTransport", "Transport",
    "decode", "encode", "make_frames", "memory_pair", "validate",
    "worker_main",
]
