"""Validate real distributed execution against the simulator oracle.

The repo's discrete-event simulator / single-process compiled runner
stay the source of truth: :func:`validate` runs the *same* frames
through a :class:`~repro.dist.launcher.DistLauncher` and through the
in-process compiled path (chunked identically, so the scan/call split
matches), then asserts

* **bit-identical outputs** — every sink tensor of every frame is
  ``np.array_equal`` between the two paths (the hard gate);
* **zero dropped in-flight frames** across the clean shutdown;
* **observed-vs-modeled cost ratios** — each stage's measured compute
  wall per frame over the plan's modeled ``StageCost.t_comp``.  The
  model prices paper-testbed Raspberry-Pi capacities, not this host,
  so the gate is a sanity band (finite, positive, within
  ``ratio_band``) plus a bounded cross-stage spread, not equality.

Returns a :class:`DistValidation`; ``ok`` is the conjunction, and
``failures`` says what broke.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..api.specs import DistSpec


@dataclass
class DistValidation:
    """Outcome of one dist-vs-oracle comparison."""

    ok: bool
    bit_identical: bool
    max_abs_diff: float
    frames: int
    dropped: int
    ratios: dict[int, float]            # stage -> observed / modeled compute
    ratio_ok: bool
    sim_period: float                   # simulator steady-state period (s)
    report: object                      # the underlying DistReport
    failures: list[str] = field(default_factory=list)

    def describe(self) -> str:
        r = ", ".join(f"s{k}={v:.2g}" for k, v in sorted(self.ratios.items()))
        return (f"dist.validate: {'OK' if self.ok else 'FAIL'} — "
                f"{self.frames} frames, bit_identical={self.bit_identical} "
                f"(max|diff|={self.max_abs_diff:.3g}), "
                f"dropped={self.dropped}, ratios[{r}]"
                + (f"; failures: {self.failures}" if self.failures else ""))


def make_frames(model, n: int, seed: int = 0) -> list[np.ndarray]:
    """Deterministic pseudo-random input frames shaped like the model's
    graph input ``(1, H, W, C)``."""
    w, h = model.input_size
    ch = getattr(model, "in_channels", 3)
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((1, h, w, ch), dtype=np.float32)
            for _ in range(n)]


def reference_outputs(deployment, frames, micro_batch: int = 1,
                      seed: int = 0) -> list[dict[str, np.ndarray]]:
    """Single-process compiled-path outputs, chunked exactly like the
    launcher chunks (``micro_batch`` cohorts through ``run_frames``,
    singletons through ``__call__``) so the comparison is bit-for-bit
    meaningful."""
    import jax
    import jax.numpy as jnp
    params = deployment.model.init(jax.random.PRNGKey(seed))
    runner = deployment.runner
    outs: list[dict[str, np.ndarray]] = []
    i = 0
    while i < len(frames):
        chunk = frames[i:i + micro_batch]
        if len(chunk) == 1:
            res = runner(params, chunk[0])
            outs.append({k: np.asarray(v) for k, v in res.items()})
        else:
            res = runner.run_frames(params, jnp.stack(chunk))
            for k_i in range(len(chunk)):
                outs.append({k: np.asarray(v[k_i]) for k, v in res.items()})
        i += len(chunk)
    return outs


def validate(deployment, spec: DistSpec | None = None, *, frames: int = 6,
             seed: int = 0, ratio_band: tuple[float, float] = (1e-4, 1e4),
             max_spread: float = 1e4) -> DistValidation:
    """Run ``frames`` random frames through real distributed execution
    and through the in-process oracle; see the module docstring for
    what is asserted.  Raises nothing — inspect ``.ok``/``.failures``
    (tests typically ``assert v.ok, v.describe()``)."""
    spec = spec or DistSpec()
    xs = make_frames(deployment.model, frames, seed=seed)
    launcher = deployment.fleet(spec)
    rep = launcher.run(xs)
    ref = reference_outputs(deployment, xs, micro_batch=spec.micro_batch,
                            seed=spec.seed)
    failures: list[str] = []
    if rep.dropped:
        failures.append(f"{len(rep.dropped)} dropped frame(s): "
                        f"{rep.dropped[:3]}")
    max_diff = 0.0
    bit_identical = True
    for fid, want in enumerate(ref):
        got = rep.outputs.get(fid)
        if got is None:
            bit_identical = False
            failures.append(f"frame {fid} missing from dist outputs")
            continue
        for sink, arr in want.items():
            g = got.get(sink)
            if g is None or g.shape != arr.shape or not np.array_equal(g,
                                                                       arr):
                bit_identical = False
                d = (float(np.max(np.abs(np.asarray(g, np.float64)
                                         - np.asarray(arr, np.float64))))
                     if g is not None and g.shape == arr.shape
                     else float("inf"))
                max_diff = max(max_diff, d)
                failures.append(f"frame {fid} sink {sink!r} differs "
                                f"(max|diff|={d:.3g})")
    # observed-vs-modeled cost ratios (simulator as the cost oracle)
    observed = rep.stage_compute_s()
    stages = deployment.pico.pipeline.stages
    ratios: dict[int, float] = {}
    for i, st in enumerate(stages):
        obs = observed.get(i)
        modeled = st.cost.t_comp
        if obs is None:
            failures.append(f"stage {i}: no observed compute stats")
            continue
        if modeled <= 0:
            continue                    # nothing to compare against
        ratios[i] = obs / modeled
    ratio_ok = bool(ratios)
    lo, hi = ratio_band
    for i, r in ratios.items():
        if not (np.isfinite(r) and lo <= r <= hi):
            ratio_ok = False
            failures.append(f"stage {i}: observed/modeled ratio {r:.3g} "
                            f"outside [{lo:g}, {hi:g}]")
    if len(ratios) > 1:
        spread = max(ratios.values()) / min(ratios.values())
        if spread > max_spread:
            ratio_ok = False
            failures.append(f"cross-stage ratio spread {spread:.3g} > "
                            f"{max_spread:g}")
    sim = deployment.simulate(frames=max(frames, 2))
    ok = bit_identical and not rep.dropped and ratio_ok
    return DistValidation(
        ok=ok, bit_identical=bit_identical, max_abs_diff=max_diff,
        frames=frames, dropped=len(rep.dropped), ratios=ratios,
        ratio_ok=ratio_ok, sim_period=getattr(sim, "period", 0.0),
        report=rep, failures=failures)
