"""Structured tracing core: explicit spans on one shared timeline.

Every layer of the system — planner, runtime, exec, serving — emits the
*same* span vocabulary (:data:`SPAN_NAMES`), so a simulated run and a
real run produce traces that can be diffed span-for-span.  A span
carries a name (what happened), a track (which actor row it renders
on — one process-row per device actor in Perfetto), a timestamp and
duration in seconds (virtual time for runtime spans, wall time for
host-side spans), and an attribute dict (frame id, stage index, tenant,
modeled-vs-observed seconds, ...).

Two tracer implementations share one interface:

* :class:`Tracer` — records spans into a list and exports
  Chrome-trace / Perfetto JSON (:meth:`Tracer.to_chrome_trace`);
* :class:`NullTracer` — the zero-allocation default: every method is a
  no-op returning cached singletons, so instrumented hot paths cost a
  single attribute lookup and call when tracing is off.

Instrumented library code reaches the active tracer through
:func:`current`; an owner (a :class:`~repro.api.deployment.Deployment`,
the runtime, a test) activates its tracer with :func:`scoped` around
the work it wants captured.
"""

from __future__ import annotations

import json
import math
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping

#: The shared span vocabulary.  Emitters are not restricted to it, but
#: every subsystem's instrumentation sticks to these names so traces
#: from different execution forms (closed-form replay, event-driven
#: runtime, multi-tenant serving) line up.
SPAN_NAMES = (
    "frame",            # one request end-to-end (arrival -> completion)
    "stage.compute",    # one device's compute phase of one stage batch
    "stage.comm",       # inter-stage hand-off transfer
    "halo.exchange",    # intra-stage scatter/gather (tile boundaries)
    "plan",             # a full PICO optimization pass
    "replan",           # runtime churn/drift re-plan (incl. migration)
    "calibrate",        # one stage timed through its compiled executable
    "compile",          # executable-cache miss: stage lowered + jitted
    "cache.lookup",     # executable-cache probe (hit or miss)
    "conv.fallback",    # Pallas conv fell back to the XLA reference
    "sched.admit",      # scheduler admission decision
    "sched.coalesce",   # stage-0 batch formation
    "sched.drain",      # drain window before a re-plan / re-partition
    "sched.repartition",  # cross-tenant device re-split + migration
    "registry.lookup",  # fleet plan-registry probe (hit or miss)
    "fleet.route",      # tenant admission / routing decision
    "fleet.autoscale",  # autoscaler watermark evaluation
    "dist.launch",      # dist worker spawn + handshake + warmup probe
    "dist.churn",       # dist worker declared dead (heartbeat/link/error)
)

#: Default track for host-side (wall-clock) spans.
HOST_TRACK = "host"


@dataclass(frozen=True)
class Span:
    """One traced interval (or instant, when ``dur == 0``).

    ``ts``/``dur`` are seconds on the emitting timeline — virtual
    seconds for runtime spans, wall seconds for host-side spans; the
    Chrome-trace exporter converts to microseconds for display but
    preserves the exact values for round-trips.
    """

    name: str
    ts: float
    dur: float = 0.0
    track: str = HOST_TRACK
    attrs: tuple[tuple[str, Any], ...] = ()

    @property
    def end(self) -> float:
        return self.ts + self.dur

    def attr(self, key: str, default=None):
        """Look up one attribute by name."""
        for k, v in self.attrs:
            if k == key:
                return v
        return default

    @staticmethod
    def freeze_attrs(attrs: Mapping[str, Any]) -> tuple:
        """Attrs as a canonical (sorted, hashable) tuple of pairs."""
        return tuple(sorted(attrs.items()))


class _NullSpanCtx:
    """Reusable no-op context manager returned by NullTracer.wall_span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullSpanCtx()


class NullTracer:
    """The disabled tracer: every emit is a no-op, nothing allocates.

    A single module-level instance (:data:`NULL_TRACER`) is shared by
    every un-traced code path; ``bool(NULL_TRACER)`` is False so hot
    paths can guard optional work (batch fid lists, attr dicts) with
    ``if tracer:``.
    """

    __slots__ = ()
    enabled = False
    spans: tuple = ()

    def __bool__(self) -> bool:
        return False

    def emit(self, name, ts, dur=0.0, track=HOST_TRACK, **attrs) -> None:
        """Record nothing."""

    def instant(self, name, ts, track=HOST_TRACK, **attrs) -> None:
        """Record nothing."""

    def wall_span(self, name, track=HOST_TRACK, **attrs):
        """Return a cached no-op context manager."""
        return _NULL_CTX


NULL_TRACER = NullTracer()


class _WallSpanCtx:
    """Context manager measuring a wall-clock span for a live Tracer."""

    __slots__ = ("_tracer", "_name", "_track", "_attrs", "_t0")

    def __init__(self, tracer, name, track, attrs):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._attrs = attrs

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t0 = self._t0
        self._tracer.emit(self._name, t0 - self._tracer.epoch,
                          time.perf_counter() - t0, track=self._track,
                          **self._attrs)
        return False


class Tracer:
    """Span recorder with Chrome-trace / Perfetto JSON export.

    Spans are appended in emission order; tracks (Perfetto process
    rows) are created on first use in a stable order.  ``epoch`` anchors
    wall-clock spans (:meth:`wall_span`) so their timestamps start near
    zero like virtual-time spans do.
    """

    enabled = True

    def __init__(self):
        self.spans: list[Span] = []
        self.epoch = time.perf_counter()

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self.spans)

    def emit(self, name: str, ts: float, dur: float = 0.0,
             track: str = HOST_TRACK, **attrs) -> None:
        """Record one span at ``ts`` lasting ``dur`` seconds on ``track``."""
        self.spans.append(Span(name, float(ts), float(dur), track,
                               Span.freeze_attrs(attrs)))

    def instant(self, name: str, ts: float, track: str = HOST_TRACK,
                **attrs) -> None:
        """Record a zero-duration marker."""
        self.emit(name, ts, 0.0, track=track, **attrs)

    def wall_span(self, name: str, track: str = HOST_TRACK, **attrs):
        """Context manager timing a host-side block with perf_counter."""
        return _WallSpanCtx(self, name, track, attrs)

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------

    def tracks(self) -> list[str]:
        """Track names in order of first appearance."""
        seen: dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s.track, None)
        return list(seen)

    def by_name(self, name: str) -> list[Span]:
        return [s for s in self.spans if s.name == name]

    # ------------------------------------------------------------------
    # Chrome trace / Perfetto export
    # ------------------------------------------------------------------

    def to_chrome_trace(self) -> dict:
        """Export as Chrome-trace JSON (the format Perfetto opens).

        One *process row* per track: each track gets its own ``pid``
        with a ``process_name`` metadata event, so devices render as
        separate rows in the Perfetto UI.  Intervals are complete
        (``ph: "X"``) events; instants are ``ph: "i"``.  The exact
        float seconds are carried in ``args`` (``ts_s``/``dur_s``) so
        :func:`from_chrome_trace` reloads are bit-identical despite the
        microsecond display unit.
        """
        events: list[dict] = []
        pids: dict[str, int] = {}
        for track in self.tracks():
            pid = pids[track] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name", "pid": pid,
                           "tid": 0, "args": {"name": track}})
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": 0, "args": {"name": track}})
        for s in self.spans:
            args = {k: _jsonable(v) for k, v in s.attrs}
            args["ts_s"] = s.ts
            args["dur_s"] = s.dur
            ev = {"name": s.name, "cat": s.name, "pid": pids[s.track],
                  "tid": 0, "ts": s.ts * 1e6, "args": args}
            if s.dur > 0.0:
                ev["ph"] = "X"
                ev["dur"] = s.dur * 1e6
            else:
                ev["ph"] = "i"
                ev["s"] = "t"
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_json(self, **dump_kw) -> str:
        dump_kw.setdefault("sort_keys", True)
        return json.dumps(self.to_chrome_trace(), **dump_kw)

    def save(self, path) -> str:
        """Write the Perfetto JSON trace to ``path``; returns the path."""
        import os
        with open(path, "w") as f:
            f.write(self.to_json(indent=1))
            f.write("\n")
        return os.fspath(path)


def _jsonable(v):
    """Attr values as strict-JSON scalars (containers via repr)."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        if isinstance(v, float) and not math.isfinite(v):
            return repr(v)
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    return repr(v)


def from_chrome_trace(doc: Mapping) -> list[Span]:
    """Rebuild the span list from :meth:`Tracer.to_chrome_trace` output.

    Uses the exact ``ts_s``/``dur_s`` values stashed in ``args`` (the
    microsecond fields are display-only), so an emit → export → reload
    cycle reproduces the original span tree bit-identically.
    """
    errors = validate_chrome_trace(doc)
    if errors:
        raise ValueError(f"invalid chrome trace: {errors[0]} "
                         f"(+{len(errors) - 1} more)" if len(errors) > 1
                         else f"invalid chrome trace: {errors[0]}")
    track_of: dict[int, str] = {}
    for ev in doc["traceEvents"]:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            track_of[ev["pid"]] = ev["args"]["name"]
    spans: list[Span] = []
    for ev in doc["traceEvents"]:
        if ev.get("ph") not in ("X", "i", "I"):
            continue
        args = dict(ev.get("args", {}))
        ts = args.pop("ts_s", ev["ts"] / 1e6)
        dur = args.pop("dur_s", ev.get("dur", 0.0) / 1e6)
        spans.append(Span(ev["name"], float(ts), float(dur),
                          track_of.get(ev["pid"], HOST_TRACK),
                          Span.freeze_attrs(args)))
    return spans


def validate_chrome_trace(doc: Mapping) -> list[str]:
    """Structural validation of a Chrome-trace document.

    Returns a list of human-readable problems (empty = valid):
    ``traceEvents`` must be a list; every event needs a ``ph``; every
    span/instant needs a numeric ``ts`` and a ``pid`` with a
    ``process_name`` metadata row; ``X`` events need a non-negative
    ``dur``.  Used by ``python -m repro.tools.trace --validate``.
    """
    errors: list[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    named_pids: set[int] = set()
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            if not isinstance(ev.get("args", {}).get("name"), str):
                errors.append(f"process_name metadata without a string "
                              f"name: {ev}")
            named_pids.add(ev.get("pid"))
    n_spans = 0
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None:
            errors.append(f"event {i} has no ph field")
            continue
        if ph == "M":
            continue
        if ph not in ("X", "i", "I"):
            errors.append(f"event {i} has unsupported ph {ph!r}")
            continue
        n_spans += 1
        if not isinstance(ev.get("ts"), (int, float)):
            errors.append(f"event {i} ({ev.get('name')!r}) has no "
                          f"numeric ts")
        if not isinstance(ev.get("name"), str):
            errors.append(f"event {i} has no name")
        if ev.get("pid") not in named_pids:
            errors.append(f"event {i} ({ev.get('name')!r}) pid "
                          f"{ev.get('pid')!r} has no process_name row")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({ev.get('name')!r}) X-event "
                              f"without non-negative dur")
    if n_spans == 0:
        errors.append("trace contains no span or instant events")
    return errors


def span_tree(spans: Iterable[Span]) -> dict[str, list[Span]]:
    """Spans grouped by track, each list sorted by (ts, name) — the
    canonical comparison form for round-trip tests and sim-vs-real
    diffs."""
    tree: dict[str, list[Span]] = {}
    for s in spans:
        tree.setdefault(s.track, []).append(s)
    for track in tree:
        tree[track].sort(key=lambda s: (s.ts, s.name, s.dur))
    return tree


# ---------------------------------------------------------------------------
# active-tracer plumbing
# ---------------------------------------------------------------------------

_ACTIVE: Tracer | NullTracer = NULL_TRACER


def current() -> "Tracer | NullTracer":
    """The tracer instrumented library code should emit into.

    Defaults to :data:`NULL_TRACER`; an owner activates its tracer with
    :func:`scoped` (or :func:`activate`) around the work it captures.
    """
    return _ACTIVE


def activate(tracer: "Tracer | NullTracer | None") -> "Tracer | NullTracer":
    """Install ``tracer`` as the process-wide active tracer; returns the
    previous one so callers can restore it (prefer :func:`scoped`).
    ``None`` installs :data:`NULL_TRACER` — :func:`current` never hands
    instrumented code a non-tracer."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = tracer if tracer is not None else NULL_TRACER
    return prev


@contextmanager
def scoped(tracer: "Tracer | NullTracer"):
    """Activate ``tracer`` for the dynamic extent of a with-block."""
    prev = activate(tracer)
    try:
        yield tracer
    finally:
        activate(prev)
