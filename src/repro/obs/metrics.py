"""Metrics registry: counters, gauges, windowed histograms, snapshots.

One substrate for every number the system used to keep in private
ad-hoc dicts — ``runtime.Monitor`` EWMAs, ``ServeStats`` latency lists,
``exec.cache`` counters, conv-backend fallback tallies.  Instruments
are cheap mutable cells keyed by ``(name, labels)``;
:meth:`MetricsRegistry.snapshot` freezes everything into a versioned
strict-JSON document (same envelope discipline as
:mod:`repro.api.artifacts`), and :func:`flatten` turns a snapshot into
the flat ``name -> value`` map the bench-regression gate consumes — so
bench figures, serving reports and the CI gate share one schema.

Quantiles use the nearest-rank method (:func:`quantile`), shared by
:class:`Histogram` and ``serving.ServeStats`` so every surface reports
identical percentiles, including on tiny windows (n < 3) where linear
interpolation degenerates.
"""

from __future__ import annotations

import json
import math
from collections import deque
from typing import Callable, Iterable, Mapping, Sequence

#: Version of the metrics-snapshot payload schema.  Same policy as
#: ``api.artifacts.SCHEMA_VERSION``: loaders reject *newer* payloads;
#: additive evolution (new optional fields) does not bump it.
METRICS_SCHEMA_VERSION = 1

#: Artifact kind in the snapshot envelope.
ARTIFACT_KIND = "metrics"

#: Default bound on histogram windows — enough for smoke-bench streams
#: while keeping long-running serves O(1) in memory.
DEFAULT_WINDOW = 4096

#: The percentiles every histogram snapshot reports.
SNAPSHOT_QUANTILES = (50.0, 95.0, 99.0)


def quantile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of ``values`` (``q`` in [0, 100]).

    The nearest-rank method returns an actual observed sample (rank
    ``ceil(q/100 * n)``), so it is well-defined for any ``n >= 1`` —
    unlike linear interpolation, which degenerates on tiny windows
    (n < 3 collapses p50/p95/p99 toward the midpoint).  Monotone in
    ``q``, exact on the empirical distribution.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    n = len(values)
    if n == 0:
        return 0.0
    s = sorted(values)
    if q == 0.0:
        return float(s[0])
    rank = math.ceil(q / 100.0 * n)          # 1-based
    return float(s[min(n, max(1, rank)) - 1])


def _label_key(labels: Mapping[str, object]) -> tuple:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing count (events, bytes, fallbacks)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (must be >= 0) to the count."""
        if n < 0:
            raise ValueError(f"counter {self.name}: negative inc {n}")
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (ratios, occupancy, config)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Windowed distribution with nearest-rank percentiles.

    Keeps the last ``window`` observations for quantiles plus lifetime
    ``count``/``sum``/``min``/``max``; the snapshot reports p50/p95/p99
    over the window via :func:`quantile`, so histogram percentiles and
    ``ServeStats`` percentiles agree sample-for-sample.
    """

    __slots__ = ("name", "labels", "window", "count", "sum", "min", "max")

    def __init__(self, name: str, labels: tuple = (),
                 window: int = DEFAULT_WINDOW):
        self.name = name
        self.labels = labels
        self.window: deque = deque(maxlen=max(1, int(window)))
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        """Record one sample."""
        v = float(v)
        self.window.append(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the current window."""
        return quantile(list(self.window), q)


class _NullInstrument:
    """Shared no-op counter/gauge/histogram for a disabled registry."""

    __slots__ = ()
    name = "null"
    labels = ()
    value = 0.0
    count = 0
    sum = 0.0
    mean = 0.0

    def inc(self, n: float = 1.0) -> None:
        """Record nothing."""

    def set(self, v: float) -> None:
        """Record nothing."""

    def observe(self, v: float) -> None:
        """Record nothing."""

    def percentile(self, q: float) -> float:
        return 0.0


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    """Disabled registry: every instrument is the shared no-op cell.
    ``bool(NULL_REGISTRY)`` is False so callers can skip optional
    bookkeeping entirely."""

    __slots__ = ()
    enabled = False

    def __bool__(self) -> bool:
        return False

    def counter(self, name: str, **labels) -> _NullInstrument:
        """Return the shared no-op instrument."""
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels) -> _NullInstrument:
        """Return the shared no-op instrument."""
        return _NULL_INSTRUMENT

    def histogram(self, name: str, window: int = DEFAULT_WINDOW,
                  **labels) -> _NullInstrument:
        """Return the shared no-op instrument."""
        return _NULL_INSTRUMENT

    def register_collector(self, fn) -> None:
        """Ignore the collector."""


NULL_REGISTRY = NullRegistry()


class MetricsRegistry:
    """Instrument store keyed by ``(kind, name, sorted labels)``.

    ``counter``/``gauge``/``histogram`` are get-or-create (the same
    call site always returns the same cell).  Subsystems that keep
    their own cheap hot-path state (the executable cache, a serve's
    stats) publish through *collectors*: callables invoked at snapshot
    time to set gauges/counters from that state, so hot paths pay
    nothing extra between snapshots.
    """

    enabled = True

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._collectors: list[Callable[["MetricsRegistry"], None]] = []

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self._metrics)

    # -------------------------------------------------------------- get

    def counter(self, name: str, **labels) -> Counter:
        """Get-or-create the counter ``name`` with ``labels``."""
        key = ("c", name, _label_key(labels))
        c = self._metrics.get(key)
        if c is None:
            c = self._metrics[key] = Counter(name, key[2])
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        """Get-or-create the gauge ``name`` with ``labels``."""
        key = ("g", name, _label_key(labels))
        g = self._metrics.get(key)
        if g is None:
            g = self._metrics[key] = Gauge(name, key[2])
        return g

    def histogram(self, name: str, window: int = DEFAULT_WINDOW,
                  **labels) -> Histogram:
        """Get-or-create the histogram ``name`` with ``labels``."""
        key = ("h", name, _label_key(labels))
        h = self._metrics.get(key)
        if h is None:
            h = self._metrics[key] = Histogram(name, key[2], window=window)
        return h

    def register_collector(self,
                           fn: Callable[["MetricsRegistry"], None]) -> None:
        """Add a snapshot-time publisher (idempotent per function)."""
        if fn not in self._collectors:
            self._collectors.append(fn)

    def clear(self) -> None:
        """Drop every instrument and collector (tests, fresh runs)."""
        self._metrics.clear()
        self._collectors.clear()

    def drop(self, name: str, kind: str | None = None) -> int:
        """Remove every instrument named ``name`` (all label sets;
        optionally restricted to one kind: "c"/"g"/"h").  Returns the
        number of cells removed.  Lets a subsystem scope its accounting
        per run — e.g. ``kernels.conv2d.ops.reset_fallbacks`` — without
        clearing unrelated instruments."""
        keys = [k for k in self._metrics
                if k[1] == name and (kind is None or k[0] == kind)]
        for k in keys:
            del self._metrics[k]
        return len(keys)

    # ------------------------------------------------------------ views

    def counters(self) -> list[Counter]:
        return [m for (k, _, _), m in sorted(self._metrics.items(),
                                             key=lambda kv: kv[0])
                if k == "c"]

    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge (0.0 when absent)."""
        for kind in ("c", "g"):
            m = self._metrics.get((kind, name, _label_key(labels)))
            if m is not None:
                return m.value
        return 0.0

    def total(self, name: str) -> float:
        """Sum of a counter's value across all label sets."""
        return sum(m.value for (k, n, _), m in self._metrics.items()
                   if k == "c" and n == name)

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold ``other``'s instruments into this registry (counters
        add, gauges last-write-win, histogram samples append)."""
        for (kind, name, labels), m in other._metrics.items():
            lbl = dict(labels)
            if kind == "c":
                self.counter(name, **lbl).inc(m.value)
            elif kind == "g":
                self.gauge(name, **lbl).set(m.value)
            else:
                mine = self.histogram(name, window=m.window.maxlen, **lbl)
                for v in m.window:
                    mine.observe(v)
                # lifetime stats beyond the window survive the merge
                extra = m.count - len(m.window)
                if extra > 0:
                    mine.count += extra
                    mine.sum += m.sum - sum(m.window)
                mine.min = min(mine.min, m.min)
                mine.max = max(mine.max, m.max)
        for fn in other._collectors:
            self.register_collector(fn)
        return self

    # --------------------------------------------------------- snapshot

    def snapshot(self, meta: Mapping | None = None) -> dict:
        """Freeze every instrument into a versioned strict-JSON doc.

        Runs registered collectors first, then emits::

            {"artifact": "metrics", "version": 1, "payload": {
              "counters":   [{"name", "labels", "value"}, ...],
              "gauges":     [{"name", "labels", "value"}, ...],
              "histograms": [{"name", "labels", "count", "sum", "mean",
                              "min", "max", "p50", "p95", "p99"}, ...],
              "meta": {...}}}

        Non-finite floats are encoded as ``"Infinity"``-style strings
        (the :mod:`repro.api.specs` float codec) so the document stays
        strict-JSON parseable.
        """
        from ..api.specs import encode_float
        for fn in list(self._collectors):
            fn(self)
        counters, gauges, histograms = [], [], []
        for (kind, name, labels), m in sorted(self._metrics.items(),
                                              key=lambda kv: kv[0]):
            row = {"name": name, "labels": dict(labels)}
            if kind in ("c", "g"):
                row["value"] = encode_float(float(m.value))
                (counters if kind == "c" else gauges).append(row)
            else:
                row.update(count=m.count,
                           sum=encode_float(m.sum),
                           mean=encode_float(m.mean),
                           min=encode_float(m.min if m.count else 0.0),
                           max=encode_float(m.max if m.count else 0.0))
                for q in SNAPSHOT_QUANTILES:
                    row[f"p{q:g}"] = encode_float(m.percentile(q))
                histograms.append(row)
        payload = {"counters": counters, "gauges": gauges,
                   "histograms": histograms, "meta": dict(meta or {})}
        return {"artifact": ARTIFACT_KIND,
                "version": METRICS_SCHEMA_VERSION, "payload": payload}

    def snapshot_json(self, meta: Mapping | None = None, **dump_kw) -> str:
        dump_kw.setdefault("sort_keys", True)
        return json.dumps(self.snapshot(meta), **dump_kw)


def open_snapshot(doc: Mapping) -> dict:
    """Validate a snapshot envelope and return its payload.

    Same version policy as artifact codecs: payloads *newer* than
    :data:`METRICS_SCHEMA_VERSION` are rejected with a clear error;
    older/current versions decode with the current reader.
    """
    if doc.get("artifact") != ARTIFACT_KIND:
        raise ValueError(f"expected a {ARTIFACT_KIND!r} artifact, got "
                         f"{doc.get('artifact')!r}")
    version = doc.get("version")
    if not isinstance(version, int):
        raise ValueError("metrics snapshot has no integer version field")
    if version > METRICS_SCHEMA_VERSION:
        raise ValueError(f"metrics snapshot version {version} is newer "
                         f"than supported {METRICS_SCHEMA_VERSION}")
    try:
        payload = doc["payload"]
    except KeyError:
        raise ValueError("metrics snapshot envelope has no payload field")
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(payload.get(section), list):
            raise ValueError(f"metrics snapshot payload has no {section} "
                             f"list")
    return payload


def _flat_name(row: Mapping) -> str:
    labels = row.get("labels") or {}
    if not labels:
        return row["name"]
    inner = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
    return f"{row['name']}{{{inner}}}"


def flatten(doc: Mapping) -> dict[str, float]:
    """Snapshot -> flat ``name -> value`` map (the bench-gate view).

    Counters and gauges map to their value under ``name`` (labelled
    series under ``name{k=v,...}``); histograms expand to
    ``name.count/.mean/.p50/.p95/.p99/...``.  Non-finite string-encoded
    floats decode back to floats.
    """
    from ..api.specs import decode_float
    payload = open_snapshot(doc)
    flat: dict[str, float] = {}
    for row in payload["counters"] + payload["gauges"]:
        flat[_flat_name(row)] = float(decode_float(row["value"]))
    for row in payload["histograms"]:
        base = _flat_name(row)
        for k in ("count", "sum", "mean", "min", "max",
                  *(f"p{q:g}" for q in SNAPSHOT_QUANTILES)):
            if k in row:
                flat[f"{base}.{k}"] = float(decode_float(row[k]))
    return flat


def registry_from_values(values: Mapping[str, float]) -> MetricsRegistry:
    """Build a registry of gauges from a flat name -> value map (how
    ``benchmarks.run`` lifts its derived figures into snapshot form)."""
    reg = MetricsRegistry()
    for name, v in values.items():
        reg.gauge(name).set(float(v))
    return reg


# ---------------------------------------------------------------------------
# process-global default registry
# ---------------------------------------------------------------------------

_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry for process-global signals: executable
    cache hits/misses, conv-backend fallbacks, compile wall-times.
    Deployment-scoped registries merge it into their snapshots."""
    return _DEFAULT


def percentiles(values: Iterable[float],
                qs: Sequence[float] = SNAPSHOT_QUANTILES) -> dict[str, float]:
    """Convenience: nearest-rank percentiles of ``values`` as a dict."""
    vals = list(values)
    return {f"p{q:g}": quantile(vals, q) for q in qs}
