"""``repro.obs`` — unified tracing, metrics, and profiling.

One observability substrate for every layer: the planner, the
event-driven runtime, the compiled exec path and the serving tier all
emit the same span vocabulary (:data:`~repro.obs.trace.SPAN_NAMES`)
and publish into the same metrics registry, so a simulated run and a
real run can be diffed signal-for-signal.

* :mod:`~repro.obs.trace` — :class:`Tracer` (explicit spans, Chrome
  trace / Perfetto JSON export with one process-row per device actor)
  and the zero-alloc :data:`NULL_TRACER` default;
* :mod:`~repro.obs.metrics` — :class:`MetricsRegistry` (counters,
  gauges, windowed histograms with nearest-rank p50/p95/p99) and the
  versioned JSON snapshot codec shared with the bench gate.

Summarize/validate traces from the shell with
``python -m repro.tools.trace``.
"""

from .trace import (HOST_TRACK, NULL_TRACER, NullTracer, SPAN_NAMES, Span,
                    Tracer, activate, current, from_chrome_trace, scoped,
                    span_tree, validate_chrome_trace)
from .metrics import (Counter, DEFAULT_WINDOW, Gauge, Histogram,
                      METRICS_SCHEMA_VERSION, MetricsRegistry, NULL_REGISTRY,
                      NullRegistry, default_registry, flatten, open_snapshot,
                      percentiles, quantile, registry_from_values)

__all__ = [
    "HOST_TRACK", "NULL_TRACER", "NullTracer", "SPAN_NAMES", "Span",
    "Tracer", "activate", "current", "from_chrome_trace", "scoped",
    "span_tree", "validate_chrome_trace",
    "Counter", "DEFAULT_WINDOW", "Gauge", "Histogram",
    "METRICS_SCHEMA_VERSION", "MetricsRegistry", "NULL_REGISTRY",
    "NullRegistry", "default_registry", "flatten", "open_snapshot",
    "percentiles", "quantile", "registry_from_values",
]
