"""The CNNs used in the paper's evaluation, as PICO graphs.

Padding is explicit geometry (SAME where the original models use it) and
the range machinery makes halo-tiled execution bit-exact, including each
tile's share of boundary zero padding.  Structure classes per the paper:
chain (VGG16, YOLOv2), block (ResNet34, InceptionV3, SqueezeNet,
MobileNetV3), graph (NASNet-style cells).

``scale`` shrinks channel counts for fast CPU tests.
"""

from __future__ import annotations

from .builder import GB, CNNDef


def _c(ch: int, scale: float) -> int:
    return max(1, int(round(ch * scale)))


# ---------------------------------------------------------------------------
# chain structure
# ---------------------------------------------------------------------------

def vgg16(input_size=(224, 224), scale: float = 1.0,
          head: bool = True) -> CNNDef:
    b = GB("vgg16", input_size)
    x = None
    plan = [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)]
    for reps, ch in plan:
        for _ in range(reps):
            x = b.conv(x, _c(ch, scale), k=3, s=1, p=1)
        x = b.pool(x, 2, 2)
    if head:
        x = b.gpool(x)
        x = b.fc(x, _c(4096, scale))
        x = b.fc(x, 1000)
    return b.done()


def yolov2(input_size=(448, 448), scale: float = 1.0) -> CNNDef:
    """Darknet-19 trunk + detection convs: 23 conv, 5 pool (chain)."""
    b = GB("yolov2", input_size)
    x = b.conv(None, _c(32, scale), 3, p=1)
    x = b.pool(x)
    x = b.conv(x, _c(64, scale), 3, p=1)
    x = b.pool(x)
    for ch in (128, 64, 128):
        x = b.conv(x, _c(ch, scale), 3 if ch != 64 else 1, p="same")
    x = b.pool(x)
    for ch in (256, 128, 256):
        x = b.conv(x, _c(ch, scale), 3 if ch != 128 else 1, p="same")
    x = b.pool(x)
    for ch in (512, 256, 512, 256, 512):
        x = b.conv(x, _c(ch, scale), 3 if ch != 256 else 1, p="same")
    x = b.pool(x)
    for ch in (1024, 512, 1024, 512, 1024):
        x = b.conv(x, _c(ch, scale), 3 if ch != 512 else 1, p="same")
    # detection head
    x = b.conv(x, _c(1024, scale), 3, p=1)
    x = b.conv(x, _c(1024, scale), 3, p=1)
    x = b.conv(x, 425, 1)
    return b.done()


# ---------------------------------------------------------------------------
# block structure
# ---------------------------------------------------------------------------

def resnet34(input_size=(224, 224), scale: float = 1.0,
             head: bool = True) -> CNNDef:
    b = GB("resnet34", input_size)
    x = b.conv(None, _c(64, scale), 7, s=2, p=3)
    x = b.pool(x, 3, 2, p=1)

    def basic(x, ch, stride, project):
        c1 = b.conv(x, ch, 3, s=stride, p=1)
        c2 = b.conv(c1, ch, 3, s=1, p=1)
        if project:  # 1x1 projection shortcut (stride/channel change)
            sc = b.conv(x, ch, 1, s=stride, p=0)
            out = b.add([c2, sc])
            b.block([c1, c2, sc, out])
        else:        # identity skip-connection
            out = b.add([c2, x])
            b.block([c1, c2, out])
        return out

    plan = [(3, 64, 1), (4, 128, 2), (6, 256, 2), (3, 512, 2)]
    prev_ch = _c(64, scale)
    for reps, ch, s0 in plan:
        ch = _c(ch, scale)
        for i in range(reps):
            stride = s0 if i == 0 else 1
            x = basic(x, ch, stride, project=(stride != 1 or ch != prev_ch))
            prev_ch = ch
    if head:
        x = b.gpool(x)
        x = b.fc(x, 1000)
    return b.done()


def inceptionv3(input_size=(299, 299), scale: float = 1.0,
                head: bool = True) -> CNNDef:
    """InceptionV3: stem + A blocks + reduction + C blocks (with the
    unbalanced 1x7 / 7x1 kernels of Fig. 6) + reduction."""
    b = GB("inceptionv3", input_size)
    x = b.conv(None, _c(32, scale), 3, s=2, p=0)
    x = b.conv(x, _c(32, scale), 3, p=0)
    x = b.conv(x, _c(64, scale), 3, p=1)
    x = b.pool(x, 3, 2, p=0)
    x = b.conv(x, _c(80, scale), 1)
    x = b.conv(x, _c(192, scale), 3, p=0)
    x = b.pool(x, 3, 2, p=0)

    def inception_a(x, pool_ch):
        b1 = b.conv(x, _c(64, scale), 1)
        b2 = b.conv(x, _c(48, scale), 1)
        b2 = b.conv(b2, _c(64, scale), 5, p=2)
        b3 = b.conv(x, _c(64, scale), 1)
        b3 = b.conv(b3, _c(96, scale), 3, p=1)
        b3 = b.conv(b3, _c(96, scale), 3, p=1)
        b4 = b.pool(x, 3, 1, p=1)
        b4 = b.conv(b4, _c(pool_ch, scale), 1)
        return b.concat([b1, b2, b3, b4])

    def inception_c(x, ch7):
        # 4 branches; b2/b3 carry the unbalanced kernels of Fig. 6
        c7 = _c(ch7, scale)
        b1 = b.conv(x, _c(192, scale), 1)
        b2 = b.conv(x, c7, 1)
        b2 = b.conv(b2, c7, (7, 1), p=(3, 0))        # 1x7 (wide)
        b2 = b.conv(b2, _c(192, scale), (1, 7), p=(0, 3))  # 7x1 (tall)
        b3 = b.conv(x, c7, 1)
        b3 = b.conv(b3, c7, (7, 1), p=(3, 0))
        b3 = b.conv(b3, c7, (1, 7), p=(0, 3))
        b3 = b.conv(b3, c7, (7, 1), p=(3, 0))
        b3 = b.conv(b3, _c(192, scale), (1, 7), p=(0, 3))
        b4 = b.pool(x, 3, 1, p=1)
        b4 = b.conv(b4, _c(192, scale), 1)
        return b.concat([b1, b2, b3, b4])

    def reduction(x, ch):
        r1 = b.conv(x, _c(ch, scale), 3, s=2, p=0)
        r2 = b.conv(x, _c(ch // 2, scale), 1)
        r2 = b.conv(r2, _c(ch, scale), 3, s=2, p=0)
        p = b.pool(x, 3, 2, p=0)
        return b.concat([r1, r2, p])

    for pool_ch in (32, 64, 64):
        x = inception_a(x, pool_ch)
    x = reduction(x, 384)
    for ch7 in (128, 160, 160, 192):
        x = inception_c(x, ch7)
    x = reduction(x, 192)
    if head:
        x = b.gpool(x)
        x = b.fc(x, 1000)
    return b.done()


def squeezenet(input_size=(224, 224), scale: float = 1.0) -> CNNDef:
    b = GB("squeezenet", input_size)
    x = b.conv(None, _c(96, scale), 7, s=2, p=0)
    x = b.pool(x, 3, 2)

    def fire(x, s1, e1, e3):
        sq = b.conv(x, _c(s1, scale), 1)
        ex1 = b.conv(sq, _c(e1, scale), 1)
        ex3 = b.conv(sq, _c(e3, scale), 3, p=1)
        return b.concat([ex1, ex3])

    for (s1, e1, e3) in [(16, 64, 64), (16, 64, 64), (32, 128, 128)]:
        x = fire(x, s1, e1, e3)
    x = b.pool(x, 3, 2)
    for (s1, e1, e3) in [(32, 128, 128), (48, 192, 192), (48, 192, 192),
                         (64, 256, 256)]:
        x = fire(x, s1, e1, e3)
    x = b.pool(x, 3, 2)
    x = fire(x, 64, 256, 256)
    x = b.conv(x, 1000, 1)
    x = b.gpool(x)
    return b.done()


def mobilenetv3(input_size=(224, 224), scale: float = 1.0) -> CNNDef:
    """MobileNetV3-large plan: inverted residual bottlenecks with
    identity skip when stride == 1 and channels match."""
    b = GB("mobilenetv3", input_size)
    x = b.conv(None, _c(16, scale), 3, s=2, p=1)
    cur = _c(16, scale)

    def bneck(x, cur, exp, out, k, s):
        e = b.conv(x, _c(exp, scale), 1)
        d = b.conv(e, _c(exp, scale), k, s=s, p=k // 2)
        p = b.conv(d, _c(out, scale), 1)
        if s == 1 and _c(out, scale) == cur:
            return b.add([p, x]), _c(out, scale)
        return p, _c(out, scale)

    plan = [
        (16, 16, 3, 1), (64, 24, 3, 2), (72, 24, 3, 1),
        (72, 40, 5, 2), (120, 40, 5, 1), (120, 40, 5, 1),
        (240, 80, 3, 2), (200, 80, 3, 1), (184, 80, 3, 1), (184, 80, 3, 1),
        (480, 112, 3, 1), (672, 112, 3, 1),
        (672, 160, 5, 2), (960, 160, 5, 1), (960, 160, 5, 1),
    ]
    for exp, out, k, s in plan:
        x, cur = bneck(x, cur, exp, out, k, s)
    x = b.conv(x, _c(960, scale), 1)
    x = b.gpool(x)
    x = b.fc(x, 1000)
    return b.done()


# ---------------------------------------------------------------------------
# graph structure (NASNet-style)
# ---------------------------------------------------------------------------

def nasnet_cells(n_cells: int = 6, input_size=(224, 224),
                 scale: float = 1.0, width: int = 4,
                 name: str = "nasnet") -> CNNDef:
    """Synthetic NASNet-style graph: each cell combines the two previous
    cells' outputs through ``width`` parallel separable branches — a
    genuine graph structure (no clean block chain)."""
    b = GB(name, input_size)
    prev2 = b.conv(None, _c(44, scale), 3, s=2, p=1)
    prev1 = b.conv(prev2, _c(44, scale), 3, s=1, p=1)
    ch = _c(44, scale)
    for ci in range(n_cells):
        branches = []
        for wi in range(width):
            src = prev1 if wi % 2 == 0 else prev2
            k = 3 if wi % 3 != 2 else 5
            h = b.conv(src, ch, 1)
            h = b.conv(h, ch, k, p=k // 2)
            branches.append(h)
        adds = []
        for i in range(0, len(branches) - 1, 2):
            adds.append(b.add([branches[i], branches[i + 1]]))
        if len(branches) % 2:
            adds.append(branches[-1])
        cell = b.concat(adds) if len(adds) > 1 else adds[0]
        cell = b.conv(cell, ch, 1)  # fit channels
        prev2, prev1 = prev1, cell
        if ci in (n_cells // 3, 2 * n_cells // 3):
            prev1 = b.pool(prev1, 2, 2)
            prev2 = b.pool(prev2, 2, 2)
    return b.done()


ZOO = {
    "vgg16": vgg16,
    "yolov2": yolov2,
    "resnet34": resnet34,
    "inceptionv3": inceptionv3,
    "squeezenet": squeezenet,
    "mobilenetv3": mobilenetv3,
    "nasnet": nasnet_cells,
}


def build(name: str, **kw) -> CNNDef:
    return ZOO[name](**kw)
