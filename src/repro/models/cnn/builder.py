"""Graph-defined, executable CNNs.

A :class:`CNNDef` couples the PICO :class:`~repro.core.graph.Graph`
(used by the planner/cost model) with parameter initialization and an
executable JAX forward over any *segment* of the graph — which is what
the pipeline runtime executes per stage, on halo-extended input tiles.

Only layer kinds that change feature geometry or carry weights are
vertices (conv/pool/fc/add/concat); norm/activation are fused into the
conv vertex (the paper ignores them for the same reason, §2.3).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ...core.graph import Graph, LayerSpec
from ...exec.backends import apply_conv, apply_layer


@dataclass
class CNNDef:
    name: str
    graph: Graph
    input_size: tuple[int, int]      # (W, H)
    in_channels: int = 3
    blocks: list[list[str]] = field(default_factory=list)  # block structure
    backend: str | None = None       # conv lowering (exec.backends); None
    #                                  = the registry default ("xla")

    # ---------------- parameters ----------------
    def init(self, key: jax.Array, dtype=jnp.float32) -> dict[str, dict]:
        params: dict[str, dict] = {}
        for n, spec in self.graph.layers.items():
            if spec.kind == "conv":
                key, k1 = jax.random.split(key)
                fan_in = spec.kernel[0] * spec.kernel[1] * spec.in_channels
                w = jax.random.normal(
                    k1, (spec.kernel[1], spec.kernel[0],
                         spec.in_channels, spec.out_channels), dtype
                ) / math.sqrt(fan_in)
                params[n] = {"w": w, "b": jnp.zeros((spec.out_channels,), dtype)}
            elif spec.kind == "fc":
                key, k1 = jax.random.split(key)
                w = jax.random.normal(k1, (spec.in_channels, spec.out_channels),
                                      dtype) / math.sqrt(spec.in_channels)
                params[n] = {"w": w, "b": jnp.zeros((spec.out_channels,), dtype)}
        return params

    # ---------------- geometry ----------------
    @property
    def full_sizes(self):
        fs = getattr(self, "_full_sizes", None)
        if fs is None:
            fs = self.graph.forward_sizes(self.input_size)
            self._full_sizes = fs
        return fs

    def segment_ranges(self, nodes, sink_ranges):
        """Exact (out_range, in_range) per node for a width-tiled segment."""
        return self.graph.required_ranges(frozenset(nodes), sink_ranges,
                                          self.full_sizes, self.input_size)

    # ---------------- execution ----------------
    def boundary_needs(self, nodes) -> list[tuple[str, str | None]]:
        """(node, outside-pred) pairs the segment needs fed from outside.

        A node with no predecessors at all needs the graph input,
        reported as (node, None).
        """
        nodes = set(nodes)
        g = self.graph
        needs: list[tuple[str, str | None]] = []
        for n in g.topo_order:
            if n not in nodes:
                continue
            ps = g.preds[n]
            if not ps:
                needs.append((n, None))
            else:
                needs.extend((n, p) for p in ps if p not in nodes)
        return needs

    def run_segment(
        self,
        params: Mapping[str, dict],
        nodes: frozenset[str] | set[str] | Sequence[str],
        inputs: Mapping[tuple[str, str | None], jax.Array],
        ranges: tuple[Mapping[str, tuple[int, int]],
                      Mapping[str, tuple[int, int]]] | None = None,
        relu: bool = True,
        backend: str | None = None,
        fusion: Mapping[str, str] | None = None,
    ) -> dict[str, jax.Array]:
        """Execute the sub-DAG ``nodes`` on (halo-extended) width tiles.

        ``inputs[(n, p)]`` is the (N, H, W, C) tile of outside-predecessor
        ``p`` feeding node ``n`` (``p`` None = graph input), covering
        exactly ``ranges[1][n]`` along W.  ``ranges`` is the
        (req_out, req_in) pair from :meth:`segment_ranges`; None means
        full-width (monolithic) execution.  Convs run VALID — padding is
        represented in the graph as explicit geometry, which is what
        makes tiled execution bit-equal to the monolithic run.

        ``backend`` selects the conv lowering (``exec.backends``); None
        uses the model's own ``self.backend``.

        ``fusion`` maps conv -> pool pairs (from
        :func:`repro.exec.compiler.fusable_chains`) to lower as one
        fused kernel call; a pair whose tile ranges do not line up on
        the pool grid silently executes unfused instead.

        Returns {sink: tile covering ranges[0][sink] along W}.
        """
        backend = backend or self.backend
        fusion = fusion or {}
        nodes = set(nodes)
        g = self.graph
        if ranges is None:
            req_out = {n: (0, self.full_sizes[n][0]) for n in nodes}
            req_in = {}
            for n in nodes:
                ps = g.preds[n]
                w_in = (self.full_sizes[ps[0]] if ps else self.input_size)[0]
                req_in[n] = (0, w_in)
        else:
            req_out, req_in = ranges

        def pred_slice(p: str, n: str) -> jax.Array:
            """Slice producer p's tile down to consumer n's input range."""
            a, b = req_in[n]
            pa, _ = req_out[p]
            x = vals[p]
            lo = a - pa
            return x[:, :, lo: lo + (b - a), :]

        def fused_ranges_ok(conv: str, pool: str) -> bool:
            """The fused kernel pools the conv tile in place, so the
            conv tile must start on the pool grid and cover exactly the
            pool's input; anything else runs unfused."""
            kw_p = g.layers[pool].kernel[0]
            ca, cb = req_out[conv]
            pa, pb = req_out[pool]
            return (req_in[pool] == req_out[conv]
                    and ca == pa * kw_p
                    and (cb - ca) // kw_p == pb - pa)

        vals: dict[str, jax.Array] = {}
        for n in g.topo_order:
            if n not in nodes or n in vals:  # in vals: emitted by a fused conv
                continue
            spec = g.layers[n]
            ps = g.preds[n]
            if not ps:
                xs = [inputs[(n, None)]]
            else:
                xs = [pred_slice(p, n) if p in nodes else inputs[(n, p)]
                      for p in ps]
            if spec.kind == "add":
                vals[n] = sum(xs[1:], xs[0])
                continue
            if spec.kind == "concat":
                vals[n] = jnp.concatenate(xs, axis=-1)
                continue
            full_in_w = (self.full_sizes[ps[0]] if ps else self.input_size)[0]
            pad_w = g.tile_padding(n, req_out[n], full_in_w) \
                if spec.kind in ("conv", "pool", "dwconv") else (0, 0)
            if spec.kind == "conv" and n in fusion \
                    and fused_ranges_ok(n, fusion[n]):
                vals[fusion[n]] = apply_conv(
                    spec, params.get(n), xs[0], relu, pad_w, backend=backend,
                    pool_spec=g.layers[fusion[n]])
                continue
            vals[n] = apply_layer(spec, params.get(n), xs[0], relu, pad_w,
                                  backend=backend)
        return {s: vals[s] for s in g.sinks(nodes)}

    def forward(self, params, image: jax.Array, relu: bool = True,
                backend: str | None = None):
        """Monolithic forward over the whole graph (reference path)."""
        srcs = self.graph.sources()
        outs = self.run_segment(params, set(self.graph.layers),
                                {(s, None): image for s in srcs}, relu=relu,
                                backend=backend)
        return outs


def set_conv_backend(name: str):
    """Deprecated: set ``CNNDef.backend`` (or pass ``backend=`` to the
    executors) instead of flipping a process-wide default.

    Unlike the seed's module global (read at apply time), this only
    changes the *default* for executors built afterwards — a
    StageExecutor resolves its backend once at construction, so
    already-built executors keep the numerics they were created with.
    """
    import warnings
    from ...exec import backends as _backends
    warnings.warn("set_conv_backend is deprecated; set CNNDef.backend or "
                  "pass backend= to StageExecutor/PipelineRunner "
                  "(executors built before this call keep their backend)",
                  DeprecationWarning, stacklevel=2)
    assert name in _backends.available_backends(), name
    _backends.DEFAULT_BACKEND = name


# ---------------------------------------------------------------------------
# builder helpers
# ---------------------------------------------------------------------------

class GB:
    """Tiny fluent builder tracking channels automatically."""

    def __init__(self, name: str, input_size=(224, 224), in_channels=3):
        self.d = CNNDef(name, Graph(), input_size, in_channels)
        self.ch: dict[str, int] = {}
        self.sz: dict[str, tuple[int, int]] = {}  # (W, H) per vertex
        self._n = 0

    def _name(self, kind):
        self._n += 1
        return f"{kind}{self._n}"

    def _src_size(self, src):
        return self.sz[src] if src else self.d.input_size

    def conv(self, src, cout, k=3, s=1, p=0, name=None):
        """p may be an int or (pw, ph); 'same' means k//2."""
        cin = self.ch[src] if src else self.d.in_channels
        kk = k if isinstance(k, tuple) else (k, k)
        ss = s if isinstance(s, tuple) else (s, s)
        if p == "same":
            p = (kk[0] // 2, kk[1] // 2)
        pp = p if isinstance(p, tuple) else (p, p)
        name = name or self._name("conv")
        spec = LayerSpec(name, "conv", kk, ss, pp, cin, cout,
                         param_bytes=4 * (kk[0] * kk[1] * cin * cout + cout))
        self.d.graph.add(spec, [src] if src else [])
        self.ch[name] = cout
        self.sz[name] = spec.out_size(self._src_size(src))
        return name

    def pool(self, src, k=2, s=2, p=0, name=None):
        cin = self.ch[src]
        name = name or self._name("pool")
        kk = k if isinstance(k, tuple) else (k, k)
        ss = s if isinstance(s, tuple) else (s, s)
        if p == "same":
            p = (kk[0] // 2, kk[1] // 2)
        pp = p if isinstance(p, tuple) else (p, p)
        spec = LayerSpec(name, "pool", kk, ss, pp, cin, cin)
        self.d.graph.add(spec, [src])
        self.ch[name] = cin
        self.sz[name] = spec.out_size(self._src_size(src))
        return name

    def gpool(self, src, name=None):
        cin = self.ch[src]
        name = name or self._name("gpool")
        self.d.graph.add(LayerSpec(name, "gpool", (1, 1), (1, 1), (0, 0),
                                   cin, cin), [src])
        self.ch[name] = cin
        self.sz[name] = (1, 1)
        return name

    def fc(self, src, cout, cin=None, name=None):
        w, h = self._src_size(src)
        cin = cin if cin is not None else self.ch[src] * w * h
        name = name or self._name("fc")
        self.d.graph.add(LayerSpec(name, "fc", (1, 1), (1, 1), (0, 0),
                                   cin, cout,
                                   param_bytes=4 * (cin * cout + cout)), [src])
        self.ch[name] = cout
        self.sz[name] = (1, 1)
        return name

    def add(self, srcs, name=None):
        name = name or self._name("add")
        c = self.ch[srcs[0]]
        sizes = {self.sz[s] for s in srcs}
        assert len(sizes) == 1, f"add branches disagree on geometry: {sizes}"
        self.d.graph.add(LayerSpec(name, "add", (1, 1), (1, 1), (0, 0), c, c),
                         list(srcs))
        self.ch[name] = c
        self.sz[name] = sizes.pop()
        return name

    def concat(self, srcs, name=None):
        name = name or self._name("concat")
        c = sum(self.ch[s] for s in srcs)
        sizes = {self.sz[s] for s in srcs}
        assert len(sizes) == 1, f"concat branches disagree on geometry: {sizes}"
        self.d.graph.add(LayerSpec(name, "concat", (1, 1), (1, 1), (0, 0),
                                   c, c), list(srcs))
        self.ch[name] = c
        self.sz[name] = sizes.pop()
        return name

    def block(self, nodes):
        self.d.blocks.append(list(nodes))

    def done(self) -> CNNDef:
        return self.d
