"""Graph-defined executable CNNs used in the paper's evaluation."""

from .builder import CNNDef, GB
from . import zoo

__all__ = ["CNNDef", "GB", "zoo"]
