"""Architecture configs for the assigned-architecture pool.

Every config cites its source model card / paper.  ``layer_pattern``
selects the mixer per layer: 'attn' (transformer block), 'mamba'
(Mamba2/SSD block).  ``shared_attn_every`` > 0 inserts a *shared* (one
weight set) attention+MLP block after every k-th layer (Zamba2).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class ArchConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int                 # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    qkv_bias: bool = False
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # structure
    layer_pattern: str = "attn"      # 'attn' | 'mamba'
    shared_attn_every: int = 0       # Zamba2: shared block cadence
    sliding_window: int = 0          # 0 = full (global) attention
    input_mode: str = "tokens"       # 'tokens' | 'embeds' (vlm/audio stubs)
    family: str = "dense"            # dense|moe|ssm|hybrid|vlm|audio
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    source: str = ""

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def vocab_padded(self) -> int:
        """Embedding table padded so the vocab axis shards over 'model'
        (multiple of 512; logits at padded slots are masked)."""
        return _pad_to(self.vocab_size, 512)

    @property
    def is_ssm(self) -> bool:
        return self.layer_pattern == "mamba"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state or a finite attention window."""
        return self.is_ssm or self.sliding_window > 0

    def with_sliding_window(self, window: int) -> "ArchConfig":
        """Sliding-window variant used by pure full-attention archs for
        the long_500k shape (see DESIGN.md §4)."""
        return replace(self, sliding_window=window,
                       name=f"{self.name}-swa{window}")

    def reduced(self, n_layers: int = 2, d_model: int | None = None,
                n_experts: int | None = None) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, d_model or 256)
        # keep head structure but shrink: head_dim <= 64
        if self.n_heads:
            heads = max(2, min(self.n_heads, 4))
            kv = max(1, min(self.n_kv_heads, heads))
            hd = max(8, min(64, d // heads))
        else:
            heads = kv = hd = 0
        ne = min(self.n_experts, 4 if n_experts is None else n_experts)
        return replace(
            self,
            name=f"{self.name}-smoke",
            n_layers=n_layers,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 2 * d) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            n_experts=ne,
            moe_top_k=min(self.moe_top_k, max(1, ne // 2)) if ne else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            shared_attn_every=2 if self.shared_attn_every else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
        )

    def param_count(self) -> int:
        """Approximate parameter count (for 6·N·D roofline sanity)."""
        d, L = self.d_model, self.n_layers
        total = self.vocab_size * d  # embedding
        per = 0
        if self.layer_pattern == "attn":
            per += d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd
            per += self.n_heads * self.hd * d
            if self.is_moe:
                per += self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            else:
                per += 3 * d * self.d_ff
        else:  # mamba
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            per += d * (2 * di) + 2 * d * N + d * H  # in projections
            per += di * self.ssm_conv + di * d       # conv + out proj
        total += L * per
        if self.shared_attn_every:
            sd = d
            total += (sd * self.n_heads * self.hd
                      + 2 * sd * self.n_kv_heads * self.hd
                      + self.n_heads * self.hd * sd + 3 * sd * self.d_ff)
        total += self.vocab_size * d  # output head
        return total

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        inactive = L * (self.n_experts - self.moe_top_k) * 3 * d * self.d_ff
        return self.param_count() - inactive
