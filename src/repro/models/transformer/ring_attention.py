"""Ring attention: sequence-parallel causal attention via shard_map.

The §Perf seqshard iteration showed that plain GSPMD sequence sharding
re-gathers K/V inside the q-block scan (3.5e12 B of all-gather per
step).  The correct construction rotates KV shards around the mesh axis
with ``lax.ppermute`` while each device keeps only its local q rows:
per step, one (B, S/m, K, D) block crosses each link — the minimum
possible traffic — and the S x S score tile never exceeds
(S/m) x (S/m) per device.

Causality: with q shard i and kv shard src = (i - r) mod m, global
positions decide the mask; blocks entirely in the future are skipped
cheaply (the mask zeroes them; TPU grids are static so the matmul still
runs — half the ring steps do useful work, as in published ring
attention).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ...compat import shard_map


def _block_update(carry, q_blk, k_blk, v_blk, q_pos, k_pos,
                  sliding_window: int):
    """Online-softmax update of (m, l, acc) with one kv block.

    q_blk: (B, Sq, K, G, D); k_blk/v_blk: (B, Sk, K, D);
    q_pos: (Sq,), k_pos: (Sk,) global positions.
    """
    m_, l_, acc = carry
    D = q_blk.shape[-1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    mask = q_pos[:, None] >= k_pos[None, :]
    if sliding_window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < sliding_window
    s = jnp.where(mask[None, None, None], s, -1e30)
    m_new = jnp.maximum(m_, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_ - m_new)
    l_new = l_ * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk,
        preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def ring_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   mesh: Mesh | None = None, axis: str = "model",
                   sliding_window: int = 0,
                   axis_size: int | None = None) -> jax.Array:
    """Causal GQA attention with the sequence dim sharded over ``axis``.

    q: (B, S, K, G, D); k/v: (B, S, K, D); S % axis_size == 0.
    ``mesh`` may be None inside jit under an ambient mesh context
    (pass ``axis_size`` then).  Returns (B, S, K, G, D), sharded like q.
    """
    m_size = axis_size if axis_size is not None else mesh.shape[axis]
    B, S, K, G, D = q.shape
    assert S % m_size == 0, (S, m_size)

    def local(q_l, k_l, v_l):
        i = jax.lax.axis_index(axis)
        S_loc = q_l.shape[1]
        q_pos = i * S_loc + jnp.arange(S_loc)

        m0 = jnp.full((B, K, G, S_loc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, S_loc), jnp.float32)
        a0 = jnp.zeros((B, K, G, S_loc, D), jnp.float32)

        def step(r, carry):
            m_, l_, acc, k_cur, v_cur = carry
            src = (i - r) % m_size
            k_pos = src * S_loc + jnp.arange(S_loc)
            m_, l_, acc = _block_update((m_, l_, acc), q_l, k_cur,
                                        v_cur, q_pos, k_pos,
                                        sliding_window)
            # rotate kv to the next device (i receives from i-1)
            perm = [(j, (j + 1) % m_size) for j in range(m_size)]
            k_nxt = jax.lax.ppermute(k_cur, axis, perm)
            v_nxt = jax.lax.ppermute(v_cur, axis, perm)
            return m_, l_, acc, k_nxt, v_nxt

        m_, l_, acc, _, _ = jax.lax.fori_loop(
            0, m_size, step, (m0, l0, a0, k_l, v_l))
        out = acc / jnp.maximum(l_[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1).astype(q_l.dtype)  # (B,S_loc,K,G,D)

    spec_q = P(None, axis, None, None, None)
    spec_kv = P(None, axis, None, None)
    kw = {} if mesh is None else {"mesh": mesh}
    fn = shard_map(local, in_specs=(spec_q, spec_kv, spec_kv),
                   out_specs=spec_q, check_vma=False, **kw)
    return fn(q, k, v)
