"""Decoder LM substrate for the assigned architecture pool."""

from .config import ArchConfig
from . import layers, model

__all__ = ["ArchConfig", "layers", "model"]
