"""Transformer / SSM layer primitives (pure JAX, jit/scan/pjit friendly).

Everything here is shape-polymorphic over batch/sequence and written so
that GSPMD can propagate shardings from the parameter/input specs:

* ``attention_prefill`` — blockwise causal attention with online softmax
  (two-level scan: q blocks outer, kv blocks inner) so the S x S score
  matrix is never materialized; optional sliding window.
* ``attention_decode`` — one-token attention against a KV cache.
* ``mlp`` — SwiGLU.
* ``moe`` — top-k routed experts with capacity-based scatter dispatch
  (positions via cumsum ranking; dropped tokens fall back to residual).
* ``mamba2_*`` — SSD (state-space duality, arXiv:2405.21060): chunked
  prefill and O(1) recurrent decode.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0
         ) -> jax.Array:
    """Rotary embedding.  x: (..., T, H, D); positions: (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)        # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                           axis=-1)


def _pick_block(s: int, pref: int = 512) -> int:
    if s % pref == 0:
        return pref
    b = math.gcd(s, pref)
    return b if b >= 64 else s


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

class AttnParams(NamedTuple):
    wq: jax.Array          # (d, Hq*D)
    wk: jax.Array          # (d, K*D)
    wv: jax.Array          # (d, K*D)
    wo: jax.Array          # (Hq*D, d)
    bq: jax.Array | None = None
    bk: jax.Array | None = None
    bv: jax.Array | None = None


def qkv_project(p: AttnParams, x: jax.Array, n_heads: int, n_kv: int,
                hd: int):
    B, S, _ = x.shape
    q = x @ p.wq
    k = x @ p.wk
    v = x @ p.wv
    if p.bq is not None:
        q, k, v = q + p.bq, k + p.bk, v + p.bv
    q = q.reshape(B, S, n_kv, n_heads // n_kv, hd)   # (B,S,K,G,D)
    k = k.reshape(B, S, n_kv, hd)
    v = v.reshape(B, S, n_kv, hd)
    return q, k, v


def blockwise_causal_attention(
    q: jax.Array,                  # (B, S, K, G, D) — rope already applied
    k: jax.Array,                  # (B, S, K, D)
    v: jax.Array,                  # (B, S, K, D)
    sliding_window: int = 0,
    q_block: int = 512,
    kv_block: int = 512,
) -> jax.Array:
    """Flash-style blockwise attention; returns (B, S, K, G, D)."""
    B, S, K, G, D = q.shape
    qb = _pick_block(S, q_block)
    kb = _pick_block(S, kv_block)
    nq, nk = S // qb, S // kb
    scale = 1.0 / math.sqrt(D)
    NEG = jnp.asarray(-1e30, jnp.float32)

    qs = q.reshape(B, nq, qb, K, G, D)
    ks = k.reshape(B, nk, kb, K, D)
    vs = v.reshape(B, nk, kb, K, D)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk                     # q_blk: (B, qb, K, G, D)
        q_pos = qi * qb + jnp.arange(qb)

        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, k_blk, v_blk = kj_blk
            k_pos = kj * kb + jnp.arange(kb)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            mask = q_pos[:, None] >= k_pos[None, :]
            if sliding_window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < sliding_window
            s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, qb), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, K, G, qb), jnp.float32)
        a0 = jnp.zeros((B, K, G, qb, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, jnp.moveaxis(out, 3, 1)   # (B, qb, K, G, D)

    _, outs = jax.lax.scan(q_step, None,
                           (jnp.arange(nq), jnp.moveaxis(qs, 1, 0)))
    # outs: (nq, B, qb, K, G, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, K, G, D)
    return out.astype(q.dtype)


def attention_prefill(p: AttnParams, x: jax.Array, *, n_heads: int,
                      n_kv: int, hd: int, rope_theta: float,
                      sliding_window: int = 0,
                      ring: tuple[str, int] | None = None
                      ) -> tuple[jax.Array, dict]:
    """Full-sequence causal attention.  Returns (out, kv_for_cache).

    ``ring=(axis_name, axis_size)`` switches to sequence-parallel ring
    attention over the ambient mesh axis (shard_map + ppermute)."""
    B, S, d = x.shape
    q, k, v = qkv_project(p, x, n_heads, n_kv, hd)
    pos = jnp.arange(S)[None, :]
    q = rope(q.reshape(B, S, n_heads, hd), pos, rope_theta) \
        .reshape(B, S, n_kv, n_heads // n_kv, hd)
    k = rope(k, pos, rope_theta)
    if ring is not None and S % ring[1] == 0:
        from .ring_attention import ring_attention
        o = ring_attention(q, k, v, axis=ring[0],
                           sliding_window=sliding_window,
                           axis_size=ring[1])
    else:
        o = blockwise_causal_attention(q, k, v, sliding_window)
    o = o.reshape(B, S, n_heads * hd) @ p.wo
    return o, {"k": k, "v": v}


def attention_decode(p: AttnParams, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, cache_len: jax.Array, *,
                     n_heads: int, n_kv: int, hd: int, rope_theta: float,
                     sliding_window: int = 0
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode.  x: (B, 1, d); cache_[kv]: (B, W, K, D) where W
    is the cache capacity (seq_len, or the window for SWA — a ring
    buffer; softmax is permutation-invariant over kv so ring order is
    irrelevant once keys carry their rope).  ``cache_len`` is the number
    of tokens already in the cache (== current position)."""
    B, _, d = x.shape
    W = cache_k.shape[1]
    q, k, v = qkv_project(p, x, n_heads, n_kv, hd)
    pos = cache_len[None, None] if cache_len.ndim == 0 else cache_len[:, None]
    q = rope(q.reshape(B, 1, n_heads, hd), pos, rope_theta) \
        .reshape(B, n_kv, n_heads // n_kv, hd)
    k = rope(k, pos, rope_theta)
    slot = (cache_len % W) if sliding_window else jnp.minimum(cache_len, W - 1)
    cache_k = cache_k.at[:, slot].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[:, slot].set(v[:, 0].astype(cache_v.dtype))
    valid = jnp.arange(W) <= jnp.minimum(cache_len, W - 1)
    s = jnp.einsum("bkgd,bwkd->bkgw", q, cache_k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    s = jnp.where(valid[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bkgw,bwkd->bkgd", w, cache_v)
    o = o.reshape(B, 1, n_heads * hd) @ p.wo
    return o, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

class MlpParams(NamedTuple):
    w1: jax.Array   # (d, ff) gate
    w3: jax.Array   # (d, ff) up
    w2: jax.Array   # (ff, d) down


def mlp(p: MlpParams, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p.w1) * (x @ p.w3)) @ p.w2


class MoeParams(NamedTuple):
    router: jax.Array  # (d, E)
    w1: jax.Array      # (E, d, ff)
    w3: jax.Array      # (E, d, ff)
    w2: jax.Array      # (E, ff, d)


def moe(p: MoeParams, x: jax.Array, top_k: int,
        capacity_factor: float = 1.25,
        buf_pspec=None) -> tuple[jax.Array, jax.Array]:
    """Top-k routed MoE with *per-sample* capacity-based dispatch.

    x: (B, S, d).  Returns (out, aux_loss).  Capacity is enforced per
    sample (C = cf * k * S / E): the position-ranking cumsum runs along
    the sequence axis only, so with batch sharded over the data axes the
    dispatch is entirely local — no cross-device cumsum/all-reduce of
    dispatch state (§Perf iteration 1; the original global-T dispatch
    all-reduced O(T_global x E) rank tensors every layer).  Tokens over
    capacity are dropped (residual covers them) — the standard scheme.
    """
    B, S, d = x.shape
    E = p.router.shape[-1]
    logits = (x @ p.router).astype(jnp.float32)           # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)              # (B, S, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch/Mixtral style)
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        jnp.ones((B * S * top_k,), jnp.float32)) / (B * S * top_k)
    aux = E * jnp.sum(me * ce)

    cap = max(1, int(capacity_factor * top_k * S / E))
    flat_e = idx.reshape(B, S * top_k)                    # (B, S*k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (B, S*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos_all, flat_e[..., None],
                              axis=2)[..., 0]             # (B, S*k)
    keep = (pos < cap).astype(x.dtype)
    pc = jnp.minimum(pos, cap - 1)
    tok = jnp.repeat(jnp.arange(S), top_k)                # (S*k,)

    def dispatch(xb, eb, pb, kb):
        buf = jnp.zeros((E, cap, d), x.dtype)
        return buf.at[eb, pb].add(xb[tok] * kb[:, None])

    buf = jax.vmap(dispatch)(x, flat_e, pc, keep)         # (B, E, C, d)
    if buf_pspec is not None:
        # keep the dispatch buffer batch-sharded: GSPMD otherwise
        # replicates the scatter operand (Perf iteration 1b)
        buf = jax.lax.with_sharding_constraint(buf, buf_pspec)
    h = jnp.einsum("becd,edf->becf", buf, p.w1)
    u = jnp.einsum("becd,edf->becf", buf, p.w3)
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u, p.w2)
    if buf_pspec is not None:
        y = jax.lax.with_sharding_constraint(y, buf_pspec)

    def combine(yb, eb, pb, kb, gb):
        out_flat = yb[eb, pb] * kb[:, None]               # (S*k, d)
        return jnp.zeros((S, d), x.dtype).at[tok].add(
            out_flat * gb[:, None])

    out = jax.vmap(combine)(y, flat_e, pc, keep,
                            gates.reshape(B, S * top_k).astype(x.dtype))
    return out, aux


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------

class MambaParams(NamedTuple):
    w_in: jax.Array       # (d, 2*di + 2*N)  -> [z, xbc packed]
    w_dt: jax.Array       # (d, H)
    dt_bias: jax.Array    # (H,)
    conv_w: jax.Array     # (CK, di + 2*N) depthwise causal conv
    conv_b: jax.Array     # (di + 2*N,)
    A_log: jax.Array      # (H,)
    Dskip: jax.Array      # (H,)
    norm_w: jax.Array     # (di,)
    w_out: jax.Array      # (di, d)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along S.  x: (B, S, C); w: (CK, C).

    Returns (y, new_state) where state holds the last CK-1 inputs.
    """
    CK = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], CK - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(CK)) + b
    new_state = xp[:, -(CK - 1):] if CK > 1 else state
    return y, new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, Dskip: jax.Array, chunk: int = 256,
                h0: jax.Array | None = None):
    """SSD chunked scan (arXiv:2405.21060 Alg. 1; ngroups=1).

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) < 0;
    Bm/Cm: (B, S, N).  Returns (y, h_final) with h: (B, H, P, N).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk if S % chunk == 0 else _pick_block(S, chunk)
    nc = S // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    a = dtc * A                                # (B, nc, Q, H) decay logs
    cum = jnp.cumsum(a, axis=2)                # inclusive cumsum

    # intra-chunk: S_ij = C_i.B_j * exp(cum_i - cum_j) * dt_j  (i >= j)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)            # (B,nc,Q,Q)
    M = cb[..., None] * L * dtc[:, :, None, :, :]         # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # chunk summaries: states fed into the inter-chunk recurrence
    decay_tail = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                             Bc, decay_tail * dtc, xc)    # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (B,nc,H)

    def scan_fn(h, inp):
        cs, cd = inp                                      # state, decay
        y_head = h                                        # state entering chunk
        h_new = h * cd[..., None, None] + cs
        return h_new, y_head

    h_init = h0 if h0 is not None else jnp.zeros((Bsz, H, P, N), x.dtype)
    h_fin, h_prev = jax.lax.scan(
        scan_fn, h_init,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                   # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, h_prev) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    y = y + x * Dskip[None, None, :, None]
    return y, h_fin


def mamba2_prefill(p: MambaParams, x: jax.Array, *, d_inner: int,
                   ssm_state: int, n_heads: int, head_dim: int,
                   norm_eps: float = 1e-5):
    """Full-sequence Mamba2 block.  Returns (out, cache) where cache =
    {'conv': (B, CK-1, di+2N), 'ssm': (B, H, P, N)}."""
    B, S, d = x.shape
    N = ssm_state
    zxbc = x @ p.w_in
    z, xbc = zxbc[..., :d_inner], zxbc[..., d_inner:]
    dt = jax.nn.softplus((x @ p.w_dt) + p.dt_bias)        # (B, S, H)
    xbc, conv_state = _causal_conv(xbc, p.conv_w, p.conv_b)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_inner].reshape(B, S, n_heads, head_dim)
    Bm = xbc[..., d_inner:d_inner + N]
    Cm = xbc[..., d_inner + N:]
    A = -jnp.exp(p.A_log)
    y, h = ssd_chunked(xs, dt, A, Bm, Cm, p.Dskip)
    y = y.reshape(B, S, d_inner) * jax.nn.silu(z)
    y = rms_norm(y, p.norm_w, norm_eps)
    return y @ p.w_out, {"conv": conv_state, "ssm": h}


def mamba2_decode(p: MambaParams, x: jax.Array, cache: dict, *,
                  d_inner: int, ssm_state: int, n_heads: int,
                  head_dim: int, norm_eps: float = 1e-5):
    """One-token recurrent update.  x: (B, 1, d)."""
    B, _, d = x.shape
    N = ssm_state
    zxbc = x @ p.w_in
    z, xbc = zxbc[..., :d_inner], zxbc[..., d_inner:]
    dt = jax.nn.softplus((x @ p.w_dt) + p.dt_bias)[:, 0]  # (B, H)
    xbc, conv_state = _causal_conv(xbc, p.conv_w, p.conv_b,
                                   state=cache["conv"])
    xbc = jax.nn.silu(xbc)[:, 0]                          # (B, di+2N)
    xs = xbc[:, :d_inner].reshape(B, n_heads, head_dim)
    Bm = xbc[:, d_inner:d_inner + N]
    Cm = xbc[:, d_inner + N:]
    A = -jnp.exp(p.A_log)
    h = cache["ssm"]                                      # (B, H, P, N)
    decay = jnp.exp(dt * A)                               # (B, H)
    h = h * decay[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bm, xs)
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + xs * p.Dskip[None, :, None]
    y = y.reshape(B, 1, d_inner) * jax.nn.silu(z)
    y = rms_norm(y, p.norm_w, norm_eps)
    return y @ p.w_out, {"conv": conv_state, "ssm": h}
