"""Decoder LM assembly: init, forward (train), prefill, one-token decode.

Layers are stacked on a leading L axis and applied with ``lax.scan``
(keeps HLO size O(1) in depth — essential for the 512-device dry-run),
with ``jax.checkpoint`` rematerialization for training.

Supports the assigned families:
  dense / moe        — pattern 'attn' (+ optional MoE FFN, sliding window)
  ssm                — pattern 'mamba' (Mamba2/SSD blocks)
  hybrid (zamba2)    — 'mamba' pattern + one *shared* attention+MLP block
                       applied every k layers (one weight set, per-site
                       KV caches)
  vlm / audio        — same decoders with input_mode='embeds' (frontend
                       stubs provide patch/frame embeddings)
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (AttnParams, MlpParams, MoeParams, MambaParams,
                     attention_prefill, attention_decode, mlp, moe,
                     mamba2_prefill, mamba2_decode, rms_norm)

Params = dict
Cache = dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm(key, shape, scale):
    return jax.random.normal(key, shape, jnp.float32) * scale


def init_params(cfg: ArchConfig, key: jax.Array,
                dtype=jnp.float32) -> Params:
    L, d = cfg.n_layers, cfg.d_model
    keys = iter(jax.random.split(key, 64))
    p: Params = {}
    if cfg.input_mode == "tokens":
        p["embed"] = _norm(next(keys), (cfg.vocab_padded, d), 0.02)
    p["head"] = _norm(next(keys), (d, cfg.vocab_padded), 1 / math.sqrt(d))
    p["final_norm"] = jnp.ones((d,))

    def attn_params(k, stack: int | None):
        sh = (lambda *s: (stack, *s)) if stack else (lambda *s: s)
        ks = jax.random.split(k, 4)
        nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        return AttnParams(
            wq=_norm(ks[0], sh(d, nq * hd), 1 / math.sqrt(d)),
            wk=_norm(ks[1], sh(d, nkv * hd), 1 / math.sqrt(d)),
            wv=_norm(ks[2], sh(d, nkv * hd), 1 / math.sqrt(d)),
            wo=_norm(ks[3], sh(nq * hd, d), 1 / math.sqrt(nq * hd)),
            bq=jnp.zeros(sh(nq * hd)) if cfg.qkv_bias else None,
            bk=jnp.zeros(sh(nkv * hd)) if cfg.qkv_bias else None,
            bv=jnp.zeros(sh(nkv * hd)) if cfg.qkv_bias else None,
        )

    def mlp_params(k, stack: int | None):
        sh = (lambda *s: (stack, *s)) if stack else (lambda *s: s)
        ks = jax.random.split(k, 3)
        ff = cfg.d_ff
        return MlpParams(
            w1=_norm(ks[0], sh(d, ff), 1 / math.sqrt(d)),
            w3=_norm(ks[1], sh(d, ff), 1 / math.sqrt(d)),
            w2=_norm(ks[2], sh(ff, d), 1 / math.sqrt(ff)),
        )

    layers: dict = {"ln1": jnp.ones((L, d))}
    if cfg.layer_pattern == "attn":
        layers["attn"] = attn_params(next(keys), L)
        layers["ln2"] = jnp.ones((L, d))
        if cfg.is_moe:
            ks = jax.random.split(next(keys), 4)
            E, ff = cfg.n_experts, cfg.d_ff
            layers["moe"] = MoeParams(
                router=_norm(ks[0], (L, d, E), 1 / math.sqrt(d)),
                w1=_norm(ks[1], (L, E, d, ff), 1 / math.sqrt(d)),
                w3=_norm(ks[2], (L, E, d, ff), 1 / math.sqrt(d)),
                w2=_norm(ks[3], (L, E, ff, d), 1 / math.sqrt(ff)),
            )
        else:
            layers["mlp"] = mlp_params(next(keys), L)
    elif cfg.layer_pattern == "mamba":
        ks = jax.random.split(next(keys), 8)
        di, N, H, CK = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
        conv_ch = di + 2 * N
        layers["mamba"] = MambaParams(
            w_in=_norm(ks[0], (L, d, 2 * di + 2 * N), 1 / math.sqrt(d)),
            w_dt=_norm(ks[1], (L, d, H), 1 / math.sqrt(d)),
            dt_bias=jnp.log(jnp.broadcast_to(
                jnp.expm1(jnp.linspace(1e-3, 0.1, H)), (L, H))),
            conv_w=_norm(ks[2], (L, CK, conv_ch), 1 / math.sqrt(CK)),
            conv_b=jnp.zeros((L, conv_ch)),
            A_log=jnp.log(jnp.broadcast_to(
                jnp.linspace(1.0, 16.0, H), (L, H))),
            Dskip=jnp.ones((L, H)),
            norm_w=jnp.ones((L, di)),
            w_out=_norm(ks[3], (L, di, d), 1 / math.sqrt(di)),
        )
    else:
        raise ValueError(cfg.layer_pattern)
    p["layers"] = layers

    if cfg.shared_attn_every:
        p["shared"] = {
            "ln1": jnp.ones((d,)),
            "attn": attn_params(next(keys), None),
            "ln2": jnp.ones((d,)),
            "mlp": mlp_params(next(keys), None),
        }
    return jax.tree.map(lambda a: a.astype(dtype), p)


# ---------------------------------------------------------------------------
# shared (Zamba2) helpers
# ---------------------------------------------------------------------------

def _shared_apply_flags(cfg: ArchConfig) -> jnp.ndarray:
    i = jnp.arange(cfg.n_layers)
    if not cfg.shared_attn_every:
        return jnp.zeros((cfg.n_layers,), bool), jnp.zeros((cfg.n_layers,),
                                                           jnp.int32)
    apply = ((i + 1) % cfg.shared_attn_every) == 0
    app_idx = jnp.cumsum(apply.astype(jnp.int32)) - 1
    return apply, jnp.maximum(app_idx, 0)


def n_shared_apps(cfg: ArchConfig) -> int:
    return (cfg.n_layers // cfg.shared_attn_every
            if cfg.shared_attn_every else 0)


# ---------------------------------------------------------------------------
# forward (training / scoring): full-sequence, no cache
# ---------------------------------------------------------------------------

def _constrain(x, act_pspec):
    if act_pspec is None:
        return x
    return jax.lax.with_sharding_constraint(x, act_pspec)


def forward(cfg: ArchConfig, params: Params, batch: dict,
            remat: bool = True, unroll: bool = False,
            act_pspec=None, moe_pspec=None, ring=None) -> jax.Array:
    """Returns logits (B, S, vocab_padded) with padded slots masked.

    ``act_pspec`` (a PartitionSpec for the (B, S, d) activations) lets
    the launcher request e.g. sequence sharding over the model axis —
    §Perf iteration 2: attention scores then materialize only for the
    local S/model_parallel rows instead of being replicated."""
    if cfg.input_mode == "tokens":
        x = params["embed"][batch["tokens"]]
    else:
        x = batch["embeds"]
    B, S, d = x.shape
    x = _constrain(x, act_pspec)
    shared = params.get("shared")
    apply_flags, app_idx = _shared_apply_flags(cfg)

    def block(x, lp):
        if cfg.layer_pattern == "attn":
            h, _ = attention_prefill(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
                rope_theta=cfg.rope_theta,
                sliding_window=cfg.sliding_window, ring=ring)
            x = x + h
            if cfg.is_moe:
                m, _aux = moe(lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                              cfg.moe_top_k, cfg.capacity_factor,
                              buf_pspec=moe_pspec)
            else:
                m = mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            return x + m
        # mamba
        h, _ = mamba2_prefill(
            lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps),
            d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
            n_heads=cfg.ssm_heads, head_dim=cfg.ssm_head_dim,
            norm_eps=cfg.norm_eps)
        x = x + h
        return x

    def shared_block(x):
        h, _ = attention_prefill(
            AttnParams(**{k: v for k, v in
                          zip(AttnParams._fields, shared["attn"])}),
            rms_norm(x, shared["ln1"], cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
            rope_theta=cfg.rope_theta, sliding_window=cfg.sliding_window)
        x = x + h
        m = mlp(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps))
        return x + m

    def body(x, scanned):
        lp, use_shared = scanned
        x = block(x, lp)
        if shared is not None:
            x = jax.lax.cond(use_shared, shared_block, lambda y: y, x)
        return _constrain(x, act_pspec), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["layers"], apply_flags),
                        unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"]
    if cfg.vocab_padded > cfg.vocab_size:
        pad_mask = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab_size,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_mask
    return logits


def loss_fn(cfg: ArchConfig, params: Params, batch: dict,
            unroll: bool = False, act_pspec=None,
            moe_pspec=None, ring=None) -> jax.Array:
    logits = forward(cfg, params, batch, unroll=unroll,
                     act_pspec=act_pspec, moe_pspec=moe_pspec, ring=ring)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               dtype=jnp.float32) -> Cache:
    L, d = cfg.n_layers, cfg.d_model
    W = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
    cache: Cache = {"len": jnp.zeros((), jnp.int32)}
    if cfg.layer_pattern == "attn":
        cache["k"] = jnp.zeros((L, batch, W, cfg.n_kv_heads, cfg.hd), dtype)
        cache["v"] = jnp.zeros((L, batch, W, cfg.n_kv_heads, cfg.hd), dtype)
    else:
        CK, di, N = cfg.ssm_conv, cfg.d_inner, cfg.ssm_state
        cache["conv"] = jnp.zeros((L, batch, CK - 1, di + 2 * N), dtype)
        cache["ssm"] = jnp.zeros((L, batch, cfg.ssm_heads,
                                  cfg.ssm_head_dim, N), dtype)
    if cfg.shared_attn_every:
        A = n_shared_apps(cfg)
        Ws = min(seq_len, cfg.sliding_window) if cfg.sliding_window \
            else seq_len
        cache["shared_k"] = jnp.zeros(
            (A, batch, Ws, cfg.n_kv_heads, cfg.hd), dtype)
        cache["shared_v"] = jnp.zeros(
            (A, batch, Ws, cfg.n_kv_heads, cfg.hd), dtype)
    return cache


# ---------------------------------------------------------------------------
# decode: one new token against the cache
# ---------------------------------------------------------------------------

def decode_step(cfg: ArchConfig, params: Params, cache: Cache,
                inputs: dict, unroll: bool = False
                ) -> tuple[jax.Array, Cache]:
    """inputs: {'token': (B,) int32} or {'embed': (B, d)}.

    Returns (logits (B, vocab_padded), new cache).
    """
    if cfg.input_mode == "tokens":
        x = params["embed"][inputs["token"]][:, None, :]   # (B, 1, d)
    else:
        x = inputs["embed"][:, None, :]
    B = x.shape[0]
    shared = params.get("shared")
    apply_flags, app_idx = _shared_apply_flags(cfg)
    cache_len = cache["len"]

    def shared_block(x, sk, sv):
        h, nk, nv = attention_decode(
            AttnParams(**{k: v for k, v in
                          zip(AttnParams._fields, shared["attn"])}),
            rms_norm(x, shared["ln1"], cfg.norm_eps), sk, sv, cache_len,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
            rope_theta=cfg.rope_theta, sliding_window=cfg.sliding_window)
        x = x + h
        m = mlp(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps))
        return x + m, nk, nv

    def body(carry, scanned):
        x, shared_k, shared_v = carry
        if cfg.layer_pattern == "attn":
            lp, ck, cv, use_shared, ai = scanned
            h, nk, nv = attention_decode(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), ck, cv,
                cache_len, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                hd=cfg.hd, rope_theta=cfg.rope_theta,
                sliding_window=cfg.sliding_window)
            x = x + h
            if cfg.is_moe:
                m, _ = moe(lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                           cfg.moe_top_k, cfg.capacity_factor)
            else:
                m = mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            x = x + m
            new_layer_cache = (nk, nv)
        else:
            lp, cconv, cssm, use_shared, ai = scanned
            h, nc = mamba2_decode(
                lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                {"conv": cconv, "ssm": cssm},
                d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                n_heads=cfg.ssm_heads, head_dim=cfg.ssm_head_dim,
                norm_eps=cfg.norm_eps)
            x = x + h
            new_layer_cache = (nc["conv"], nc["ssm"])
        if shared is not None:
            sk = jax.lax.dynamic_index_in_dim(shared_k, ai, 0,
                                              keepdims=False)
            sv = jax.lax.dynamic_index_in_dim(shared_v, ai, 0,
                                              keepdims=False)
            x2, nk2, nv2 = shared_block(x, sk, sv)
            x = jnp.where(use_shared, x2, x)
            nk2 = jnp.where(use_shared, nk2, sk)
            nv2 = jnp.where(use_shared, nv2, sv)
            shared_k = jax.lax.dynamic_update_index_in_dim(
                shared_k, nk2, ai, 0)
            shared_v = jax.lax.dynamic_update_index_in_dim(
                shared_v, nv2, ai, 0)
        return (x, shared_k, shared_v), new_layer_cache

    zk = cache.get("shared_k", jnp.zeros((), x.dtype))
    zv = cache.get("shared_v", jnp.zeros((), x.dtype))
    if cfg.layer_pattern == "attn":
        xs = (params["layers"], cache["k"], cache["v"], apply_flags, app_idx)
    else:
        xs = (params["layers"], cache["conv"], cache["ssm"], apply_flags,
              app_idx)
    (x, zk, zv), new_caches = jax.lax.scan(
        body, (x, zk, zv), xs, unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["head"])[:, 0]
    if cfg.vocab_padded > cfg.vocab_size:
        pad_mask = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab_size,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_mask

    new_cache: Cache = {"len": cache_len + 1}
    if cfg.layer_pattern == "attn":
        new_cache["k"], new_cache["v"] = new_caches
    else:
        new_cache["conv"], new_cache["ssm"] = new_caches
    if cfg.shared_attn_every:
        new_cache["shared_k"], new_cache["shared_v"] = zk, zv
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill: process a full prompt, returning last logits + a filled cache
# ---------------------------------------------------------------------------

def prefill(cfg: ArchConfig, params: Params, batch: dict,
            unroll: bool = False, act_pspec=None, moe_pspec=None,
            ring=None) -> tuple[jax.Array, Cache]:
    """batch: {'tokens': (B, S)} or {'embeds': (B, S, d)}."""
    if cfg.input_mode == "tokens":
        x = params["embed"][batch["tokens"]]
    else:
        x = batch["embeds"]
    B, S, d = x.shape
    x = _constrain(x, act_pspec)
    shared = params.get("shared")
    apply_flags, app_idx = _shared_apply_flags(cfg)
    W = min(S, cfg.sliding_window) if cfg.sliding_window else S

    def keep_window(k):  # (B, S, K, D) -> last W entries, ring-aligned
        if W >= S:
            return k
        # decode writes token t at slot t % W: place token S-W+i at
        # slot (S-W+i) % W == (i + S) % W  ->  roll by S % W
        return jnp.roll(k[:, -W:], shift=S % W, axis=1)

    def shared_block(x):
        h, kv = attention_prefill(
            AttnParams(**{k: v for k, v in
                          zip(AttnParams._fields, shared["attn"])}),
            rms_norm(x, shared["ln1"], cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
            rope_theta=cfg.rope_theta, sliding_window=cfg.sliding_window)
        x = x + h
        m = mlp(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps))
        return x + m, kv

    def body(carry, scanned):
        x, shared_k, shared_v = carry
        lp, use_shared, ai = scanned
        if cfg.layer_pattern == "attn":
            h, kv = attention_prefill(
                lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, hd=cfg.hd,
                rope_theta=cfg.rope_theta,
                sliding_window=cfg.sliding_window, ring=ring)
            x = x + h
            if cfg.is_moe:
                m, _ = moe(lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps),
                           cfg.moe_top_k, cfg.capacity_factor,
                           buf_pspec=moe_pspec)
            else:
                m = mlp(lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            x = x + m
            x = _constrain(x, act_pspec)
            layer_cache = (keep_window(kv["k"]), keep_window(kv["v"]))
        else:
            h, nc = mamba2_prefill(
                lp["mamba"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                d_inner=cfg.d_inner, ssm_state=cfg.ssm_state,
                n_heads=cfg.ssm_heads, head_dim=cfg.ssm_head_dim,
                norm_eps=cfg.norm_eps)
            x = x + h
            layer_cache = (nc["conv"], nc["ssm"])
        if shared is not None:
            x2, kv2 = shared_block(x)
            x = jnp.where(use_shared, x2, x)
            nk2 = keep_window(kv2["k"])
            nv2 = keep_window(kv2["v"])
            upd = use_shared.astype(shared_k.dtype)
            shared_k = jax.lax.dynamic_update_index_in_dim(
                shared_k,
                upd * nk2 + (1 - upd) * jax.lax.dynamic_index_in_dim(
                    shared_k, ai, 0, keepdims=False),
                ai, 0)
            shared_v = jax.lax.dynamic_update_index_in_dim(
                shared_v,
                upd * nv2 + (1 - upd) * jax.lax.dynamic_index_in_dim(
                    shared_v, ai, 0, keepdims=False),
                ai, 0)
        return (x, shared_k, shared_v), layer_cache

    A = n_shared_apps(cfg)
    zk = jnp.zeros((A, B, W, cfg.n_kv_heads, cfg.hd), x.dtype) if A else \
        jnp.zeros((), x.dtype)
    zv = jnp.zeros_like(zk)
    (x, zk, zv), layer_caches = jax.lax.scan(
        body, (x, zk, zv), (params["layers"], apply_flags, app_idx),
        unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1] @ params["head"])
    if cfg.vocab_padded > cfg.vocab_size:
        pad_mask = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab_size,
                             0.0, -1e30).astype(logits.dtype)
        logits = logits + pad_mask

    cache: Cache = {"len": jnp.asarray(S, jnp.int32)}
    if cfg.layer_pattern == "attn":
        cache["k"], cache["v"] = layer_caches
    else:
        cache["conv"], cache["ssm"] = layer_caches
    if cfg.shared_attn_every:
        cache["shared_k"], cache["shared_v"] = zk, zv
    return logits, cache
