"""Model substrates: CNN zoo (paper) and transformer decoders (assigned archs)."""
