"""Export decoder architectures as PICO graphs (DESIGN.md §4).

Each transformer/SSM block becomes a vertex chain over the *sequence*
dimension (W = seq_len, H = 1):

* full attention   -> 'attn' vertex, global receptive field (the halo is
  the whole sequence — the Fig. 6 analogue: tiling inside a fused piece
  that crosses it degenerates to full recomputation, which C(M) prices),
* sliding window   -> 'swa' vertex, kernel = window (finite halo),
* mamba2 conv1d    -> 'conv1d' vertex, kernel = ssm_conv (halo 3),
* SSD scan         -> 'ssd' vertex, kernel 1 (state passes at chunk
  boundaries; inter-chunk recurrence is sequential but cheap),
* mlp / moe        -> pointwise vertices with exact FLOPs coefficients,
* Zamba2's shared block -> extra attn+mlp vertices every k layers.

This lets Algorithm 1 cut pieces for the assigned archs exactly as for
CNNs, and Algorithms 2+3 build pipelines over TPU 'device' groups.
"""

from __future__ import annotations

from ..core.graph import Graph, LayerSpec
from .transformer.config import ArchConfig


def _attn_vertex(name: str, cfg: ArchConfig, seq_len: int) -> LayerSpec:
    d, hd, nq, nkv = cfg.d_model, cfg.hd, cfg.n_heads, cfg.n_kv_heads
    proj = 2 * d * (nq * hd) + 2 * 2 * d * (nkv * hd)  # q,o + k,v MACs*2
    if cfg.sliding_window:
        ctx = min(cfg.sliding_window, seq_len)
        kind, kernel = "swa", (cfg.sliding_window, 1)
        glob = False
    else:
        ctx = seq_len / 2  # causal average context
        kind, kernel = "attn", (1, 1)
        glob = True
    score = 2 * 2 * nq * hd * ctx      # QK^T + PV per output token
    return LayerSpec(
        name, kind, kernel=kernel, stride=(1, 1), padding=(0, 0),
        in_channels=d, out_channels=d,
        flops_coeff=proj + score,
        param_bytes=2 * (2 * d * nq * hd + 2 * d * nkv * hd),
        global_rf=glob, tile_independent_flops=True)


def _mlp_vertex(name: str, cfg: ArchConfig) -> LayerSpec:
    d, ff = cfg.d_model, cfg.d_ff
    return LayerSpec(name, "ffn", in_channels=d, out_channels=d,
                     flops_coeff=2 * 3 * d * ff,
                     param_bytes=2 * 3 * d * ff)


def _moe_vertex(name: str, cfg: ArchConfig) -> LayerSpec:
    d, ff = cfg.d_model, cfg.d_ff
    active = 2 * 3 * d * ff * cfg.moe_top_k * cfg.capacity_factor
    return LayerSpec(name, "moe", in_channels=d, out_channels=d,
                     flops_coeff=active + 2 * d * cfg.n_experts,
                     param_bytes=2 * (3 * d * ff * cfg.n_experts
                                      + d * cfg.n_experts))


def _mamba_vertices(i: int, cfg: ArchConfig) -> list[LayerSpec]:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * N
    # projections (pointwise) -> causal conv1d (halo CK-1) -> SSD scan
    return [
        LayerSpec(f"l{i}.in_proj", "ffn", in_channels=d,
                  out_channels=conv_ch,
                  flops_coeff=2 * d * (2 * di + 2 * N + H),
                  param_bytes=2 * d * (2 * di + 2 * N + H)),
        LayerSpec(f"l{i}.conv1d", "conv1d", kernel=(cfg.ssm_conv, 1),
                  stride=(1, 1), padding=(cfg.ssm_conv - 1, 0),
                  in_channels=conv_ch, out_channels=conv_ch,
                  flops_coeff=2 * cfg.ssm_conv * conv_ch,
                  param_bytes=2 * cfg.ssm_conv * conv_ch),
        LayerSpec(f"l{i}.ssd", "ssd", in_channels=conv_ch,
                  out_channels=di,
                  flops_coeff=2 * H * cfg.ssm_head_dim * N * 4,
                  param_bytes=2 * 3 * H),
        LayerSpec(f"l{i}.out_proj", "ffn", in_channels=di,
                  out_channels=d, flops_coeff=2 * di * d,
                  param_bytes=2 * di * d),
    ]


def export_graph(cfg: ArchConfig, seq_len: int) -> Graph:
    """Decoder -> PICO Graph over the sequence dimension."""
    g = Graph()
    g.add(LayerSpec("embed", "embed", in_channels=1,
                    out_channels=cfg.d_model,
                    flops_coeff=0.0,
                    param_bytes=2 * cfg.vocab_padded * cfg.d_model,
                    global_rf=False))
    prev = "embed"
    for i in range(cfg.n_layers):
        if cfg.layer_pattern == "attn":
            a = g.add(_attn_vertex(f"l{i}.attn", cfg, seq_len), [prev])
            if cfg.is_moe:
                prev = g.add(_moe_vertex(f"l{i}.moe", cfg), [a])
            else:
                prev = g.add(_mlp_vertex(f"l{i}.mlp", cfg), [a])
        else:
            vs = _mamba_vertices(i, cfg)
            for v in vs:
                prev = g.add(v, [prev])
        if cfg.shared_attn_every and (i + 1) % cfg.shared_attn_every == 0:
            sa = g.add(_attn_vertex(f"l{i}.shared_attn", cfg, seq_len),
                       [prev])
            prev = g.add(_mlp_vertex(f"l{i}.shared_mlp", cfg), [sa])
    # the LM head is pointwise per token (unlike a CNN fc over a map)
    g.add(LayerSpec("head", "ffn", in_channels=cfg.d_model,
                    out_channels=cfg.vocab_padded,
                    flops_coeff=2 * cfg.d_model * cfg.vocab_padded,
                    param_bytes=2 * cfg.d_model * cfg.vocab_padded),
          [prev])
    return g
