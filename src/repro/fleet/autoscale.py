"""Watermark autoscaling over smoothed cell load.

The policy is deliberately simple and deterministic: a cell whose
load-EWMA exceeds :attr:`~repro.api.specs.FleetSpec.scale_up_load`
wants capacity, one below
:attr:`~repro.api.specs.FleetSpec.scale_down_load` is a drain
candidate.  The *mechanism* is delegated to hooks so the same policy
drives simulation and a real control plane:

* ``provision(router, decision) -> (name, Cluster) | None`` — supply a
  new cell (e.g. spin up hardware, or clone the overloaded cell's
  shape); returning ``None`` declines;
* ``decommission(router, decision) -> bool`` — approve draining the
  named cell (its tenants re-route through the registry, so the move
  costs admissions, not plans).

``evaluate()`` returns every decision (including holds) for the audit
trail and applies the approved ones, bounded by ``min_clusters`` /
``max_clusters``.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .router import FleetRouter


@dataclass(frozen=True)
class ScaleDecision:
    cell: str
    action: str          # "scale_up" | "scale_down" | "hold"
    load: float
    applied: bool = False
    detail: str = ""


class Autoscaler:
    def __init__(self, router: FleetRouter, provision=None,
                 decommission=None, metrics=None):
        self.router = router
        self.provision = provision
        self.decommission = decommission
        self._metrics = (metrics if metrics is not None
                         else obs_metrics.default_registry())

    def evaluate(self) -> list[ScaleDecision]:
        spec = self.router.spec
        decisions: list[ScaleDecision] = []
        with obs_trace.current().wall_span(
                "fleet.autoscale", n_cells=len(self.router.cells)):
            for name in sorted(self.router.cells):
                load = self.router.cell_load(name)
                if load > spec.scale_up_load:
                    decisions.append(self._scale_up(name, load))
                elif load < spec.scale_down_load:
                    decisions.append(self._scale_down(name, load))
                else:
                    decisions.append(ScaleDecision(name, "hold", load))
        for d in decisions:
            if d.action != "hold":
                self._metrics.counter("fleet.autoscale.decisions",
                                      action=d.action,
                                      applied=str(d.applied).lower()).inc()
        return decisions

    def _scale_up(self, name: str, load: float) -> ScaleDecision:
        spec = self.router.spec
        if (spec.max_clusters is not None
                and len(self.router.cells) >= spec.max_clusters):
            return ScaleDecision(name, "scale_up", load,
                                 detail="at max_clusters")
        if self.provision is None:
            return ScaleDecision(name, "scale_up", load,
                                 detail="no provision hook")
        d = ScaleDecision(name, "scale_up", load)
        supplied = self.provision(self.router, d)
        if supplied is None:
            return ScaleDecision(name, "scale_up", load,
                                 detail="provision declined")
        new_name, cluster = supplied
        self.router.add_cell(new_name, cluster)
        return ScaleDecision(name, "scale_up", load, applied=True,
                             detail=f"added cell {new_name}")

    def _scale_down(self, name: str, load: float) -> ScaleDecision:
        spec = self.router.spec
        if len(self.router.cells) <= spec.min_clusters:
            return ScaleDecision(name, "scale_down", load,
                                 detail="at min_clusters")
        if self.decommission is None:
            return ScaleDecision(name, "scale_down", load,
                                 detail="no decommission hook")
        d = ScaleDecision(name, "scale_down", load)
        if not self.decommission(self.router, d):
            return ScaleDecision(name, "scale_down", load,
                                 detail="decommission declined")
        moved = self.router.remove_cell(name)
        return ScaleDecision(name, "scale_down", load, applied=True,
                             detail=f"drained {len(moved)} tenants")
