"""Fleet admission/routing: tenants -> cells, driven by load-EWMA.

A *cell* is one cluster that a :class:`~repro.serving.scheduler.
ServingScheduler` (or the discrete-event runtime) would operate; the
router owns many and decides where each tenant lands.  Load per cell is
an EWMA of observed utilization samples — the same smoothing convention
the serving scheduler applies per tenant — normalized by cell capacity
so heterogeneous cells compare fairly.  Plans come from the shared
:class:`~repro.fleet.registry.PlanRegistry`: admitting the same model
onto an identical cell anywhere in the fleet is a registry hit, and
device churn re-plans through the per-model incremental planner cache.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..api.specs import FleetSpec, PlanSpec
from ..core.cost import Cluster, CostTable
from ..core.planner import PicoPlan
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .registry import PlanRegistry


@dataclass
class Tenant:
    """One admitted workload: a graph carrier + its planner knobs."""

    name: str
    model: object                      # .graph / .input_size carrier
    weight: float = 1.0                # relative demand (frames/s share)
    spec: PlanSpec | None = None


@dataclass
class Cell:
    """One cluster plus its routing state."""

    name: str
    cluster: Cluster
    tenants: list[Tenant] = field(default_factory=list)
    load_ewma: float | None = None     # smoothed utilization in [0, ~1]

    @property
    def capacity(self) -> float:
        return self.cluster.total_capacity

    @property
    def load(self) -> float:
        return self.load_ewma if self.load_ewma is not None else 0.0


@dataclass
class Admission:
    """Outcome of routing one tenant."""

    tenant: str
    cell: str
    plan: PicoPlan

    @property
    def plan_source(self) -> str:
        return self.plan.source


class FleetRouter:
    """Routes tenant admissions across cells and keeps their plans.

    ``observe(cell, utilization)`` feeds the load-EWMA (wire it to the
    serving tier's utilization signal); ``admit`` places a tenant by
    the :class:`~repro.api.specs.FleetSpec` policy and returns the
    plan with honest provenance (``registry`` on a registry hit,
    ``incremental``/``scratch`` otherwise).  ``churn`` swaps a cell's
    cluster (device join/leave) and re-plans its tenants through the
    registry — the incremental planner path.
    """

    def __init__(self, clusters: dict[str, Cluster],
                 spec: FleetSpec | None = None,
                 registry: PlanRegistry | None = None,
                 cost_table: CostTable | None = None,
                 metrics=None):
        if not clusters:
            raise ValueError("FleetRouter needs at least one cluster")
        self.spec = spec or FleetSpec()
        self._metrics = (metrics if metrics is not None
                         else obs_metrics.default_registry())
        self.registry = (registry if registry is not None
                         else PlanRegistry(self.spec.registry_capacity,
                                           metrics=self._metrics))
        self.cost_table = cost_table
        self.cells: dict[str, Cell] = {name: Cell(name, c)
                                       for name, c in clusters.items()}
        self.plans: dict[str, PicoPlan] = {}      # tenant name -> plan
        # round-robin cursor: the last cell *name* served.  Keying on
        # the name (not an integer index into sorted(cells)) keeps the
        # rotation stable across add_cell/remove_cell — an index would
        # silently land on a different cell once the sorted sequence
        # shifts, skewing or repeating placements.
        self._rr_last: str | None = None

    # -- load signal ----------------------------------------------------
    def observe(self, cell: str, utilization: float) -> float:
        """Feed one utilization sample into a cell's load-EWMA."""
        c = self.cells[cell]
        beta = self.spec.ewma_beta
        c.load_ewma = (utilization if c.load_ewma is None
                       else beta * utilization + (1.0 - beta) * c.load_ewma)
        self._metrics.gauge("fleet.cell.load", cell=cell).set(c.load_ewma)
        return c.load_ewma

    def observe_report(self, cell: str, report) -> float:
        """Feed *real execution telemetry* into a cell's load-EWMA.

        Accepts either online tier's end-of-run report and derives the
        utilization sample the router's smoothing expects:

        * a :class:`~repro.dist.launcher.DistReport` (or anything with
          a ``utilization()`` method) — worker compute seconds over
          worker wall capacity;
        * a :class:`~repro.serving.scheduler.ServeReport` — summed
          ``device_busy_s`` over ``len(devices) * makespan``.

        This closes the plan/route/execute loop: the same artifact that
        validates an execution also steers where the next tenant lands.
        """
        util = getattr(report, "utilization", None)
        if callable(util):
            sample = float(util())
        elif (hasattr(report, "device_busy_s")
              and hasattr(report, "makespan")):
            busy = report.device_busy_s
            span = report.makespan
            sample = (sum(busy.values()) / (len(busy) * span)
                      if busy and span > 0 else 0.0)
        else:
            raise TypeError(
                f"observe_report wants a DistReport/ServeReport-like "
                f"object, got {type(report).__name__}")
        return self.observe(cell, min(1.0, max(0.0, sample)))

    def _demand_load(self, cell: Cell) -> float:
        """Static fallback load when no utilization was observed yet:
        admitted tenant weight per unit capacity, fleet-normalized.
        A degraded/empty cell (zero capacity) is infinitely loaded —
        never a routing target, never a ZeroDivisionError."""
        if cell.capacity <= 0:
            return float("inf")
        total_cap = sum(c.capacity for c in self.cells.values())
        scale = total_cap / len(self.cells)
        return sum(t.weight for t in cell.tenants) / (cell.capacity / scale)

    def cell_load(self, cell: str) -> float:
        c = self.cells[cell]
        return c.load_ewma if c.load_ewma is not None else self._demand_load(c)

    # -- routing --------------------------------------------------------
    def _pick(self, tenant: Tenant) -> Cell:
        # zero-capacity cells (degraded/empty clusters) are not routable:
        # they cannot host a plan, and pricing one divides by capacity
        names = [n for n in sorted(self.cells)
                 if self.cells[n].capacity > 0]
        if not names:
            raise ValueError(
                f"no routable cell for tenant {tenant.name!r}: all "
                f"{len(self.cells)} cell(s) have zero capacity")
        if self.spec.routing == "round_robin":
            # resume after the last *name* served (wrapping), so the
            # rotation survives topology changes
            if self._rr_last is None:
                name = names[0]
            else:
                name = next((n for n in names if n > self._rr_last),
                            names[0])
            self._rr_last = name
            return self.cells[name]
        # least_loaded: smoothed load, capacity-normalized; name breaks ties
        return self.cells[min(names, key=lambda n: (self.cell_load(n), n))]

    def admit(self, tenant: Tenant) -> Admission:
        """Place a tenant on a cell and plan it (registry-first)."""
        cell = self._pick(tenant)
        with obs_trace.current().wall_span(
                "fleet.route", tenant=tenant.name, cell=cell.name,
                policy=self.spec.routing):
            plan = self.registry.get_or_plan(
                tenant.model, cell.cluster, tenant.spec,
                cost_table=self.cost_table)
            cell.tenants.append(tenant)
            self.plans[tenant.name] = plan
            self._metrics.counter("fleet.admissions",
                                  source=plan.source).inc()
        return Admission(tenant.name, cell.name, plan)

    def evict(self, tenant_name: str) -> Tenant | None:
        """Remove a tenant from whichever cell holds it."""
        for cell in self.cells.values():
            for t in cell.tenants:
                if t.name == tenant_name:
                    cell.tenants.remove(t)
                    self.plans.pop(tenant_name, None)
                    return t
        return None

    # -- churn / topology -----------------------------------------------
    def churn(self, cell_name: str, cluster: Cluster) -> dict[str, PicoPlan]:
        """Replace a cell's cluster (device join/leave/degrade) and
        re-plan its tenants.  Known cluster signatures are registry
        hits; new ones re-plan incrementally off the per-model
        :class:`~repro.core.pipeline_dp.PlannerCache`."""
        cell = self.cells[cell_name]
        cell.cluster = cluster
        replanned = {}
        # same observability contract as admit: one fleet.route span per
        # re-planned tenant (policy="churn") and a plan-source counter,
        # so repartition audits see churn-driven plans too
        with obs_trace.current().wall_span(
                "fleet.churn", cell=cell_name, tenants=len(cell.tenants)):
            for t in cell.tenants:
                with obs_trace.current().wall_span(
                        "fleet.route", tenant=t.name, cell=cell_name,
                        policy="churn"):
                    plan = self.registry.get_or_plan(
                        t.model, cluster, t.spec,
                        cost_table=self.cost_table)
                    self.plans[t.name] = plan
                    replanned[t.name] = plan
                    self._metrics.counter("fleet.replans",
                                          source=plan.source).inc()
        return replanned

    def add_cell(self, name: str, cluster: Cluster) -> Cell:
        if name in self.cells:
            raise ValueError(f"cell {name!r} already exists")
        if (self.spec.max_clusters is not None
                and len(self.cells) >= self.spec.max_clusters):
            raise ValueError(f"fleet is at max_clusters="
                             f"{self.spec.max_clusters}")
        cell = Cell(name, cluster)
        self.cells[name] = cell
        return cell

    def remove_cell(self, name: str) -> list[Admission]:
        """Drain a cell: its tenants are re-admitted elsewhere."""
        if len(self.cells) <= self.spec.min_clusters:
            raise ValueError(f"fleet is at min_clusters="
                             f"{self.spec.min_clusters}")
        cell = self.cells.pop(name)
        moved = []
        for t in cell.tenants:
            self.plans.pop(t.name, None)
            moved.append(self.admit(t))
        return moved
