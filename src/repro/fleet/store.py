"""File-backed shared plan store: a directory of versioned artifacts.

A :class:`PlanStore` persists :class:`~repro.fleet.registry.
PlanRegistry` entries as individual ``plan_registry_entry`` JSON
artifacts, one file per content key, named by the key's sha256.  Wired
into a registry (``PlanRegistry(store=...)``), it makes the cache
*shared*: a plan computed by one process (or one run) is a registry
hit for every other registry pointing at the same directory — the
fleet-wide "identical clusters never re-plan" promise survives process
boundaries with no coordination service.

Writes are crash-safe by construction: each entry is serialized to a
unique temp file in the same directory and published with
``os.replace`` (atomic on POSIX), so concurrent readers only ever see
absent-or-complete artifacts, and two writers racing on one key both
leave a valid file.  Reads tolerate and skip corrupt/foreign files —
a shared directory must never poison every consumer.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path

ENTRY_KIND = "plan_registry_entry"


def _key_digest(key: tuple) -> str:
    return hashlib.sha256(
        json.dumps(list(key), sort_keys=True).encode()).hexdigest()[:32]


class PlanStore:
    """Directory of ``plan_registry_entry`` artifacts keyed by the
    registry's content key (see module docstring)."""

    def __init__(self, root: str | os.PathLike):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, key: tuple) -> Path:
        return self.root / f"{_key_digest(key)}.json"

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*.json"))

    def __contains__(self, key: tuple) -> bool:
        return self._path(key).exists()

    def get(self, key: tuple) -> dict | None:
        """The stored entry payload for ``key``, or None.  Corrupt or
        foreign files read as misses, never as errors."""
        from ..api import artifacts
        path = self._path(key)
        try:
            text = path.read_text()
        except OSError:
            return None
        try:
            doc = artifacts.loads_payload(ENTRY_KIND, text)
        except (ValueError, KeyError, json.JSONDecodeError):
            return None
        if doc.get("key") != list(key):
            return None                 # digest collision / stale rename
        return doc["entry"]

    def put(self, key: tuple, entry: dict) -> None:
        """Atomically publish ``entry`` under ``key`` (tempfile in the
        same directory + ``os.replace``; readers never see partials)."""
        from ..api import artifacts
        text = artifacts.dumps_payload(
            ENTRY_KIND, {"key": list(key), "entry": entry})
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(text)
            os.replace(tmp, self._path(key))
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    def delete(self, key: tuple) -> bool:
        try:
            os.unlink(self._path(key))
            return True
        except OSError:
            return False

    def keys(self) -> list[tuple]:
        """All content keys currently published (scans the directory;
        unreadable files are skipped)."""
        from ..api import artifacts
        out = []
        for p in sorted(self.root.glob("*.json")):
            try:
                doc = artifacts.loads_payload(ENTRY_KIND, p.read_text())
                out.append(tuple(doc["key"]))
            except (OSError, ValueError, KeyError, json.JSONDecodeError):
                continue
        return out
