"""``repro.fleet`` — the tier above one :class:`ServingScheduler`.

Production scale is thousands of clusters serving the same handful of
models: planning must not be redone per cluster.  This package supplies

* :class:`~repro.fleet.registry.PlanRegistry` — an LRU cache of
  finished :class:`~repro.core.planner.PicoPlan` artifacts keyed by
  ``(model fingerprint, cluster signature, PlanSpec, CostTable)``, so
  an identical cluster anywhere in the fleet gets its plan without
  running the optimizer (DynO's serialized plan hand-off, fleet-wide),
  optionally backed by a :class:`~repro.fleet.store.PlanStore` — a
  shared directory of versioned artifacts (atomic-rename writes) that
  makes hits survive process boundaries;
* :class:`~repro.fleet.router.FleetRouter` — admission/routing of
  tenants across cells driven by the same load-EWMA convention the
  serving scheduler uses, with device-churn handling that re-plans
  through per-model :class:`~repro.core.pipeline_dp.PlannerCache`
  instances (the incremental planner hot path);
* :class:`~repro.fleet.autoscale.Autoscaler` — watermark policy over
  smoothed cell load with provision/decommission hooks.

Everything is configured by one frozen
:class:`~repro.api.specs.FleetSpec` and observable through
``repro.obs`` (``fleet.*`` metrics, ``registry.lookup`` /
``fleet.route`` / ``fleet.autoscale`` spans).
"""

from .registry import PlanRegistry, cluster_signature, fingerprint_model
from .router import Admission, Cell, FleetRouter, Tenant
from .autoscale import Autoscaler, ScaleDecision
from .store import PlanStore

__all__ = [
    "Admission", "Autoscaler", "Cell", "FleetRouter", "PlanRegistry",
    "PlanStore", "ScaleDecision", "Tenant", "cluster_signature",
    "fingerprint_model",
]
