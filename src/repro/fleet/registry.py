"""Fleet plan registry: identical clusters never re-plan.

The registry caches finished :class:`~repro.core.planner.PicoPlan`
artifacts (the same versioned payloads ``repro.api`` ships to disk)
under a content key::

    (model fingerprint, cluster signature, PlanSpec, CostTable)

* **model fingerprint** — sha256 over the serialized layer graph +
  input size, so two tenants loading "vgg16" from different processes
  collide onto one entry;
* **cluster signature** — when the link is flat (no per-pair bandwidth
  overrides) the signature is *name-insensitive*: the sorted multiset
  of device parameters + bandwidth.  Identical hardware with different
  device names is the same planning problem, and on a hit the cached
  plan's devices are rebound positionally onto the requesting
  cluster's.  With pair overrides, names are load-bearing and the
  signature is exact;
* **spec / cost table** — the planner knobs and measured calibration
  ratios that shaped the plan.

Misses plan through :func:`~repro.core.planner.plan_with_spec` with a
per-model :class:`~repro.core.pipeline_dp.PlannerCache`, so even a miss
is incremental when the same model was planned before on a different
cluster.  Hits and misses are counted locally and published to
``repro.obs`` (``fleet.registry.hit`` / ``fleet.registry.miss``).
"""

from __future__ import annotations

import hashlib
import json
from collections import OrderedDict

from ..api import artifacts
from ..api.specs import PlanSpec
from ..core.cost import Cluster, CostTable
from ..core.pipeline_dp import PlannerCache
from ..core.planner import PicoPlan, plan_with_spec
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace


def _sha(payload) -> str:
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()[:16]


def fingerprint_model(model) -> str:
    """Content hash of a graph carrier (``.graph`` + ``.input_size``)."""
    return _sha({"graph": artifacts.graph_to_dict(model.graph),
                 "input_size": list(model.input_size)})


def _device_key(d) -> list:
    return [d.capacity, d.alpha, d.active_power, d.idle_power]


def cluster_signature(cluster: Cluster) -> str:
    """Content hash of the planning-relevant cluster state.

    Name-insensitive (sorted device-parameter multiset) when the link
    is flat; exact (ordered, named) when per-pair bandwidth overrides
    make names load-bearing.
    """
    if cluster.pair_bandwidth:
        return _sha({"exact": artifacts.cluster_to_dict(cluster)})
    return _sha({"devices": sorted(_device_key(d) for d in cluster.devices),
                 "bandwidth": cluster.bandwidth})


def _spec_key(spec: PlanSpec) -> str:
    return spec.to_json()


def _cost_table_key(ct: CostTable | None) -> str:
    if ct is None:
        return ""
    return _sha(json.loads(artifacts.dumps_payload(
        "cost_table", artifacts.cost_table_to_dict(ct))))


def _rebind(plan: PicoPlan, cluster: Cluster) -> PicoPlan:
    """Re-point a cached plan's stage devices at ``cluster``'s devices.

    Valid only under a name-insensitive signature match: both sides
    hold the same multiset of device parameters, so sorting each by
    (capacity desc, params, name) pairs equivalent devices.
    """
    old = sorted({d.name: d for st in plan.pipeline.stages
                  for d in st.devices}.values(),
                 key=lambda d: (-d.capacity, d.alpha, d.name))
    new = sorted(cluster.devices, key=lambda d: (-d.capacity, d.alpha, d.name))
    mapping = {o.name: n for o, n in zip(old, new)}
    for st in plan.pipeline.stages:
        st.devices = [mapping[d.name] for d in st.devices]
    return plan


class PlanRegistry:
    """LRU cache of finished plans, shared fleet-wide.

    Entries store the *serialized* plan payload (exactly what
    ``repro.api`` writes to disk), so a hit decodes a fresh, isolated
    :class:`PicoPlan` — mutating a served plan never corrupts the
    registry — and :meth:`to_payload`/:meth:`from_payload` round-trip
    the whole registry as one versioned artifact
    (``artifacts.to_json("plan_registry", reg)``).
    """

    def __init__(self, capacity: int = 256, metrics=None, store=None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._entries: OrderedDict[tuple, dict] = OrderedDict()
        self._caches: dict[str, PlannerCache] = {}
        self.hits = 0
        self.misses = 0
        self._metrics = (metrics if metrics is not None
                         else obs_metrics.default_registry())
        if store is not None and not hasattr(store, "get"):
            from .store import PlanStore      # path-like -> file-backed
            store = PlanStore(store)
        self.store = store

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def key(self, model, cluster: Cluster, spec: PlanSpec,
            cost_table: CostTable | None = None) -> tuple:
        return (fingerprint_model(model), cluster_signature(cluster),
                _spec_key(spec), _cost_table_key(cost_table))

    def planner_cache_for(self, model) -> PlannerCache:
        """The per-model incremental-planner state (misses plan through
        this, so repeat models stay on the hot path even when the
        cluster signature is new)."""
        return self._caches.setdefault(fingerprint_model(model),
                                       PlannerCache())

    # -- lookup / insert ------------------------------------------------
    def get(self, model, cluster: Cluster, spec: PlanSpec | None = None,
            cost_table: CostTable | None = None) -> PicoPlan | None:
        spec = spec or PlanSpec()
        key = self.key(model, cluster, spec, cost_table)
        with obs_trace.current().wall_span(
                "registry.lookup", model=key[0], cluster=key[1],
                hit=key in self._entries):
            entry = self._entries.get(key)
            if entry is None and self.store is not None:
                entry = self.store.get(key)     # shared-store fallback
                if entry is not None:
                    self._entries[key] = entry
                    while len(self._entries) > self.capacity:
                        self._entries.popitem(last=False)
                    self._metrics.counter("fleet.registry.store_hit").inc()
            if entry is None:
                self.misses += 1
                self._metrics.counter("fleet.registry.miss").inc()
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            self._metrics.counter("fleet.registry.hit").inc()
            plan = artifacts.plan_from_dict(entry["plan"])
            plan.source = "registry"
            cached_names = entry["device_names"]
            if cached_names != [d.name for d in cluster.devices]:
                _rebind(plan, cluster)
            return plan

    def put(self, model, cluster: Cluster, spec: PlanSpec | None,
            plan: PicoPlan, cost_table: CostTable | None = None) -> None:
        spec = spec or PlanSpec()
        key = self.key(model, cluster, spec, cost_table)
        entry = {
            "model": key[0], "cluster_sig": key[1], "spec": spec.to_dict(),
            "cost_table_key": key[3],
            "device_names": [d.name for d in cluster.devices],
            "cluster": artifacts.cluster_to_dict(cluster),
            "plan": artifacts.plan_to_dict(plan),
        }
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
        if self.store is not None:
            # persist beyond the LRU horizon and this process's lifetime
            self.store.put(key, entry)
        self._metrics.gauge("fleet.registry.size").set(len(self._entries))

    def get_or_plan(self, model, cluster: Cluster,
                    spec: PlanSpec | None = None,
                    cost_table: CostTable | None = None) -> PicoPlan:
        """Serve from the registry, or plan (incrementally when the
        model is known) and insert.  ``plan.source`` says which."""
        spec = spec or PlanSpec()
        hit = self.get(model, cluster, spec, cost_table)
        if hit is not None:
            return hit
        pico = plan_with_spec(model.graph, cluster, model.input_size, spec,
                              cost_table=cost_table,
                              planner_cache=self.planner_cache_for(model))
        self.put(model, cluster, spec, pico, cost_table)
        return pico

    # -- artifact round-trip --------------------------------------------
    def to_payload(self) -> dict:
        return {"capacity": self.capacity,
                "entries": list(self._entries.values())}

    @classmethod
    def from_payload(cls, d) -> "PlanRegistry":
        reg = cls(capacity=d["capacity"])
        for e in d["entries"]:
            spec = PlanSpec.from_dict(e["spec"])
            key = (e["model"], e["cluster_sig"], _spec_key(spec),
                   e.get("cost_table_key", ""))
            reg._entries[key] = dict(e)
        return reg

    def to_json(self, **kw) -> str:
        return artifacts.to_json("plan_registry", self, **kw)

    @classmethod
    def from_json(cls, s: str) -> "PlanRegistry":
        return artifacts.from_json("plan_registry", s)
