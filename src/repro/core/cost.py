"""Cost model (paper §3.2, Eq. 4-12).

Quantifies per-device compute time, per-stage communication, pipeline
period/latency, redundancy and memory.  Devices are generic: a
Raspberry-Pi (paper repro) and a TPU v5e chip (production mesh) are both
:class:`Device` instances — see DESIGN.md §3.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Mapping, Sequence

from .graph import Graph, LayerSpec, tile_widths, proportional_widths

BYTES_PER_ELEM = 4.0  # fp32 features, as in the paper's PyTorch testbed


@dataclass(frozen=True)
class Device:
    """One compute device.  ``capacity`` is FLOP/s (paper: ϑ(d_k))."""

    name: str
    capacity: float
    alpha: float = 1.0          # regression coefficient α_k (Eq. 7)
    active_power: float = 4.0   # Watts, for the energy benchmark (Fig. 16)
    idle_power: float = 1.6

    def t_comp(self, flops: float) -> float:
        return self.alpha * flops / self.capacity


@dataclass
class Cluster:
    """A set of devices + link model.

    The paper assumes a uniform WLAN bandwidth ``b`` (bytes/s); we also
    support per-pair overrides (two-tier TPU fabric: ICI vs DCI).
    """

    devices: list[Device]
    bandwidth: float = 50e6 / 8          # 50 Mbps WLAN -> bytes/s
    pair_bandwidth: dict[tuple[str, str], float] = field(default_factory=dict)

    def __post_init__(self):
        self.devices = list(self.devices)

    def __len__(self) -> int:
        return len(self.devices)

    def b(self, a: Device | str, c: Device | str) -> float:
        ka = a.name if isinstance(a, Device) else a
        kc = c.name if isinstance(c, Device) else c
        return self.pair_bandwidth.get((ka, kc),
               self.pair_bandwidth.get((kc, ka), self.bandwidth))

    def sorted_by_capacity(self, reverse: bool = True) -> list[Device]:
        return sorted(self.devices, key=lambda d: d.capacity, reverse=reverse)

    @property
    def total_capacity(self) -> float:
        return sum(d.capacity for d in self.devices)

    def homogenized(self) -> "Cluster":
        """D' of Eq. 14: same count, average capacity."""
        avg = self.total_capacity / len(self.devices)
        devs = [Device(f"avg{i}", avg) for i in range(len(self.devices))]
        return Cluster(devs, bandwidth=self.bandwidth)

    def restricted(self, devices: "Sequence[Device]") -> "Cluster":
        """Sub-cluster over ``devices``, keeping only the pair-bandwidth
        overrides internal to the subset (tenant shares, re-partitions)."""
        names = {d.name for d in devices}
        pairs = {k: v for k, v in self.pair_bandwidth.items()
                 if k[0] in names and k[1] in names}
        return Cluster(list(devices), bandwidth=self.bandwidth,
                       pair_bandwidth=pairs)


def make_pi_cluster(freqs_ghz: Sequence[float],
                    bandwidth_mbps: float = 50.0) -> Cluster:
    """Paper testbed: Raspberry-Pi 4B, one Cortex-A73 core.

    We model capacity as ~2 FLOP/cycle/core (NEON fp32 MAC) so a 1.5 GHz
    Pi is ~3 GFLOP/s — matches the order of magnitude implied by the
    paper's VGG16 (~15.5 GFLOP/frame, seconds per frame on one Pi).
    """
    devs = [Device(f"pi{i}@{f:g}GHz", capacity=f * 2e9,
                   active_power=4.0 + 1.5 * f, idle_power=1.6)
            for i, f in enumerate(freqs_ghz)]
    return Cluster(devs, bandwidth=bandwidth_mbps * 1e6 / 8)


# TPU v5e constants (production target; see system prompt / DESIGN.md §3)
TPU_PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9               # bytes/s
TPU_ICI_BW = 50e9                # bytes/s per link


def make_tpu_cluster(n_chips: int, ici_bw: float = TPU_ICI_BW) -> Cluster:
    devs = [Device(f"tpu{i}", capacity=TPU_PEAK_FLOPS, active_power=200.0,
                   idle_power=60.0) for i in range(n_chips)]
    return Cluster(devs, bandwidth=ici_bw)


# ---------------------------------------------------------------------------
# Measured cost corrections
# ---------------------------------------------------------------------------

@dataclass
class CostTable:
    """Measured per-segment compute-cost corrections.

    ``ratios[nodes]`` is observed/analytic seconds for the fused segment
    ``nodes``, as timed by ``exec.calibrate`` on its *compiled*
    executable.  ``stage_cost`` multiplies each device's analytic
    compute time by the segment's ratio, replacing the purely analytic
    alpha with measured numbers.  Segments never calibrated fall back to
    ``default`` (typically the mean measured ratio), or 1.0.
    """

    ratios: dict[frozenset[str], float] = field(default_factory=dict)
    default: float | None = None
    # autotuned kernel winners keyed by ``exec.autotune.shape_key`` —
    # {"block_ci", "block_co", "best_us", "backend"} per entry, so
    # calibration ratios and kernel tunings share one versioned store
    kernels: dict[str, dict] = field(default_factory=dict)

    def ratio(self, nodes) -> float:
        r = self.ratios.get(frozenset(nodes))
        if r is not None:
            return r
        if self.default is not None:
            return self.default
        if self.ratios:
            return sum(self.ratios.values()) / len(self.ratios)
        return 1.0

    def __len__(self) -> int:
        return len(self.ratios)


# ---------------------------------------------------------------------------
# Segment / stage costing
# ---------------------------------------------------------------------------

@dataclass
class SegmentCost:
    """Costs of one fused segment executed by ``m`` devices.

    ``per_device_flops[k]`` includes halo redundancy; ``exact_flops`` is
    the no-redundancy total; ``in_bytes[k]``/``out_bytes[k]`` are the
    scatter/gather feature volumes of device k (Eq. 9).
    """

    nodes: frozenset[str]
    per_device_flops: list[float]
    exact_flops: float
    in_bytes: list[float]
    out_bytes: list[float]
    param_bytes: int
    feature_bytes: list[float]   # peak live feature memory per device

    @property
    def redundant_flops(self) -> float:
        return max(0.0, sum(self.per_device_flops) - self.exact_flops)

    @property
    def redundancy_ratio(self) -> float:
        tot = sum(self.per_device_flops)
        return self.redundant_flops / tot if tot > 0 else 0.0


def segment_cost(
    g: Graph,
    nodes: frozenset[str] | set[str],
    full_sizes: Mapping[str, tuple[int, int]],
    input_size: tuple[int, int],
    fractions: Sequence[float],
) -> SegmentCost:
    """Cost a fused segment whose sink outputs are tile-split along width.

    ``fractions`` are per-device output-width shares (sum to 1).  Each
    device k computes the whole segment on its halo-extended input tile
    (fused-layer scheme inside a stage, paper §2.4.2).
    """
    nodes = frozenset(nodes)
    sinks = g.sinks(nodes)
    sources = g.sources(nodes)

    # exact (un-tiled) cost of the segment
    exact_out, _ = g.required_sizes(nodes, {}, full_sizes, input_size)
    exact = g.segment_flops(nodes, exact_out)

    m = len(fractions)
    per_flops, in_b, out_b, feat_b = [], [], [], []
    sink_ws = {s: full_sizes[s][0] for s in sinks}
    # integer tile widths per device per sink
    widths = {s: proportional_widths(w, fractions) if m > 1 else [w]
              for s, w in sink_ws.items()}
    for k in range(m):
        tiles = {s: (widths[s][k], full_sizes[s][1]) for s in sinks}
        if all(t[0] == 0 for t in tiles.values()):
            # device got no slice of any sink: fully idle
            per_flops.append(0.0)
            in_b.append(0.0)
            out_b.append(0.0)
            feat_b.append(0.0)
            continue
        tiles = {s: (max(t[0], 0), t[1]) for s, t in tiles.items()}
        req_out, req_in = g.required_sizes(nodes, tiles, full_sizes, input_size)
        fl = 0.0
        for n in nodes:
            spec = g.layers[n]
            if spec.tile_independent_flops:
                # attention-like: full input gathered but each output row
                # computed once -> FLOPs follow the *tile*, not the halo
                fl += spec.flops(tiles.get(n, req_out[n]))
            else:
                fl += spec.flops(req_out[n])
        per_flops.append(fl)
        ib = sum(req_in[s][0] * req_in[s][1] * g.layers[s].in_channels
                 * BYTES_PER_ELEM for s in sources)
        ob = sum(req_out[s][0] * req_out[s][1] * g.layers[s].out_channels
                 * BYTES_PER_ELEM for s in sinks)
        in_b.append(ib)
        out_b.append(ob)
        # live features: inputs + the two largest intermediate outputs
        inter = sorted((req_out[n][0] * req_out[n][1]
                        * g.layers[n].out_channels * BYTES_PER_ELEM
                        for n in nodes), reverse=True)
        feat_b.append(ib + sum(inter[:2]))
    return SegmentCost(nodes, per_flops, exact, in_b, out_b,
                       g.segment_params(nodes), feat_b)


def grid_redundant_flops(
    g: Graph,
    nodes: frozenset[str] | set[str],
    full_sizes: Mapping[str, tuple[int, int]],
    input_size: tuple[int, int],
    n_split: int,
) -> float:
    """Redundant FLOPs of a fused segment under a 2-D reference tiling.

    The paper's feature partition (Fig. 4) splits both width and height;
    this is what makes the Fig. 6 example (7x1 then 1x7 kernels) show
    redundancy when fused.  The grid is the most-square factorization of
    ``n_split``.  Used by Algorithm 1's C(M); the 1-D stage costing is
    used for the actual pipeline execution model.
    """
    nodes = frozenset(nodes)
    sinks = g.sinks(nodes)
    exact_out, _ = g.required_sizes(nodes, {}, full_sizes, input_size)
    exact = g.segment_flops(nodes, exact_out)

    # most-square factorization gw * gh == n_split
    gw = int(math.sqrt(n_split))
    while n_split % gw:
        gw -= 1
    gh = n_split // gw

    total = 0.0
    w_parts = {s: tile_widths(full_sizes[s][0], gw) for s in sinks}
    h_parts = {s: tile_widths(full_sizes[s][1], gh) for s in sinks}
    for iw in range(gw):
        for ih in range(gh):
            # a feature smaller than the grid leaves some cells idle
            # (zero tile), NOT duplicated
            tiles = {s: (w_parts[s][iw] if iw < len(w_parts[s]) else 0,
                         h_parts[s][ih] if ih < len(h_parts[s]) else 0)
                     for s in sinks}
            if all(t[0] == 0 or t[1] == 0 for t in tiles.values()):
                continue
            req_out, _ = g.required_sizes(nodes, tiles, full_sizes, input_size)
            for n in nodes:
                spec = g.layers[n]
                if spec.tile_independent_flops:
                    total += spec.flops(tiles.get(n, req_out[n]))
                else:
                    total += spec.flops(req_out[n])
    return max(0.0, total - exact)


@dataclass
class StageCost:
    """T(S) = T_comp + T_comm of one stage (Eq. 8-11)."""

    t_comp: float
    t_comm: float
    per_device_comp: list[float]
    seg: SegmentCost

    @property
    def total(self) -> float:
        return self.t_comp + self.t_comm


def stage_cost_from_segment(
    seg: SegmentCost,
    devices: Sequence[Device],
    cluster: Cluster,
    ratio: float = 1.0,
) -> StageCost:
    """Price a (possibly cached) :class:`SegmentCost` on ``devices``.

    This is the exact arithmetic tail of :func:`stage_cost` — the
    geometry (:func:`segment_cost`) is the expensive, device-independent
    part, so the incremental planner caches :class:`SegmentCost` objects
    across re-plans and re-prices them here.  Both paths share these
    lines, which is what makes cached and from-scratch stage costs
    bit-identical.
    """
    comp = [d.t_comp(f) * ratio for d, f in zip(devices, seg.per_device_flops)]
    t_comp = max(comp)
    # d_f = the first device distributes/gathers (Eq. 9-10)
    d_f = devices[0]
    t_comm = sum((seg.in_bytes[k] + seg.out_bytes[k]) / cluster.b(d_f, devices[k])
                 for k in range(1, len(devices)))
    return StageCost(t_comp, t_comm, comp, seg)


def stage_cost(
    g: Graph,
    nodes: frozenset[str] | set[str],
    full_sizes: Mapping[str, tuple[int, int]],
    input_size: tuple[int, int],
    devices: Sequence[Device],
    cluster: Cluster,
    fractions: Sequence[float] | None = None,
    cost_table: CostTable | None = None,
) -> StageCost:
    """Cost a stage: ``devices`` tile-split the segment's output.

    If ``fractions`` is None, widths are proportional to capacities
    (Algorithm 3's divide-and-conquer rebalancing; equal for homogeneous
    devices, reproducing Algorithm 2's equal split).  ``cost_table``
    scales the analytic compute times by the segment's measured ratio
    (see :class:`CostTable`).
    """
    if fractions is None:
        total = sum(d.capacity for d in devices)
        fractions = [d.capacity / total for d in devices]
    seg = segment_cost(g, nodes, full_sizes, input_size, fractions)
    ratio = cost_table.ratio(nodes) if cost_table is not None else 1.0
    return stage_cost_from_segment(seg, devices, cluster, ratio)
