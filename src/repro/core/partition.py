"""Algorithm 1 — partition a CNN DAG into a chain of *pieces*.

Dynamic programming over *ending pieces* (Definition 4: suffix-closed
vertex subsets), memoized on the frozenset of remaining vertices, with
the chain-constraint of §4.2 (every vertex adjacent to the removed part
must join the next ending piece) and the diameter bound of Definition 5.

State transfer (Eq. 13):

    F(G) = min over ending pieces M_E of max(F(G - M_E), C(M_E))

where C(M) is the redundant-FLOPs cost of piece M under a reference
``n_split``-way output tiling.

A divide-and-conquer driver (``partition_graph_dnc``) handles very wide
NAS-style graphs as described in §6.2.3.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field, replace
from typing import Mapping, Sequence

from .graph import Graph, tile_widths
from .cost import grid_redundant_flops


@dataclass
class Piece:
    """One element of the resulting chain."""

    nodes: frozenset[str]
    redundancy: float           # C(M) under the reference split
    index: int = -1

    def __len__(self) -> int:
        return len(self.nodes)


@dataclass
class PartitionResult:
    pieces: list[Piece]
    objective: float            # F(G): worst piece redundancy
    states_explored: int
    wall_time_s: float

    def __iter__(self):
        return iter(self.pieces)

    def __len__(self):
        return len(self.pieces)

    @classmethod
    def from_pieces(cls, pieces: Sequence[Piece], *,
                    states_explored: int = 0,
                    wall_time_s: float = 0.0) -> "PartitionResult":
        """Honest result for a reused/caller-supplied piece chain.

        Pieces are re-indexed to their chain position and the objective
        is the true F(G) of the chain (worst piece redundancy).
        ``states_explored``/``wall_time_s`` default to 0 — nothing was
        searched — but a re-planner can carry the original search stats
        through so downstream audits (e.g. the serving scheduler's
        repartition records) see the partition's real provenance.
        """
        pieces = list(pieces)
        if not pieces:
            raise ValueError("from_pieces needs at least one piece")
        pieces = [p if p.index == i else replace(p, index=i)
                  for i, p in enumerate(pieces)]
        return cls(pieces, max(p.redundancy for p in pieces),
                   states_explored, wall_time_s)


def piece_redundancy(
    g: Graph,
    nodes: frozenset[str],
    full_sizes: Mapping[str, tuple[int, int]],
    input_size: tuple[int, int],
    n_split: int,
) -> float:
    """C(M): extra FLOPs of an ``n_split``-way 2-D tiled execution vs
    exact (the paper's Fig. 4 reference partition)."""
    return grid_redundant_flops(g, nodes, full_sizes, input_size, n_split)


class _Partitioner:
    def __init__(self, g: Graph, input_size: tuple[int, int],
                 n_split: int, max_diameter: int,
                 max_candidates: int = 512, max_states: int = 20000):
        self.g = g
        self.input_size = input_size
        self.n_split = n_split
        self.d = max_diameter
        self.full = g.forward_sizes(input_size)
        self.F: dict[frozenset, float] = {}
        self.R: dict[frozenset, frozenset] = {}
        self.C_cache: dict[frozenset, float] = {}
        self.states = 0
        # pragmatic pruning for very wide graphs (the paper's diameter
        # bound alone does not tame w>=6 NAS graphs in pure Python):
        # cap candidate ending pieces per state and total DP states;
        # beyond the caps, fall back to the smallest valid piece.
        self.max_candidates = max_candidates
        self.max_states = max_states

    # -- redundancy with memo ------------------------------------------
    def C(self, nodes: frozenset[str]) -> float:
        hit = self.C_cache.get(nodes)
        if hit is None:
            hit = piece_redundancy(self.g, nodes, self.full,
                                   self.input_size, self.n_split)
            self.C_cache[nodes] = hit
        return hit

    # -- must-set: vertices of `remaining` adjacent to removed part -----
    def must(self, remaining: frozenset[str]) -> frozenset[str]:
        g = self.g
        out = set()
        for n in remaining:
            if any(s not in remaining for s in g.succs[n]):
                out.add(n)
        return frozenset(out)

    # -- enumerate ending pieces -----------------------------------------
    def ending_pieces(self, remaining: frozenset[str]):
        """All suffix-closed S ⊆ remaining with must ⊆ S, diameter ≤ d.

        Enumeration band: only vertices whose longest path to a sink of
        ``remaining`` is ≤ d can appear in a bounded-diameter ending
        piece together with that sink; we enumerate order ideals of the
        reversed DAG restricted to that band.
        """
        g = self.g
        must = self.must(remaining)
        # height = longest path to any sink of `remaining`
        height: dict[str, int] = {}
        order = [n for n in g.topo_order if n in remaining]
        for n in reversed(order):
            hs = [height[s] + 1 for s in g.succs[n] if s in remaining]
            height[n] = max(hs, default=0)
        band = [n for n in order if height[n] <= self.d]
        band_set = set(band)
        if not must <= band_set:
            # the forced vertices are too deep: take everything reachable
            # down from them (single fallback piece = rest of the graph)
            yield remaining
            return

        # Grow suffix-closed sets over `band`, processed in reverse topo
        # order so a vertex may be added only after all its successors.
        # ``depth[n]`` = longest path from n inside the selection; since
        # selections are suffix-closed, max depth == piece diameter, so we
        # prune incrementally instead of checking at the leaves.
        rev = list(reversed(band))

        def rec(i: int, sel: set[str], depth: dict[str, int]):
            if i == len(rev):
                if sel:
                    yield frozenset(sel)
                return
            n = rev[i]
            succs_in = [s for s in g.succs[n] if s in remaining]
            can_add = all(s in sel for s in succs_in)
            dn = 0
            if can_add:
                dn = 1 + max((depth[s] for s in succs_in), default=-1)
                if dn > self.d:
                    can_add = False
            # choice 1: skip n (only legal if n not forced)
            if n not in must:
                yield from rec(i + 1, sel, depth)
            elif not can_add:
                return  # forced vertex cannot be added -> dead branch
            # choice 2: add n
            if can_add:
                sel.add(n)
                depth[n] = dn
                yield from rec(i + 1, sel, depth)
                sel.discard(n)
                del depth[n]

        yield from rec(0, set(), {})

    # -- the DP -----------------------------------------------------------
    def solve(self, remaining: frozenset[str]) -> float:
        if not remaining:
            return 0.0
        if remaining in self.F:
            return self.F[remaining]
        self.states += 1
        best, best_piece = float("inf"), None
        budget = (self.max_candidates
                  if self.states <= self.max_states else 1)
        for me in self.ending_pieces(remaining):
            budget -= 1
            c = self.C(me)
            rest = remaining - me
            cur = max(self.solve(rest), c)
            if cur < best:
                best, best_piece = cur, me
            if budget <= 0:
                break
        if best_piece is None:  # no bounded piece: swallow everything
            best_piece = remaining
            best = self.C(remaining)
        self.F[remaining] = best
        self.R[remaining] = best_piece
        return best

    def obtain(self) -> list[frozenset[str]]:
        out: list[frozenset[str]] = []
        remaining = frozenset(self.g.layers)
        while remaining:
            piece = self.R[remaining]
            out.append(piece)
            remaining = remaining - piece
        out.reverse()  # ending pieces are peeled from the back
        return out


def partition_graph(
    g: Graph,
    input_size: tuple[int, int],
    n_split: int = 2,
    max_diameter: int = 5,
) -> PartitionResult:
    """Run Algorithm 1 on the whole graph."""
    t0 = time.perf_counter()
    p = _Partitioner(g, input_size, n_split, max_diameter)
    obj = p.solve(frozenset(g.layers))
    node_sets = p.obtain()
    pieces = [Piece(ns, p.C(ns), i) for i, ns in enumerate(node_sets)]
    return PartitionResult(pieces, obj, p.states, time.perf_counter() - t0)


def partition_graph_dnc(
    g: Graph,
    input_size: tuple[int, int],
    n_split: int = 2,
    max_diameter: int = 5,
    chunk: int = 40,
    keep_margin: int = 2,
) -> PartitionResult:
    """Divide-and-conquer driver for very wide/deep graphs (§6.2.3).

    Cut a ~``chunk``-vertex prefix (closed under predecessors), run
    Algorithm 1 on it, keep all result pieces except the last
    ``keep_margin`` (those may straddle the cut line), remove the kept
    vertices and repeat on the rest.
    """
    t0 = time.perf_counter()
    full = g.forward_sizes(input_size)
    remaining = list(g.topo_order)
    kept: list[frozenset[str]] = []
    states = 0
    while remaining:
        take = remaining[: min(chunk, len(remaining))]
        take_set = set(take)
        # close under predecessors within remaining (should already hold
        # for a topo prefix, but be safe)
        sub = _induced_subgraph(g, take_set)
        p = _Partitioner(sub, input_size, n_split, max_diameter)
        # the sub-partitioner needs sizes consistent with the full graph
        p.full = {n: full[n] for n in take_set}
        # sources of the chunk need their true input sizes
        p.input_size = input_size
        p.solve(frozenset(sub.layers))
        pieces = _obtain_from(p, frozenset(sub.layers))
        states += p.states
        if len(remaining) > len(take):  # not the last chunk: drop margin
            drop = min(keep_margin, max(0, len(pieces) - 1))
            pieces = pieces[: len(pieces) - drop] if drop else pieces
        kept.extend(pieces)
        used = set().union(*pieces) if pieces else take_set
        remaining = [n for n in remaining if n not in used]
    cobj = 0.0
    out: list[Piece] = []
    pp = _Partitioner(g, input_size, n_split, max_diameter)
    for i, ns in enumerate(kept):
        c = pp.C(ns)
        cobj = max(cobj, c)
        out.append(Piece(ns, c, i))
    return PartitionResult(out, cobj, states, time.perf_counter() - t0)


def _obtain_from(p: _Partitioner, root: frozenset[str]) -> list[frozenset[str]]:
    out = []
    remaining = root
    while remaining:
        piece = p.R[remaining]
        out.append(piece)
        remaining = remaining - piece
    out.reverse()
    return out


def _induced_subgraph(g: Graph, nodes: set[str]) -> Graph:
    sub = Graph()
    for n in g.topo_order:
        if n in nodes:
            sub.layers[n] = g.layers[n]
    sub.edges = [(u, v) for u, v in g.edges if u in nodes and v in nodes]
    sub._invalidate()
    return sub


def chain_pieces(g: Graph) -> list[frozenset[str]]:
    """Trivial partition for chain graphs: every vertex its own piece."""
    return [frozenset({n}) for n in g.topo_order]


def block_pieces(g: Graph, blocks: Sequence[Sequence[str]]) -> list[Piece]:
    """Baseline of [6]/[17]: treat whole blocks as pieces."""
    return [Piece(frozenset(b), 0.0, i) for i, b in enumerate(blocks)]
