"""Discrete pipeline simulator: runtime metrics for a PipelinePlan.

Simulates a stream of frames through the stages (stage s starts frame f
when both the previous stage finished f and itself finished f-1) and
derives throughput, per-device utilization, redundancy ratio, memory
footprint and energy — the quantities of the paper's Figs. 13-16 and
Table 5.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from .cost import Cluster, BYTES_PER_ELEM
from .pipeline_dp import PipelinePlan


@dataclass
class DeviceReport:
    device: str
    stage: int
    utilization: float          # busy / makespan-per-frame
    redundancy: float           # redundant / total FLOPs on this device
    memory_bytes: float         # params + live features
    energy_j: float


@dataclass
class SimReport:
    period: float
    latency: float
    throughput_per_min: float
    frames: int
    makespan: float
    devices: list[DeviceReport] = field(default_factory=list)

    @property
    def avg_utilization(self) -> float:
        return sum(d.utilization for d in self.devices) / len(self.devices)

    @property
    def avg_redundancy(self) -> float:
        return sum(d.redundancy for d in self.devices) / len(self.devices)

    @property
    def avg_memory(self) -> float:
        return sum(d.memory_bytes for d in self.devices) / len(self.devices)

    @property
    def total_energy_j(self) -> float:
        return sum(d.energy_j for d in self.devices)


@dataclass(frozen=True)
class PlanMetrics:
    """Steady-state per-frame metrics of one plan — the four axes the
    multi-objective planner trades (:mod:`repro.core.pareto`).

    ``period`` and ``latency`` come straight off the plan;
    ``energy_j`` is the steady-state per-frame energy (the
    ``frames -> inf`` limit of :func:`simulate`'s energy accounting:
    every device pays active power while busy and idle power for the
    rest of each period); ``memory_bytes`` is the peak per-device
    footprint (params + live features, the same quantity
    ``DeviceReport.memory_bytes`` reports).
    """

    period: float
    latency: float
    energy_j: float
    memory_bytes: float

    def as_tuple(self) -> tuple[float, float, float, float]:
        """(period, latency, energy_j, memory_bytes) — all minimized."""
        return (self.period, self.latency, self.energy_j, self.memory_bytes)


def plan_metrics(plan: PipelinePlan) -> PlanMetrics:
    """Simulate-derived :class:`PlanMetrics` for a priced plan.

    Exact closed form of the steady state :func:`simulate` converges
    to: per frame, device ``k`` of a stage is busy ``per_device_comp[k]``
    seconds and idle for the remainder of the pipeline period.
    """
    period = plan.period
    energy = 0.0
    memory = 0.0
    for st in plan.stages:
        seg = st.cost.seg
        for k, dev in enumerate(st.devices):
            busy = st.cost.per_device_comp[k]
            energy += (dev.active_power * busy
                       + dev.idle_power * max(0.0, period - busy))
            memory = max(memory, seg.param_bytes + seg.feature_bytes[k])
    return PlanMetrics(period, plan.latency, energy, memory)


def simulate(plan: PipelinePlan, frames: int = 64,
             cluster: Cluster | None = None) -> SimReport:
    S = len(plan.stages)
    T = [st.cost.total for st in plan.stages]
    finish = [[0.0] * S for _ in range(frames)]
    for f in range(frames):
        for s in range(S):
            prev_stage = finish[f][s - 1] if s > 0 else 0.0
            prev_frame = finish[f - 1][s] if f > 0 else 0.0
            finish[f][s] = max(prev_stage, prev_frame) + T[s]
    makespan = finish[-1][-1]
    # steady-state period from the simulated stream (tail minus warm-up)
    if frames >= 2:
        period_meas = (finish[-1][-1] - finish[0][-1]) / (frames - 1)
    else:
        period_meas = T and max(T) or 0.0

    reports: list[DeviceReport] = []
    for si, st in enumerate(plan.stages):
        seg = st.cost.seg
        for k, dev in enumerate(st.devices):
            busy = st.cost.per_device_comp[k] * frames
            util = busy / makespan if makespan > 0 else 0.0
            tot = seg.per_device_flops[k]
            exact_share = seg.exact_flops * (st.fractions[k]
                                             if st.fractions else 1 / len(st.devices))
            red = max(0.0, (tot - exact_share) / tot) if tot > 0 else 0.0
            mem = seg.param_bytes + seg.feature_bytes[k]
            energy = (dev.active_power * busy
                      + dev.idle_power * max(0.0, makespan - busy))
            reports.append(DeviceReport(dev.name, si, util, red, mem, energy))
    return SimReport(
        period=period_meas,
        latency=plan.latency,
        throughput_per_min=60.0 / period_meas if period_meas > 0 else 0.0,
        frames=frames,
        makespan=makespan,
        devices=reports,
    )
