"""PICO facade: model graph + cluster -> executable PipelinePlan.

The two-step optimization of the paper:
  1. Algorithm 1: orchestrate the DAG into a chain of pieces.
  2. Algorithm 2 on the homogenized cluster (Eq. 14), then Algorithm 3
     to adapt to the true heterogeneous devices.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from ..api._compat import _UNSET, pick, unset, warn_legacy
from ..api.specs import PlanSpec
from ..obs import trace as obs_trace
from .graph import Graph
from .cost import Cluster, CostTable, stage_cost
from .partition import (Piece, PartitionResult, partition_graph,
                        partition_graph_dnc)
from .pipeline_dp import PipelineDP, PipelinePlan, PlannerCache, StagePlan
from .hetero import adjust_stages

# Provenance of a PicoPlan (threaded through ServeReport's repartition
# audit and the fleet registry):
#   scratch     — full Algorithm 1 + 2 + 3 run, nothing reused
#   incremental — piece chain and/or PlannerCache state reused; only
#                 device-dependent work re-ran
#   registry    — an identical (model, cluster, spec) plan was served
#                 from a fleet PlanRegistry without planning at all
PLAN_SOURCES = ("scratch", "incremental", "registry")


@dataclass
class PicoPlan:
    partition: PartitionResult
    pipeline: PipelinePlan
    source: str = "scratch"
    # objective provenance: the ObjectiveSpec label this plan was scored
    # under (None = legacy pure-throughput planning).  Rides through the
    # plan artifact codec and Deployment.describe().
    objective: str | None = None

    def __post_init__(self):
        if self.source not in PLAN_SOURCES:
            raise ValueError(f"source must be one of {PLAN_SOURCES}, "
                             f"got {self.source!r}")
        if self.objective is not None and not isinstance(self.objective, str):
            raise ValueError("objective must be None or a label string, "
                             f"got {self.objective!r}")

    @property
    def period(self) -> float:
        return self.pipeline.period

    @property
    def latency(self) -> float:
        return self.pipeline.latency

    @property
    def throughput(self) -> float:
        return self.pipeline.throughput


def plan_with_spec(
    g: Graph,
    cluster: Cluster,
    input_size: tuple[int, int],
    spec: PlanSpec | None = None,
    *,
    pieces: Sequence[Piece] | None = None,
    partition: PartitionResult | None = None,
    cost_table: CostTable | None = None,
    planner_cache: PlannerCache | None = None,
) -> PicoPlan:
    """Run the full PICO optimization under a :class:`PlanSpec`.

    This is the one implementation every entry point (the ``repro.api``
    facade, the legacy :func:`plan`/:func:`replan` shims, the runtime's
    churn re-planner, the serving scheduler) funnels into.

    Algorithm 1 may be skipped by supplying either raw ``pieces`` (an
    honest :class:`PartitionResult` is derived via
    :meth:`PartitionResult.from_pieces`) or a full ``partition`` whose
    search stats are carried through — re-plans reuse the piece chain
    without fabricating degenerate partition metadata.  ``cost_table``
    (from ``exec.calibrate``) substitutes measured per-segment compute
    costs for the analytic alpha model in every stage costing.

    ``planner_cache`` (a :class:`~repro.core.pipeline_dp.PlannerCache`
    owned by the caller and passed to every re-plan of the same model)
    turns Algorithm 2 into the incremental hot path: segment geometry
    survives device churn, and the resulting plan's ``source`` is
    ``"incremental"`` whenever cached work was actually reused.

    ``spec.objective`` (an :class:`~repro.api.specs.ObjectiveSpec`)
    makes the DP score candidates by the weighted multi-objective
    scalarization and enforce its hard constraints: a finite
    ``max_latency_s`` tightens ``t_lim``, a finite ``max_memory_bytes``
    prunes memory-violating stage shapes inside the DP.  The default
    (``None`` / pure-throughput) leaves planning bit-identical to the
    legacy single-objective path.
    """
    spec = spec or PlanSpec()
    obj = spec.objective
    t_lim = spec.t_lim
    if obj is not None:
        t_lim = min(t_lim, obj.max_latency_s)
    with obs_trace.current().wall_span(
            "plan", n_devices=len(cluster), n_layers=len(g.layers),
            reuse_partition=partition is not None or pieces is not None,
            measured_costs=cost_table is not None):
        if partition is not None:
            if pieces is not None:
                raise ValueError("pass pieces= or partition=, not both")
            part = PartitionResult.from_pieces(
                partition.pieces, states_explored=partition.states_explored,
                wall_time_s=partition.wall_time_s)
        elif pieces is not None:
            part = PartitionResult.from_pieces(pieces)
        else:
            n_split = spec.resolve_n_split(len(cluster))
            if len(g.layers) > spec.dnc_threshold:
                part = partition_graph_dnc(g, input_size, n_split,
                                           spec.max_diameter)
            else:
                part = partition_graph(g, input_size, n_split,
                                       spec.max_diameter)

        # a cache is "warm" when it already holds geometry for this
        # exact chain — only then is the plan genuinely incremental
        warm = (planner_cache is not None and len(planner_cache) > 0
                and planner_cache.sig == PlannerCache.chain_signature(
                    g, part.pieces, input_size))
        homo = cluster.homogenized()
        dp = PipelineDP(g, part.pieces, homo, input_size, t_lim,
                        cost_table=cost_table, cache=planner_cache,
                        objective=obj)
        homo_plan = dp.build()
        final = adjust_stages(homo_plan, cluster, g, input_size,
                              cost_table=cost_table)
    return PicoPlan(part, final,
                    source="incremental" if warm else "scratch",
                    objective=obj.label() if obj is not None else None)


def plan(
    g: Graph,
    cluster: Cluster,
    input_size: tuple[int, int],
    t_lim: float = _UNSET,
    max_diameter: int = _UNSET,
    n_split: int | None = _UNSET,
    dnc_threshold: int = _UNSET,
    pieces: Sequence[Piece] | None = None,
    cost_table: CostTable | None = None,
    spec: PlanSpec | None = None,
) -> PicoPlan:
    """Run the full PICO optimization.

    Planner knobs live in ``spec`` (:class:`~repro.api.specs.PlanSpec`);
    the individual ``t_lim``/``max_diameter``/``n_split``/
    ``dnc_threshold`` keywords are a deprecated compatibility surface
    that maps onto an equivalent spec.  ``pieces`` skips Algorithm 1
    with a caller-supplied chain; ``cost_table`` substitutes measured
    per-segment compute costs for the analytic alpha model.
    """
    legacy = not unset(t_lim, max_diameter, n_split, dnc_threshold)
    if spec is not None:
        if legacy:
            raise TypeError("pass either spec= or the legacy planner "
                            "kwargs, not both")
    else:
        if legacy:
            warn_legacy("repro.core.plan",
                        "plan(g, cluster, input_size, spec=PlanSpec(...))")
        spec = PlanSpec(t_lim=pick(t_lim, float("inf")),
                        max_diameter=pick(max_diameter, 5),
                        n_split=pick(n_split, None),
                        dnc_threshold=pick(dnc_threshold, 120))
    return plan_with_spec(g, cluster, input_size, spec, pieces=pieces,
                          cost_table=cost_table)


def replan(
    g: Graph,
    cluster: Cluster,
    input_size: tuple[int, int],
    prev: PicoPlan,
    t_lim: float = _UNSET,
    cost_table: CostTable | None = None,
    spec: PlanSpec | None = None,
    planner_cache: PlannerCache | None = None,
) -> PicoPlan:
    """Incremental re-plan after a cluster change (runtime feedback loop).

    Algorithm 1's piece chain depends only on the graph, so it is reused
    from ``prev`` verbatim (search stats carried through); only the
    device-dependent steps re-run (Algorithm 2's DP over the homogenized
    cluster + Algorithm 3's heterogeneous adjustment).  ``cluster`` is
    expected to carry *measured* costs — e.g.
    ``Monitor.calibrated_cluster`` scales each device's alpha by its
    observed/modeled EWMA — so successive re-plans optimize against the
    cluster as it behaves, not as it was specced.
    """
    if spec is not None:
        if not unset(t_lim):
            raise TypeError("pass either spec= or t_lim=, not both")
    else:
        if not unset(t_lim):
            warn_legacy("repro.core.replan",
                        "replan(..., spec=PlanSpec(...))")
        spec = PlanSpec(t_lim=pick(t_lim, float("inf")))
    return plan_with_spec(g, cluster, input_size, spec,
                          partition=prev.partition, cost_table=cost_table,
                          planner_cache=planner_cache)


@dataclass
class TenantShare:
    """One tenant's slice of a partitioned cluster."""

    index: int
    cluster: Cluster
    pico: PicoPlan

    @property
    def capacity(self) -> float:
        return self.cluster.total_capacity

    @property
    def device_names(self) -> frozenset[str]:
        return frozenset(d.name for d in self.cluster.devices)


@dataclass
class ClusterPartition:
    shares: list[TenantShare]
    weights: list[float]

    @property
    def aggregate_throughput(self) -> float:
        """Modeled frames/s summed across tenants (each sub-pipeline
        saturated)."""
        return sum(1.0 / s.pico.period for s in self.shares
                   if s.pico.period > 0)

    def assignment(self) -> dict[int, tuple[str, ...]]:
        return {s.index: tuple(d.name for d in s.cluster.devices)
                for s in self.shares}


def split_devices(cluster: Cluster, weights: Sequence[float]) -> list[list]:
    """Device-split step of :func:`partition_cluster` (no planning):
    every tenant gets one device (biggest devices to biggest weights),
    then each remaining device goes largest-first to the tenant most
    below its weighted capacity target.  Cheap enough for a control
    loop to test whether a re-partition would change anything."""
    n = len(weights)
    w = [float(x) for x in weights]
    if n == 0 or any(x <= 0 for x in w):
        raise ValueError("weights must be positive, one per tenant")
    if len(cluster.devices) < n:
        raise ValueError(f"{n} tenants need >= {n} devices, cluster has "
                         f"{len(cluster.devices)}")
    total_w = sum(w)
    total_cap = cluster.total_capacity
    devs = cluster.sorted_by_capacity()
    order = sorted(range(n), key=lambda i: -w[i])
    buckets: list[list] = [[] for _ in range(n)]
    cap = [0.0] * n
    for slot, ti in enumerate(order):
        buckets[ti].append(devs[slot])
        cap[ti] += devs[slot].capacity
    for d in devs[n:]:
        ti = min(range(n), key=lambda i: (cap[i] / (w[i] / total_w
                                                    * total_cap), i))
        buckets[ti].append(d)
        cap[ti] += d.capacity
    return buckets


def partition_cluster(
    models: Sequence,
    cluster: Cluster,
    weights: Sequence[float] | None = None,
    t_lims: Sequence[float] | None = None,
    cost_table: CostTable | None = None,
    prev: Sequence[PicoPlan | None] | None = None,
    plan_specs: Sequence[PlanSpec | None] | None = None,
    plan_fn=None,
) -> ClusterPartition:
    """Split one cluster's devices across several co-hosted models and
    run the PICO optimization on each sub-cluster (the many-to-many
    mapping lifted to multi-tenant serving).

    ``models`` are graph carriers (``CNNDef`` or anything with
    ``.graph`` and ``.input_size``); ``weights`` are relative capacity
    entitlements (tenant priority x observed load), defaulting to equal.
    Every tenant gets at least one device; remaining devices go
    largest-first to the tenant most below its weighted capacity
    target.  ``prev[i]`` (a prior :class:`PicoPlan` for model ``i``)
    reuses Algorithm 1's piece chain so load-shift re-partitions only
    redo the device-dependent planning steps.  ``plan_specs[i]`` carries
    tenant ``i``'s planner knobs; ``t_lims`` is the legacy equivalent
    (ignored where a spec is given).

    ``plan_fn(i, model, sub_cluster, spec, prev_plan) -> PicoPlan``
    overrides how each share is planned — the hook the serving scheduler
    and fleet tier use to route through per-tenant
    :class:`~repro.core.pipeline_dp.PlannerCache` instances or a fleet
    :class:`~repro.fleet.registry.PlanRegistry`.
    """
    n = len(models)
    if n == 0:
        raise ValueError("partition_cluster needs at least one model")
    w = [1.0] * n if weights is None else [float(x) for x in weights]
    if len(w) != n:
        raise ValueError("weights must be positive, one per model")
    buckets = split_devices(cluster, w)

    shares = []
    for i, bucket in enumerate(buckets):
        sub = cluster.restricted(bucket)
        m = models[i]
        spec = plan_specs[i] if plan_specs is not None else None
        if spec is None:
            t_lim = t_lims[i] if t_lims is not None else float("inf")
            spec = PlanSpec(t_lim=t_lim)
        prev_i = prev[i] if prev is not None else None
        if plan_fn is not None:
            pico = plan_fn(i, m, sub, spec, prev_i)
        else:
            pico = plan_with_spec(
                m.graph, sub, m.input_size, spec,
                partition=prev_i.partition if prev_i is not None else None,
                cost_table=cost_table)
        shares.append(TenantShare(i, sub, pico))
    return ClusterPartition(shares, w)


def recost(
    pipeline: PipelinePlan,
    cluster: Cluster,
    g: Graph,
    input_size: tuple[int, int],
    cost_table: CostTable | None = None,
) -> PipelinePlan:
    """Re-price an existing plan under new device costs, keeping the
    stage -> device assignment.  Lets a re-planner compare the incumbent
    plan against a fresh one on equal (measured) footing — the DP must
    use every device, so e.g. after a DeviceJoin the fresh plan can
    legitimately lose to the incumbent."""
    full = g.forward_sizes(input_size)
    by_name = {d.name: d for d in cluster.devices}
    stages = []
    for st in pipeline.stages:
        devs = [by_name.get(d.name, d) for d in st.devices]
        sc = stage_cost(g, st.nodes, full, input_size, devs, cluster,
                        list(st.fractions), cost_table=cost_table)
        stages.append(StagePlan(st.first_piece, st.last_piece, devs,
                                st.nodes, sc, list(st.fractions)))
    period = max(s.cost.total for s in stages)
    latency = sum(s.cost.total for s in stages)
    return PipelinePlan(stages, period, latency, pipeline.wall_time_s)
