"""PICO core: graph IR, cost model, and the paper's three algorithms."""

from .graph import Graph, LayerSpec, tile_widths, proportional_widths
from .cost import (Device, Cluster, CostTable, SegmentCost, StageCost,
                   segment_cost, stage_cost, make_pi_cluster,
                   make_tpu_cluster, TPU_PEAK_FLOPS, TPU_HBM_BW, TPU_ICI_BW,
                   BYTES_PER_ELEM)
from .partition import (Piece, PartitionResult, partition_graph,
                        partition_graph_dnc, piece_redundancy, chain_pieces,
                        block_pieces)
from .pipeline_dp import PipelineDP, PipelinePlan, StagePlan, plan_pipeline
from .hetero import adjust_stages
from .planner import (PicoPlan, plan, plan_with_spec, replan, recost,
                      partition_cluster, split_devices, ClusterPartition,
                      TenantShare)
from .simulate import (simulate, SimReport, DeviceReport, PlanMetrics,
                       plan_metrics)
from .pareto import FrontPoint, ParetoFront, dominates, plan_front
from . import baselines

__all__ = [
    "Graph", "LayerSpec", "tile_widths", "proportional_widths",
    "Device", "Cluster", "CostTable", "SegmentCost", "StageCost",
    "segment_cost",
    "stage_cost", "make_pi_cluster", "make_tpu_cluster",
    "TPU_PEAK_FLOPS", "TPU_HBM_BW", "TPU_ICI_BW", "BYTES_PER_ELEM",
    "Piece", "PartitionResult", "partition_graph", "partition_graph_dnc",
    "piece_redundancy", "chain_pieces", "block_pieces",
    "PipelineDP", "PipelinePlan", "StagePlan", "plan_pipeline",
    "adjust_stages", "PicoPlan", "plan", "plan_with_spec", "replan",
    "recost",
    "partition_cluster", "split_devices", "ClusterPartition", "TenantShare",
    "simulate",
    "SimReport",
    "DeviceReport", "PlanMetrics", "plan_metrics",
    "FrontPoint", "ParetoFront", "dominates", "plan_front",
    "baselines",
]
