"""Multi-objective planning: the Pareto front over PICO plans.

The single-objective planner (Algorithms 1-3) returns *the*
throughput-optimal plan.  This module sweeps the configuration space —
device-count subsets (largest devices first) x latency budgets — prices
every candidate with the simulate-derived steady-state metrics
(:func:`~repro.core.simulate.plan_metrics`: period, latency, energy,
peak per-device memory), dominance-filters, and returns the whole
:class:`ParetoFront`.  A deployment then *selects* a point by objective
(:data:`~repro.api.specs.OBJECTIVE_PRESETS` or a custom
:class:`~repro.api.specs.ObjectiveSpec`) instead of baking one
objective into the planner.

The sweep is cheap by construction: every candidate shares one
Algorithm 1 piece chain and one
:class:`~repro.core.pipeline_dp.PlannerCache`, so segment geometry —
the dominant planning cost — is computed once and every subsequent
candidate runs the vectorized incremental DP path.

Why the sweep axes create genuine trade-offs: fewer (large) devices
means fewer stages — less idle energy and no boundary traffic, at the
price of a longer period (throughput); tighter latency budgets force
the DP off the throughput optimum toward shallower pipelines.  Front
points therefore trade period against latency, energy and memory in
exactly the directions the paper's DVFS/ battery discussion predicts.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Sequence

from ..api.specs import ObjectiveSpec, PlanSpec
from ..obs import trace as obs_trace
from .cost import Cluster, CostTable
from .pipeline_dp import PlannerCache
from .planner import PicoPlan, plan_with_spec
from .simulate import PlanMetrics, plan_metrics


def dominates(a: PlanMetrics, b: PlanMetrics) -> bool:
    """Pareto dominance (all metrics minimized): ``a`` is no worse on
    every axis and strictly better on at least one."""
    at, bt = a.as_tuple(), b.as_tuple()
    return all(x <= y for x, y in zip(at, bt)) and \
        any(x < y for x, y in zip(at, bt))


@dataclass(frozen=True)
class FrontPoint:
    """One non-dominated plan: the plan itself, its steady-state
    metrics, and the sweep coordinates that produced it."""

    plan: PicoPlan
    metrics: PlanMetrics
    n_devices: int
    t_lim: float = float("inf")

    @property
    def period(self) -> float:
        return self.metrics.period

    @property
    def latency(self) -> float:
        return self.metrics.latency

    @property
    def energy_j(self) -> float:
        return self.metrics.energy_j

    @property
    def memory_bytes(self) -> float:
        return self.metrics.memory_bytes


@dataclass
class ParetoFront:
    """Mutually non-dominated plans for one (model, cluster), sorted by
    (period, latency, energy, memory) — best throughput first, so
    ``points[0]`` is always the single-objective optimum."""

    points: list[FrontPoint] = field(default_factory=list)
    spec: PlanSpec = field(default_factory=PlanSpec)

    def __len__(self) -> int:
        return len(self.points)

    def __iter__(self):
        return iter(self.points)

    @property
    def throughput_optimum(self) -> FrontPoint:
        """The pure-throughput point (min period; ties on latency) —
        bit-identical to what ``plan_with_spec`` returns on its own.
        Metric ties break toward the full-cluster unconstrained sweep
        candidate (most devices, loosest budget), i.e. the plan the
        single-objective planner itself would return."""
        return min(self.points,
                   key=lambda p: (p.metrics.period, p.metrics.latency,
                                  -p.n_devices, -p.t_lim))

    def _utopia(self) -> PlanMetrics:
        """Elementwise minimum across the front — the normalization
        reference that makes objective weights unit-free."""
        return PlanMetrics(
            min(p.metrics.period for p in self.points),
            min(p.metrics.latency for p in self.points),
            min(p.metrics.energy_j for p in self.points),
            min(p.metrics.memory_bytes for p in self.points))

    def select(self, objective: ObjectiveSpec | str | None = None
               ) -> FrontPoint:
        """Pick the front point a given objective prefers.

        ``objective`` is an :class:`ObjectiveSpec`, a preset name
        (``"battery"``, ``"latency"``, ...), or ``None`` (throughput).
        Hard constraints filter first; an empty feasible set raises
        ``ValueError`` (the caller decides whether to relax).  Scoring
        normalizes every metric by the front's elementwise minimum so
        the weights compare like-for-like; ties break toward the
        lexicographically best metrics tuple.
        """
        if not self.points:
            raise ValueError("empty Pareto front")
        if objective is None:
            obj = ObjectiveSpec.named("throughput")
        elif isinstance(objective, str):
            obj = ObjectiveSpec.named(objective)
        else:
            obj = objective
        feasible = [p for p in self.points if obj.feasible(p.metrics)]
        if not feasible:
            raise ValueError(
                f"no front point satisfies the {obj.label()!r} objective's "
                f"constraints (front size {len(self.points)}); relax the "
                f"constraints or re-sweep with a tighter spec")
        ref = self._utopia()
        return min(feasible, key=lambda p: (obj.score(p.metrics, ref),
                                            p.metrics.as_tuple()))

    def deployment(self, model, cluster: Cluster, deploy_spec=None,
                   exec_spec=None, *, objective=None,
                   cost_table: CostTable | None = None):
        """Ship one front point as a ready
        :class:`~repro.api.deployment.Deployment`.

        The point is chosen by ``objective`` (spec, preset name, or
        ``None``), defaulting to ``deploy_spec.objective`` when the
        deploy spec names a profile.  The chosen plan carries the
        objective label as provenance (``PicoPlan.objective``), visible
        in ``describe()`` and the saved artifact.
        """
        from ..api.deployment import Deployment   # lazy: avoid cycle
        from ..api.specs import DeploySpec, ExecSpec
        if objective is None and deploy_spec is not None:
            objective = deploy_spec.objective
        point = self.select(objective)
        if objective is None:
            label = "throughput"
        elif isinstance(objective, str):
            label = objective
        else:
            label = objective.label()
        pico = _dc_replace(point.plan, objective=label)
        plan_spec = (self.spec if not math.isfinite(point.t_lim)
                     else self.spec.replace(t_lim=point.t_lim))
        dep = Deployment(model, cluster, plan_spec,
                         exec_spec or ExecSpec(), pico,
                         cost_table=cost_table)
        if deploy_spec is None:
            deploy_spec = DeploySpec(objective=label)
        return dep

    # -- persistence (versioned pareto_front artifact) ------------------
    def to_json(self, **dump_kw) -> str:
        from ..api import artifacts
        return artifacts.to_json("pareto_front", self, **dump_kw)

    @classmethod
    def from_json(cls, s: str) -> "ParetoFront":
        from ..api import artifacts
        return artifacts.from_json("pareto_front", s)


def _non_dominated(points: Sequence[FrontPoint]) -> list[FrontPoint]:
    """Dedup (identical metric tuples collapse to their first plan)
    then dominance-filter."""
    seen: dict[tuple, FrontPoint] = {}
    for p in points:
        seen.setdefault(p.metrics.as_tuple(), p)
    uniq = list(seen.values())
    return [p for p in uniq
            if not any(dominates(q.metrics, p.metrics) for q in uniq)]


def plan_front(
    model,
    cluster: Cluster,
    spec: PlanSpec | None = None,
    *,
    cost_table: CostTable | None = None,
    planner_cache: PlannerCache | None = None,
    t_lim_fractions: Sequence[float] = (0.85, 0.7, 0.55),
    min_devices: int = 1,
) -> ParetoFront:
    """Sweep the configuration space and return the Pareto front.

    Candidates: for every device count ``d`` from ``len(cluster)`` down
    to ``min_devices`` (keeping the ``d`` largest devices), the
    throughput-optimal plan plus one plan per latency budget in
    ``t_lim_fractions`` (fractions of that subset's unconstrained
    latency).  All candidates share ``spec``'s partition knobs, one
    piece chain, and one :class:`PlannerCache`, so everything after the
    first plan runs the incremental vectorized DP path.  The full-
    cluster unconstrained candidate is planned on ``cluster`` exactly
    as :func:`~repro.core.planner.plan_with_spec` would, so the front
    always contains the single-objective optimum bit-identically.
    """
    spec = spec or PlanSpec()
    base = spec.replace(objective=None) if spec.objective is not None \
        else spec
    cache = planner_cache if planner_cache is not None else PlannerCache()
    g, input_size = model.graph, model.input_size
    D = len(cluster)
    lo = max(1, min(min_devices, D))
    with obs_trace.current().wall_span(
            "plan_front", n_devices=D, n_layers=len(g.layers),
            t_lims=len(t_lim_fractions)):
        by_cap = cluster.sorted_by_capacity()
        part = None
        candidates: list[FrontPoint] = []
        for d in range(D, lo - 1, -1):
            sub = cluster if d == D else cluster.restricted(by_cap[:d])
            pico = plan_with_spec(g, sub, input_size, base,
                                  partition=part, cost_table=cost_table,
                                  planner_cache=cache)
            if part is None:
                part = pico.partition
            candidates.append(FrontPoint(pico, plan_metrics(pico.pipeline),
                                         d, base.t_lim))
            for frac in t_lim_fractions:
                t = pico.latency * frac
                if not (t > 0 and math.isfinite(t)):
                    continue
                t = min(t, base.t_lim)
                tight = plan_with_spec(g, sub, input_size,
                                       base.replace(t_lim=t),
                                       partition=part,
                                       cost_table=cost_table,
                                       planner_cache=cache)
                if not tight.pipeline.feasible:
                    continue
                candidates.append(
                    FrontPoint(tight, plan_metrics(tight.pipeline), d, t))
        points = _non_dominated(candidates)
        points.sort(key=lambda p: p.metrics.as_tuple())
    return ParetoFront(points, spec)
