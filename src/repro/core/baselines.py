"""Baseline parallelization schemes the paper compares against (§6.1).

* LW  — layer-wise (MoDNN [4]): every layer split over all devices,
        scatter/gather each layer.
* EFL — early-fused-layer (DeepThings [5]): fuse the first K conv
        layers, split over all devices; the rest runs on one device.
* OFL — optimal fused-layer (AOFL [6]): DP over fusion boundaries; all
        devices execute every fused segment, synchronizing in between.
* CE  — CoEdge [22]: layer-wise with a *dynamic* per-layer device count
        and neighbor-limited halo communication.
* BFS — exhaustive search for the true optimal pipeline (used in the
        paper's Tables 6-7 to show PICO ~ optimal at tiny cost).

All schemes share the cost model of :mod:`repro.core.cost`, and report
(period, latency, per-device compute) so they can be compared with PICO.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Sequence

from .graph import Graph, tile_widths
from .cost import (BYTES_PER_ELEM, Cluster, Device, SegmentCost, StageCost,
                   segment_cost, stage_cost)
from .partition import Piece
from .pipeline_dp import PipelineDP, PipelinePlan, StagePlan
from .hetero import adjust_stages


@dataclass
class SchemeResult:
    name: str
    period: float                 # time between finished frames
    latency: float                # per-frame latency
    per_device_flops: dict[str, float] = field(default_factory=dict)
    per_device_busy: dict[str, float] = field(default_factory=dict)
    redundant_flops: float = 0.0
    total_flops: float = 0.0
    memory_bytes: dict[str, float] = field(default_factory=dict)
    wall_time_s: float = 0.0
    extra: dict = field(default_factory=dict)

    @property
    def throughput(self) -> float:
        return 1.0 / self.period if self.period > 0 else float("inf")

    @property
    def redundancy_ratio(self) -> float:
        return self.redundant_flops / self.total_flops if self.total_flops else 0.0


def _chain(g: Graph) -> list[str]:
    return list(g.topo_order)


def _acc(res: SchemeResult, devices: Sequence[Device], seg: SegmentCost,
         comp: Sequence[float]):
    for d, f, c in zip(devices, seg.per_device_flops, comp):
        res.per_device_flops[d.name] = res.per_device_flops.get(d.name, 0.0) + f
        res.per_device_busy[d.name] = res.per_device_busy.get(d.name, 0.0) + c
    res.redundant_flops += seg.redundant_flops
    res.total_flops += sum(seg.per_device_flops)


# ---------------------------------------------------------------------------
# LW — layer-wise
# ---------------------------------------------------------------------------

def layer_wise(g: Graph, cluster: Cluster,
               input_size: tuple[int, int]) -> SchemeResult:
    t0 = time.perf_counter()
    full = g.forward_sizes(input_size)
    devs = cluster.devices
    res = SchemeResult("LW", 0.0, 0.0)
    total = 0.0
    for n in g.topo_order:
        if g.layers[n].kind in ("input", "output"):
            continue
        sc = stage_cost(g, frozenset({n}), full, input_size, devs, cluster)
        total += sc.total
        _acc(res, devs, sc.seg, sc.per_device_comp)
    res.period = res.latency = total
    # every device holds the full model + its feature slice
    params = g.segment_params(g.layers)
    for d in devs:
        res.memory_bytes[d.name] = params + 2 * _max_feature_bytes(g, full) / len(devs)
    res.wall_time_s = time.perf_counter() - t0
    return res


def _max_feature_bytes(g: Graph, full) -> float:
    return max((full[n][0] * full[n][1] * g.layers[n].out_channels
                * BYTES_PER_ELEM for n in g.layers), default=0.0)


# ---------------------------------------------------------------------------
# EFL — early fused layers
# ---------------------------------------------------------------------------

def early_fused(g: Graph, cluster: Cluster, input_size: tuple[int, int],
                n_fused: int | None = None) -> SchemeResult:
    t0 = time.perf_counter()
    order = _chain(g)
    full = g.forward_sizes(input_size)
    n_fused = n_fused if n_fused is not None else max(1, len(order) * 2 // 3)
    head = frozenset(order[:n_fused])
    tail = frozenset(order[n_fused:])
    devs = cluster.devices
    res = SchemeResult("EFL", 0.0, 0.0)
    sc = stage_cost(g, head, full, input_size, devs, cluster)
    total = sc.total
    _acc(res, devs, sc.seg, sc.per_device_comp)
    if tail:
        best = max(devs, key=lambda d: d.capacity)
        sc2 = stage_cost(g, tail, full, input_size, [best], cluster)
        total += sc2.total
        # hand-off of the head output to `best`
        boundary = sc.seg.out_bytes
        total += sum(boundary) / cluster.b(best, devs[0])
        _acc(res, [best], sc2.seg, sc2.per_device_comp)
    res.period = res.latency = total
    params = g.segment_params(g.layers)
    for d in devs:
        res.memory_bytes[d.name] = params + 2 * _max_feature_bytes(g, full) / len(devs)
    res.wall_time_s = time.perf_counter() - t0
    return res


# ---------------------------------------------------------------------------
# OFL — optimal fused layers (AOFL-style DP, no pipelining)
# ---------------------------------------------------------------------------

def optimal_fused(g: Graph, cluster: Cluster, input_size: tuple[int, int],
                  pieces: Sequence[Piece] | None = None) -> SchemeResult:
    """DP over fusion boundaries on the chain of pieces; all devices run
    every fused segment and synchronize at the boundaries."""
    t0 = time.perf_counter()
    full = g.forward_sizes(input_size)
    if pieces is None:
        units = [frozenset({n}) for n in _chain(g)]
    else:
        units = [p.nodes for p in pieces]
    L = len(units)
    devs = cluster.devices

    costs: dict[tuple[int, int], StageCost] = {}

    def seg_cost(i, j) -> StageCost:
        if (i, j) not in costs:
            nodes = frozenset().union(*units[i:j + 1])
            costs[(i, j)] = stage_cost(g, nodes, full, input_size, devs, cluster)
        return costs[(i, j)]

    INF = float("inf")
    best = [INF] * (L + 1)
    back = [-1] * (L + 1)
    best[0] = 0.0
    for j in range(1, L + 1):
        for i in range(j):
            c = best[i] + seg_cost(i, j - 1).total
            if c < best[j]:
                best[j], back[j] = c, i
    # reconstruct
    bounds = []
    j = L
    while j > 0:
        bounds.append((back[j], j - 1))
        j = back[j]
    bounds.reverse()
    res = SchemeResult("OFL", best[L], best[L])
    for i, j in bounds:
        sc = seg_cost(i, j)
        _acc(res, devs, sc.seg, sc.per_device_comp)
    params = g.segment_params(g.layers)
    for d in devs:
        res.memory_bytes[d.name] = params + 2 * _max_feature_bytes(g, full) / len(devs)
    res.extra["segments"] = bounds
    res.wall_time_s = time.perf_counter() - t0
    return res


# ---------------------------------------------------------------------------
# CE — CoEdge
# ---------------------------------------------------------------------------

def coedge(g: Graph, cluster: Cluster,
           input_size: tuple[int, int]) -> SchemeResult:
    """Layer-wise with per-layer dynamic device count (greedy over the
    capacity-sorted prefix) and neighbor-only halo traffic."""
    t0 = time.perf_counter()
    full = g.forward_sizes(input_size)
    devs_sorted = cluster.sorted_by_capacity()
    res = SchemeResult("CE", 0.0, 0.0)
    total = 0.0
    for n in g.topo_order:
        spec = g.layers[n]
        if spec.kind in ("input", "output"):
            continue
        best_t, best = float("inf"), None
        for m in range(1, len(devs_sorted) + 1):
            devs = devs_sorted[:m]
            capsum = sum(d.capacity for d in devs)
            fracs = [d.capacity / capsum for d in devs]
            seg = segment_cost(g, frozenset({n}), full, input_size, fracs)
            comp = [d.t_comp(f) for d, f in zip(devs, seg.per_device_flops)]
            # neighbor-only: each device ships just its halo strip
            halo_bytes = []
            for k in range(m):
                extra = seg.in_bytes[k] - (seg.in_bytes[k] * fracs[k])
                halo_bytes.append(max(0.0, extra) * 0.25)
            t_comm = sum(h / cluster.b(devs[0], devs[k])
                         for k, h in enumerate(halo_bytes) if k > 0)
            t = max(comp) + t_comm
            if t < best_t:
                best_t, best = t, (devs, seg, comp)
        total += best_t
        _acc(res, best[0], best[1], best[2])
    res.period = res.latency = total
    params = g.segment_params(g.layers)
    for d in cluster.devices:
        res.memory_bytes[d.name] = params + _max_feature_bytes(g, full) / len(cluster)
    res.wall_time_s = time.perf_counter() - t0
    return res


# ---------------------------------------------------------------------------
# BFS — exhaustive optimal pipeline
# ---------------------------------------------------------------------------

def bfs_optimal(
    g: Graph,
    pieces: Sequence[Piece],
    cluster: Cluster,
    input_size: tuple[int, int],
    t_lim: float = float("inf"),
    budget_s: float = 3600.0,
) -> SchemeResult:
    """Enumerate every (stage boundary, device multiset) assignment.

    For heterogeneous clusters this enumerates ordered set-partitions of
    the actual devices; it explodes combinatorially — which is the
    paper's point (Tables 6-7).  ``budget_s`` caps the search.
    """
    t0 = time.perf_counter()
    full = g.forward_sizes(input_size)
    units = [p.nodes for p in pieces]
    L, D = len(units), len(cluster)
    devices = cluster.devices
    homogeneous = len({d.capacity for d in devices}) == 1

    seg_nodes: dict[tuple[int, int], frozenset] = {}

    def nodes_of(i, j):
        if (i, j) not in seg_nodes:
            seg_nodes[(i, j)] = frozenset().union(*units[i:j + 1])
        return seg_nodes[(i, j)]

    best = SchemeResult("BFS", float("inf"), float("inf"))
    best.extra["complete"] = True
    count = 0

    def boundaries():
        # ways to split 0..L-1 into 1..min(L, D) contiguous stages
        for k in range(1, min(L, D) + 1):
            for cut in itertools.combinations(range(1, L), k - 1):
                segs, prev = [], 0
                for c in cut:
                    segs.append((prev, c - 1))
                    prev = c
                segs.append((prev, L - 1))
                yield segs

    def device_splits(n_stages):
        if homogeneous:
            # only counts matter
            def comp(total, parts):
                if parts == 1:
                    yield (total,)
                    return
                for first in range(1, total - parts + 2):
                    for rest in comp(total - first, parts - 1):
                        yield (first,) + rest
            for counts in comp(D, n_stages):
                yield [devices[sum(counts[:i]):sum(counts[:i + 1])]
                       for i in range(n_stages)]
        else:
            # ordered set partitions of the device list
            def parts(items, k):
                if k == 1:
                    yield [list(items)]
                    return
                if len(items) < k:
                    return
                # assign each item to one of k groups, groups nonempty
                for assign in itertools.product(range(k), repeat=len(items)):
                    groups = [[] for _ in range(k)]
                    for it, a in zip(items, assign):
                        groups[a].append(it)
                    if all(groups):
                        yield groups
            yield from parts(list(devices), n_stages)

    for segs in boundaries():
        for groups in device_splits(len(segs)):
            if time.perf_counter() - t0 > budget_s:
                best.extra["complete"] = False
                best.wall_time_s = time.perf_counter() - t0
                return best
            count += 1
            period, latency = 0.0, 0.0
            detail = []
            ok = True
            for (i, j), devs in zip(segs, groups):
                sc = stage_cost(g, nodes_of(i, j), full, input_size, devs, cluster)
                period = max(period, sc.total)
                latency += sc.total
                detail.append((devs, sc))
                if latency > t_lim or period >= best.period:
                    ok = False
                    break
            if ok and latency <= t_lim and period < best.period:
                best.period, best.latency = period, latency
                best.per_device_flops.clear()
                best.per_device_busy.clear()
                best.redundant_flops = best.total_flops = 0.0
                for devs, sc in detail:
                    _acc(best, devs, sc.seg, sc.per_device_comp)
                best.extra["stages"] = [(i, j, [d.name for d in devs])
                                        for (i, j), devs in zip(segs, groups)]
    best.extra["configs_evaluated"] = count
    best.wall_time_s = time.perf_counter() - t0
    return best


# ---------------------------------------------------------------------------
# PICO wrapper producing a SchemeResult (for apples-to-apples tables)
# ---------------------------------------------------------------------------

def pico_scheme(g: Graph, pieces: Sequence[Piece], cluster: Cluster,
                input_size: tuple[int, int],
                t_lim: float = float("inf")) -> SchemeResult:
    t0 = time.perf_counter()
    dp = PipelineDP(g, list(pieces), cluster.homogenized(), input_size, t_lim)
    plan = adjust_stages(dp.build(), cluster, g, input_size)
    res = SchemeResult("PICO", plan.period, plan.latency)
    for st in plan.stages:
        _acc(res, st.devices, st.cost.seg, st.cost.per_device_comp)
        for k, d in enumerate(st.devices):
            res.memory_bytes[d.name] = (st.cost.seg.param_bytes
                                        + st.cost.seg.feature_bytes[k])
    res.extra["plan"] = plan
    res.wall_time_s = time.perf_counter() - t0
    return res
