"""Algorithm 3 — adapt a homogeneous-optimal pipeline to real devices.

Greedy: sort devices by capacity (desc); repeatedly give the next device
to the stage with the highest remaining per-slot average compute demand
Θ'/|D'|.  When a stage's slots fill up, rebalance its output-tile widths
proportionally to the assigned devices' capacities (the paper's
divide-and-conquer feature re-partition).
"""

from __future__ import annotations

import time
from dataclasses import replace
from typing import Sequence

from .cost import Cluster, Device, stage_cost
from .pipeline_dp import PipelinePlan, StagePlan


def adjust_stages(
    plan: PipelinePlan,
    cluster: Cluster,
    g,
    input_size: tuple[int, int],
) -> PipelinePlan:
    """Algorithm 3.  ``plan`` comes from PipelineDP on cluster.homogenized()."""
    t0 = time.perf_counter()
    full = g.forward_sizes(input_size)

    # remaining slots + per-slot demand for every homogeneous stage
    slots = [st.n_devices for st in plan.stages]
    demand = [sum(st.cost.seg.per_device_flops) / max(st.n_devices, 1)
              for st in plan.stages]
    assigned: list[list[Device]] = [[] for _ in plan.stages]

    for dev in cluster.sorted_by_capacity():
        # stage with max remaining average demand (paper text §5.1.2)
        cand = [k for k in range(len(plan.stages)) if slots[k] > 0]
        if not cand:
            break
        k = max(cand, key=lambda q: demand[q])
        assigned[k].append(dev)
        slots[k] -= 1

    stages: list[StagePlan] = []
    period = 0.0
    latency = 0.0
    for st, devs in zip(plan.stages, assigned):
        devs = devs or list(st.devices)  # safety: keep placeholder devices
        total = sum(d.capacity for d in devs)
        fracs = [d.capacity / total for d in devs]
        sc = stage_cost(g, st.nodes, full, input_size, devs, cluster, fracs)
        stages.append(StagePlan(st.first_piece, st.last_piece, devs,
                                st.nodes, sc, fracs))
        period = max(period, sc.total)
        latency += sc.total
    return PipelinePlan(stages, period, latency,
                        plan.wall_time_s + (time.perf_counter() - t0))
