"""Algorithm 3 — adapt a homogeneous-optimal pipeline to real devices.

Greedy: sort devices by capacity (desc); repeatedly give the next device
to the stage with the highest remaining per-slot average compute demand
Θ'/|D'|.  When a stage's slots fill up, rebalance its output-tile widths
proportionally to the assigned devices' capacities (the paper's
divide-and-conquer feature re-partition).
"""

from __future__ import annotations

import time
from dataclasses import replace
from typing import Sequence

from .cost import Cluster, CostTable, Device, stage_cost
from .pipeline_dp import PipelinePlan, StagePlan


def adjust_stages(
    plan: PipelinePlan,
    cluster: Cluster,
    g,
    input_size: tuple[int, int],
    cost_table: CostTable | None = None,
) -> PipelinePlan:
    """Algorithm 3.  ``plan`` comes from PipelineDP on cluster.homogenized()."""
    t0 = time.perf_counter()
    full = g.forward_sizes(input_size)

    # remaining slots + per-slot demand for every homogeneous stage
    slots = [st.n_devices for st in plan.stages]
    demand = [sum(st.cost.seg.per_device_flops) / max(st.n_devices, 1)
              for st in plan.stages]
    assigned: list[list[Device]] = [[] for _ in plan.stages]

    for dev in cluster.sorted_by_capacity():
        # stage with max remaining average demand (paper text §5.1.2)
        cand = [k for k in range(len(plan.stages)) if slots[k] > 0]
        if not cand:
            break
        k = max(cand, key=lambda q: demand[q])
        assigned[k].append(dev)
        slots[k] -= 1

    stages: list[StagePlan] = []
    period = 0.0
    latency = 0.0
    for si, (st, devs) in enumerate(zip(plan.stages, assigned)):
        if not devs:
            # The seed silently fell back to the homogenized *placeholder*
            # devices here, leaking fictitious "avgN" devices into the
            # final plan whenever the cluster had fewer devices than the
            # plan had slots.  That plan is unexecutable — fail loudly;
            # callers must re-plan on the cluster they actually have.
            raise ValueError(
                f"adjust_stages: stage {si} received no devices — the plan "
                f"needs {sum(s.n_devices for s in plan.stages)} device slots "
                f"but the cluster has {len(cluster.devices)}; re-plan on the "
                "current cluster instead of adjusting a stale pipeline")
        total = sum(d.capacity for d in devs)
        fracs = [d.capacity / total for d in devs]
        sc = stage_cost(g, st.nodes, full, input_size, devs, cluster, fracs,
                        cost_table=cost_table)
        stages.append(StagePlan(st.first_piece, st.last_piece, devs,
                                st.nodes, sc, fracs))
        period = max(period, sc.total)
        latency += sc.total
    return PipelinePlan(stages, period, latency,
                        plan.wall_time_s + (time.perf_counter() - t0))
