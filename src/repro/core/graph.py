"""Layer/DAG intermediate representation + receptive-field math (paper Eq. 2-5).

A CNN (or transformer backbone) is a DAG of :class:`LayerSpec` vertices.
PICO's cost model needs, per layer, the spatial mapping between an output
*tile* and the input region required to compute it exactly:

    in = (out - 1) * stride + kernel          (Eq. 3, backward)
    out = (in + 2*pad - kernel) // stride + 1 (Eq. 5, forward)

Layers with a *global* receptive field (fc, global-pool, full attention)
require the full input extent for any output tile — the analogue of an
infinitely large conv kernel (see DESIGN.md §6).

Feature sizes are tracked as (w, h); 1-D sequence models use h == 1.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from functools import cached_property
from typing import Callable, Iterable, Mapping, Sequence

# Kinds with weights and/or meaningful FLOPs.  Everything else (add,
# concat, input, output) is a connector with k=1, s=1 and ~zero FLOPs.
COMPUTE_KINDS = frozenset(
    {"conv", "pool", "fc", "dwconv", "attn", "swa", "conv1d", "ssd",
     "ffn", "moe", "embed", "norm"}
)
CONNECTOR_KINDS = frozenset({"add", "concat", "input", "output", "identity"})
# Kinds whose receptive field is the full input extent.
GLOBAL_RF_KINDS = frozenset({"fc", "gpool", "attn"})


@dataclass(frozen=True)
class LayerSpec:
    """One vertex of the model DAG.

    kernel/stride/padding are (w, h) tuples.  ``in_channels`` is the
    channel count of the (concatenated) input, ``out_channels`` of the
    output.  ``flops_coeff`` overrides the per-output-element FLOPs when
    the closed-form conv formula (Eq. 4) does not apply (attention, ssd,
    ffn, ...).  ``param_bytes`` is the weight memory of the layer.
    """

    name: str
    kind: str = "conv"
    kernel: tuple[int, int] = (1, 1)
    stride: tuple[int, int] = (1, 1)
    padding: tuple[int, int] = (0, 0)
    in_channels: int = 1
    out_channels: int = 1
    flops_coeff: float | None = None  # FLOPs per output spatial element
    param_bytes: int = 0
    global_rf: bool = False
    # if True, tiling the output does NOT duplicate FLOPs even though the
    # input must be fully gathered (true for attention: each query row is
    # computed once regardless of the tile layout).
    tile_independent_flops: bool = False

    def __post_init__(self):
        if self.kind in GLOBAL_RF_KINDS and not self.global_rf:
            object.__setattr__(self, "global_rf", True)

    # ---- spatial maps -------------------------------------------------
    def out_size(self, in_size: tuple[int, int]) -> tuple[int, int]:
        """Forward map (Eq. 5)."""
        if self.global_rf:
            return (1, 1) if self.kind in ("fc", "gpool") else in_size
        w = (in_size[0] + 2 * self.padding[0] - self.kernel[0]) // self.stride[0] + 1
        h = (in_size[1] + 2 * self.padding[1] - self.kernel[1]) // self.stride[1] + 1
        return (max(w, 1), max(h, 1))

    def in_size_for(self, out_size: tuple[int, int],
                    full_in: tuple[int, int]) -> tuple[int, int]:
        """Backward map (Eq. 3): input extent needed for an output tile.

        ``full_in`` caps the halo at the real feature boundary and is the
        answer for global-RF layers.
        """
        if self.global_rf:
            return full_in
        if out_size[0] == 0 or out_size[1] == 0:
            return (0, 0)
        w = (out_size[0] - 1) * self.stride[0] + self.kernel[0]
        h = (out_size[1] - 1) * self.stride[1] + self.kernel[1]
        return (min(w, full_in[0]), min(h, full_in[1]))

    # ---- cost ----------------------------------------------------------
    def flops(self, out_size: tuple[int, int]) -> float:
        """FLOPs to produce an output tile of ``out_size`` (Eq. 4)."""
        w, h = out_size
        if self.flops_coeff is not None:
            return self.flops_coeff * w * h
        if self.kind == "conv":
            return (self.kernel[0] * self.kernel[1] * self.in_channels
                    * w * h * self.out_channels)
        if self.kind == "dwconv":
            return self.kernel[0] * self.kernel[1] * w * h * self.out_channels
        if self.kind == "fc":
            return float(self.in_channels) * self.out_channels
        if self.kind in ("pool", "gpool"):
            return 0.25 * self.kernel[0] * self.kernel[1] * w * h * self.out_channels
        return 0.0


@dataclass
class Graph:
    """A DAG of layers.  Edges are (producer, consumer) name pairs."""

    layers: dict[str, LayerSpec] = field(default_factory=dict)
    edges: list[tuple[str, str]] = field(default_factory=list)

    # -- construction ---------------------------------------------------
    def add(self, spec: LayerSpec, inputs: Sequence[str] = ()) -> str:
        if spec.name in self.layers:
            raise ValueError(f"duplicate layer {spec.name!r}")
        self.layers[spec.name] = spec
        for src in inputs:
            if src not in self.layers:
                raise ValueError(f"unknown input {src!r} for {spec.name!r}")
            self.edges.append((src, spec.name))
        self._invalidate()
        return spec.name

    def _invalidate(self):
        for attr in ("preds", "succs", "topo_order"):
            self.__dict__.pop(attr, None)

    # -- structure -------------------------------------------------------
    @cached_property
    def preds(self) -> dict[str, list[str]]:
        p: dict[str, list[str]] = {n: [] for n in self.layers}
        for u, v in self.edges:
            p[v].append(u)
        return p

    @cached_property
    def succs(self) -> dict[str, list[str]]:
        s: dict[str, list[str]] = {n: [] for n in self.layers}
        for u, v in self.edges:
            s[u].append(v)
        return s

    @cached_property
    def topo_order(self) -> list[str]:
        indeg = {n: len(self.preds[n]) for n in self.layers}
        # stable Kahn: preserves insertion order for deterministic output
        ready = [n for n in self.layers if indeg[n] == 0]
        out: list[str] = []
        while ready:
            n = ready.pop(0)
            out.append(n)
            for m in self.succs[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(out) != len(self.layers):
            raise ValueError("graph has a cycle")
        return out

    def sources(self, nodes: Iterable[str] | None = None) -> list[str]:
        nodes = set(nodes) if nodes is not None else set(self.layers)
        return [n for n in self.topo_order if n in nodes
                and not any(p in nodes for p in self.preds[n])]

    def sinks(self, nodes: Iterable[str] | None = None) -> list[str]:
        """Sink vertices of a segment (paper Definition 3): vertices with
        at least one consumer *outside* the segment (or none at all).
        Skip connections crossing the boundary make a mid-segment vertex
        a sink too — its output must be shipped to a later stage."""
        nodes = set(nodes) if nodes is not None else set(self.layers)
        return [n for n in self.topo_order if n in nodes
                and (not self.succs[n]
                     or any(s not in nodes for s in self.succs[n]))]

    @property
    def n_compute_layers(self) -> int:
        return sum(1 for l in self.layers.values() if l.kind in COMPUTE_KINDS)

    def width(self) -> int:
        """Dilworth width == max antichain == min chain cover (Def. 6).

        Computed as the max, over topological 'levels', of concurrently
        open paths; exact for our layered model graphs and cheap.
        """
        # longest-path level per node
        level: dict[str, int] = {}
        for n in self.topo_order:
            level[n] = 1 + max((level[p] for p in self.preds[n]), default=-1)
        counts: dict[int, int] = {}
        for n, l in level.items():
            counts[l] = counts.get(l, 0) + 1
        return max(counts.values()) if counts else 0

    # -- feature propagation (Eq. 2-5) ------------------------------------
    def forward_sizes(self, input_size: tuple[int, int]) -> dict[str, tuple[int, int]]:
        """Full (un-tiled) output feature size of every layer."""
        out: dict[str, tuple[int, int]] = {}
        for n in self.topo_order:
            spec = self.layers[n]
            ps = self.preds[n]
            if not ps:
                in_sz = input_size
            else:
                ws = [out[p][0] for p in ps]
                hs = [out[p][1] for p in ps]
                if spec.kind == "add":
                    in_sz = (max(ws), max(hs))
                else:  # concat & everything else: spatial dims must agree
                    in_sz = (max(ws), max(hs))
            out[n] = spec.out_size(in_sz) if spec.kind not in CONNECTOR_KINDS \
                else in_sz
        return out

    def required_sizes(
        self,
        nodes: frozenset[str] | set[str],
        sink_tiles: Mapping[str, tuple[int, int]],
        full_sizes: Mapping[str, tuple[int, int]],
        input_size: tuple[int, int],
    ) -> tuple[dict[str, tuple[int, int]], dict[str, tuple[int, int]]]:
        """Backward pass over a segment (Eq. 2-3).

        Given required output tiles at the segment's sink vertices,
        returns (required_out, required_in) extents per layer.  Tiles are
        capped at the true feature size.  ``full_sizes`` must come from
        :meth:`forward_sizes` on the whole graph.
        """
        nodes = set(nodes)
        req_out: dict[str, tuple[int, int]] = {}
        req_in: dict[str, tuple[int, int]] = {}
        order = [n for n in self.topo_order if n in nodes]
        for n in reversed(order):
            spec = self.layers[n]
            demands = [req_in[s] for s in self.succs[n] if s in nodes]
            if n in sink_tiles:
                demands.append(tuple(sink_tiles[n]))
            if not demands:  # sink with no explicit tile: full output
                demands.append(full_sizes[n])
            w = max(d[0] for d in demands)
            h = max(d[1] for d in demands)
            full_out = full_sizes[n]
            req_out[n] = (min(w, full_out[0]), min(h, full_out[1]))
            if spec.kind in CONNECTOR_KINDS:
                req_in[n] = req_out[n]
            else:
                ps = self.preds[n]
                full_in = full_sizes[ps[0]] if ps else input_size
                req_in[n] = spec.in_size_for(req_out[n], full_in)
        return req_out, req_in

    def required_ranges(
        self,
        nodes: frozenset[str] | set[str],
        sink_ranges: Mapping[str, tuple[int, int]],
        full_sizes: Mapping[str, tuple[int, int]],
        input_size: tuple[int, int],
    ) -> tuple[dict[str, tuple[int, int]], dict[str, tuple[int, int]]]:
        """Exact backward *range* propagation along the width dim.

        Like :meth:`required_sizes` but positional: given half-open
        output ranges ``[a, b)`` (in each sink's own output coordinates),
        returns per-node (out_range, in_range) such that VALID execution
        of the segment on the input ranges reproduces the monolithic
        output ranges bit-for-bit.  Height is never tiled here.

        Backward map (padding-aware): out [a, b) reads padded coords
        [a*s, (b-1)*s + k), i.e. real input coords
        [a*s - p, (b-1)*s + k - p), clamped to the real extent.  The
        executor re-derives how much implicit zero padding each tile
        needs on each side from the same arithmetic, so SAME-padded
        models tile exactly.  Global-RF layers need the full input range.
        """
        nodes = set(nodes)
        req_out: dict[str, tuple[int, int]] = {}
        req_in: dict[str, tuple[int, int]] = {}
        order = [n for n in self.topo_order if n in nodes]
        for n in reversed(order):
            spec = self.layers[n]
            demands = [req_in[s] for s in self.succs[n] if s in nodes]
            if n in sink_ranges:
                demands.append(tuple(sink_ranges[n]))
            if not demands:
                demands.append((0, full_sizes[n][0]))
            a = min(d[0] for d in demands)
            b = max(d[1] for d in demands)
            full_w = full_sizes[n][0]
            a, b = max(0, a), min(b, full_w)
            req_out[n] = (a, b)
            ps = self.preds[n]
            full_in_w = (full_sizes[ps[0]] if ps else input_size)[0]
            if spec.kind in CONNECTOR_KINDS:
                req_in[n] = (a, b)
            elif spec.global_rf:
                req_in[n] = (0, full_in_w)
            else:
                ia = a * spec.stride[0] - spec.padding[0]
                ib = (b - 1) * spec.stride[0] + spec.kernel[0] - spec.padding[0]
                ia = max(0, min(ia, full_in_w))
                ib = max(ia, min(ib, full_in_w))  # all-padding tile -> empty
                req_in[n] = (ia, ib)
        return req_out, req_in

    def tile_padding(self, name: str, out_range: tuple[int, int],
                     full_in_w: int) -> tuple[int, int]:
        """Implicit zero padding (left, right) along W that a tile with
        output range ``out_range`` needs — nonzero only where the tile
        touches the real feature boundary of a padded layer."""
        spec = self.layers[name]
        a, b = out_range
        ia = a * spec.stride[0] - spec.padding[0]
        ib = (b - 1) * spec.stride[0] + spec.kernel[0] - spec.padding[0]
        return (max(0, -ia), max(0, ib - full_in_w))

    # -- segment utilities -------------------------------------------------
    def segment_flops(
        self,
        nodes: Iterable[str],
        req_out: Mapping[str, tuple[int, int]],
    ) -> float:
        total = 0.0
        for n in nodes:
            total += self.layers[n].flops(req_out[n])
        return total

    def segment_params(self, nodes: Iterable[str]) -> int:
        return sum(self.layers[n].param_bytes for n in nodes)

    def subset_diameter(self, nodes: frozenset[str]) -> int:
        """Longest path (edge count) between any two vertices inside ``nodes``."""
        longest: dict[str, int] = {}
        best = 0
        for n in self.topo_order:
            if n not in nodes:
                continue
            l = 0
            for p in self.preds[n]:
                if p in nodes:
                    l = max(l, longest[p] + 1)
            longest[n] = l
            best = max(best, l)
        return best


def tile_widths(total: int, parts: int) -> list[int]:
    """Split ``total`` into ``parts`` near-equal positive widths."""
    parts = min(parts, total)
    base, extra = divmod(total, parts)
    return [base + (1 if i < extra else 0) for i in range(parts)]


def proportional_widths(total: int, weights: Sequence[float]) -> list[int]:
    """Split ``total`` proportionally to ``weights``.

    Weights <= 0 get width 0 (the device sits the stage out — an empty
    tile, not a 1-column sliver).  Among the positive weights, parts are
    >= 1 when total >= their count; otherwise the ``total``
    largest-weight parts get 1 and the rest 0 (a feature narrower than
    the device group: surplus devices idle, as in the paper's CE note).
    """
    assert len(weights) > 0
    pos = [i for i, w in enumerate(weights) if w > 0]
    if not pos:
        raise ValueError("proportional_widths: all weights are <= 0")
    if len(pos) < len(weights):
        inner = proportional_widths(total, [weights[i] for i in pos])
        out = [0] * len(weights)
        for i, w in zip(pos, inner):
            out[i] = w
        return out
    if total < len(weights):
        order = sorted(range(len(weights)), key=lambda i: -weights[i])
        out = [0] * len(weights)
        for i in order[:total]:
            out[i] = 1
        return out
    ideal = [max(w, 1e-12) / sum(max(w, 1e-12) for w in weights) * total
             for w in weights]
    out = [max(1, int(math.floor(x))) for x in ideal]
    # distribute the remainder to the largest fractional parts
    rem = total - sum(out)
    order = sorted(range(len(weights)), key=lambda i: ideal[i] - math.floor(ideal[i]),
                   reverse=True)
    i = 0
    while rem > 0:
        out[order[i % len(out)]] += 1
        rem -= 1
        i += 1
    while rem < 0:  # floor+max(1,..) overshoot
        j = max(range(len(out)), key=lambda k: out[k])
        if out[j] > 1:
            out[j] -= 1
            rem += 1
        else:
            break
    return out
