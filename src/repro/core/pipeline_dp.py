"""Algorithm 2 — many-to-many mapping of pieces x devices to pipeline stages.

DP of Eq. 15 over states (i, j, p): the optimal pipeline for pieces
i..j with p homogeneous devices is either a single stage, or an optimal
sub-pipeline over i..s with p-m devices followed by one stage s+1..j
replicated over m devices:

    P[i][j][p] = min_{i<=s<j} min_{1<=m<p} max(P[i][s][p-m], Ts[s+1][j][m])

Latency (sum of stage times) is tracked alongside and solutions whose
latency exceeds ``T_lim`` are pruned, matching the paper's pseudocode.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Mapping, Sequence

from .graph import Graph
from .cost import Cluster, CostTable, Device, StageCost, stage_cost
from .partition import Piece


@dataclass
class StagePlan:
    """One pipeline stage: pieces [i..j] on ``devices``."""

    first_piece: int
    last_piece: int
    devices: list[Device]
    nodes: frozenset[str]
    cost: StageCost
    fractions: list[float] = field(default_factory=list)

    @property
    def n_devices(self) -> int:
        return len(self.devices)


@dataclass
class PipelinePlan:
    stages: list[StagePlan]
    period: float               # P(G, D, S)  (Eq. 12)
    latency: float              # T(G, D, S)
    wall_time_s: float = 0.0
    feasible: bool = True       # False: no config satisfied T_lim;
                                # the returned plan is the unconstrained
                                # optimum (best effort)

    @property
    def throughput(self) -> float:
        return 1.0 / self.period if self.period > 0 else float("inf")

    def __iter__(self):
        return iter(self.stages)


class PipelineDP:
    """Eq. 15 solver for a *homogeneous* cluster (use hetero.adjust after)."""

    def __init__(
        self,
        g: Graph,
        pieces: Sequence[Piece],
        cluster: Cluster,
        input_size: tuple[int, int],
        t_lim: float = float("inf"),
        cost_table: CostTable | None = None,
    ):
        self.g = g
        self.pieces = list(pieces)
        self.cluster = cluster
        self.input_size = input_size
        self.t_lim = t_lim
        self.cost_table = cost_table
        self.full = g.forward_sizes(input_size)
        self._stage_cache: dict[tuple[int, int, int], StageCost] = {}
        # memo[(i, j, p)] = (period, latency, split) where split is either
        # None (single stage) or (s, m)
        self.memo: dict[tuple[int, int, int], tuple[float, float, object]] = {}

    # -- Ts(i, j, m): one stage over pieces i..j with m devices ---------
    def stage(self, i: int, j: int, m: int) -> StageCost:
        key = (i, j, m)
        hit = self._stage_cache.get(key)
        if hit is None:
            nodes = frozenset().union(*(p.nodes for p in self.pieces[i:j + 1]))
            devs = self.cluster.devices[:m]
            hit = stage_cost(self.g, nodes, self.full, self.input_size,
                             devs, self.cluster, [1.0 / m] * m,
                             cost_table=self.cost_table)
            self._stage_cache[key] = hit
        return hit

    def solve(self, i: int, j: int, p: int) -> tuple[float, float]:
        """Returns (period, latency) for pieces i..j with p devices."""
        key = (i, j, p)
        if key in self.memo:
            per, lat, _ = self.memo[key]
            return per, lat
        # option A: a single stage with all p devices (feasible only if
        # its latency fits the budget; infinite period marks infeasible)
        sc = self.stage(i, j, p)
        if sc.total <= self.t_lim:
            best = (sc.total, sc.total, None)
        else:
            best = (float("inf"), sc.total, None)
        if p > 1 and j > i:
            for s in range(i, j):
                for m in range(1, p):
                    tail = self.stage(s + 1, j, m).total
                    if tail > best[0]:
                        # period = max(head, tail) >= tail: cannot improve
                        continue
                    head_p, head_l = self.solve(i, s, p - m)
                    lat = head_l + tail
                    if lat > self.t_lim:
                        continue
                    per = max(head_p, tail)
                    if per < best[0] or (per == best[0] and lat < best[1]):
                        best = (per, lat, (s, m))
        self.memo[key] = best
        return best[0], best[1]

    def build(self) -> PipelinePlan:
        t0 = time.perf_counter()
        L, D = len(self.pieces), len(self.cluster)
        per, lat = self.solve(0, L - 1, D)
        if per == float("inf"):
            # T_lim infeasible: fall back to the unconstrained optimum
            # and flag it (paper: the limit is a soft preference)
            fallback = PipelineDP(self.g, self.pieces, self.cluster,
                                  self.input_size,
                                  cost_table=self.cost_table).build()
            fallback.feasible = False
            fallback.wall_time_s += time.perf_counter() - t0
            return fallback
        stages: list[StagePlan] = []

        def walk(i: int, j: int, p: int):
            _, _, split = self.memo[(i, j, p)]
            if split is None:
                sc = self.stage(i, j, p)
                nodes = frozenset().union(*(x.nodes for x in self.pieces[i:j + 1]))
                stages.append(StagePlan(i, j, list(self.cluster.devices[:p]),
                                        nodes, sc, [1.0 / p] * p))
            else:
                s, m = split
                walk(i, s, p - m)
                sc = self.stage(s + 1, j, m)
                nodes = frozenset().union(*(x.nodes for x in self.pieces[s + 1:j + 1]))
                stages.append(StagePlan(s + 1, j, list(self.cluster.devices[:m]),
                                        nodes, sc, [1.0 / m] * m))

        walk(0, L - 1, D)
        # assign *distinct* device slices to stages (the DP only cares
        # about counts; Algorithm 3 re-maps real heterogeneous devices)
        off = 0
        for st in stages:
            st.devices = list(self.cluster.devices[off:off + st.n_devices])
            off += st.n_devices
        return PipelinePlan(stages, per, lat, time.perf_counter() - t0)


def plan_pipeline(
    g: Graph,
    pieces: Sequence[Piece],
    cluster: Cluster,
    input_size: tuple[int, int],
    t_lim: float = float("inf"),
    cost_table: CostTable | None = None,
) -> PipelinePlan:
    return PipelineDP(g, pieces, cluster, input_size, t_lim,
                      cost_table=cost_table).build()
