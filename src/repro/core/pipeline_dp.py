"""Algorithm 2 — many-to-many mapping of pieces x devices to pipeline stages.

DP of Eq. 15 over states (i, j, p): the optimal pipeline for pieces
i..j with p homogeneous devices is either a single stage, or an optimal
sub-pipeline over i..s with p-m devices followed by one stage s+1..j
replicated over m devices:

    P[i][j][p] = min_{i<=s<j} min_{1<=m<p} max(P[i][s][p-m], Ts[s+1][j][m])

Latency (sum of stage times) is tracked alongside and solutions whose
latency exceeds ``T_lim`` are pruned, matching the paper's pseudocode.

Two solvers share the class: the scalar top-down reference (`solve`)
and an incremental hot path used when a :class:`PlannerCache` is
attached.  Planning cost is dominated by segment *geometry*
(:func:`~repro.core.cost.segment_cost` graph walks per ``(i, j, m)``
state), which is device-independent — the cache persists it across
re-plans, so single-device churn only redoes cheap device-time
arithmetic, and a solved DP table is reused outright when the
homogenized cluster signature is unchanged.  Candidate stage costs
are evaluated batch-vectorized with numpy over all split ranges; the
elementwise operation order mirrors the scalar path exactly, so
incremental plans are bit-identical to from-scratch plans (pinned in
tests).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .graph import Graph
from .cost import (Cluster, CostTable, Device, StageCost, segment_cost,
                   stage_cost_from_segment)
from .partition import Piece


@dataclass
class StagePlan:
    """One pipeline stage: pieces [i..j] on ``devices``."""

    first_piece: int
    last_piece: int
    devices: list[Device]
    nodes: frozenset[str]
    cost: StageCost
    fractions: list[float] = field(default_factory=list)

    @property
    def n_devices(self) -> int:
        return len(self.devices)


@dataclass
class PipelinePlan:
    stages: list[StagePlan]
    period: float               # P(G, D, S)  (Eq. 12)
    latency: float              # T(G, D, S)
    wall_time_s: float = 0.0
    feasible: bool = True       # False: no config satisfied T_lim;
                                # the returned plan is the unconstrained
                                # optimum (best effort)

    @property
    def throughput(self) -> float:
        return 1.0 / self.period if self.period > 0 else float("inf")

    def __iter__(self):
        return iter(self.stages)


class PlannerCache:
    """Persistent planner state for one (graph, piece chain, input size).

    Owned by whoever re-plans repeatedly — a fleet registry entry, a
    serving tenant, a runtime's churn loop — and threaded into
    :class:`PipelineDP` (via ``plan_with_spec(planner_cache=)``).
    Three reuse tiers, cheapest first:

    * ``solutions`` — fully solved DP tables keyed by the homogenized
      cluster signature ``(L, D, capacity, alpha, bandwidth, t_lim,
      cost-table content)``; an exact signature match skips straight to
      plan reconstruction (zero ``solve(i, j, p)`` work);
    * ``segments`` — device-independent :class:`SegmentCost` geometry
      per ``(i, j, m)`` state (the graph walks that dominate planning);
      always valid across device churn, so a changed cluster only redoes
      arithmetic;
    * ``comm`` — the per-state communication-time scalar per bandwidth
      (kept scalar, summed in the same left-to-right order as
      :func:`~repro.core.cost.stage_cost_from_segment`, which is what
      keeps cached and from-scratch plans bit-identical).

    The cache self-invalidates when the chain signature changes
    (:meth:`ensure`), so holding one across a model/partition swap is
    safe, just useless.
    """

    def __init__(self):
        self.sig = None
        self.segments: dict[tuple[int, int, int], "SegmentCost"] = {}
        self.max_flops: dict[tuple[int, int, int], float] = {}
        self.mem: dict[tuple[int, int, int], float] = {}
        self.comm: dict[tuple, float] = {}
        self.nodes: dict[tuple[int, int], frozenset] = {}
        self.solutions: dict[tuple, tuple] = {}
        self.hits = 0
        self.misses = 0
        self.solution_hits = 0

    def __len__(self) -> int:
        return len(self.segments)

    def clear(self) -> None:
        self.segments.clear()
        self.max_flops.clear()
        self.mem.clear()
        self.comm.clear()
        self.nodes.clear()
        self.solutions.clear()

    def ensure(self, sig) -> "PlannerCache":
        """Validate the cache against a chain signature; a mismatch
        clears everything (a different graph/piece chain invalidates
        all geometry)."""
        if sig != self.sig:
            self.clear()
            self.sig = sig
        return self

    @staticmethod
    def chain_signature(g: Graph, pieces: Sequence[Piece],
                        input_size: tuple[int, int]) -> tuple:
        """Content signature of everything the geometry depends on."""
        layers = tuple(
            (s.name, s.kind, tuple(s.kernel), tuple(s.stride),
             tuple(s.padding), s.in_channels, s.out_channels,
             s.flops_coeff, s.global_rf, s.tile_independent_flops)
            for s in g.layers.values())
        chain = tuple(tuple(sorted(p.nodes)) for p in pieces)
        return (layers, tuple(g.edges), chain, tuple(input_size))


class PipelineDP:
    """Eq. 15 solver for a *homogeneous* cluster (use hetero.adjust after).

    With ``cache=`` (a :class:`PlannerCache`) the solver switches to the
    incremental hot path: segment geometry and communication scalars are
    reused across builds, candidate stage costs are evaluated
    numpy-vectorized over all split ranges, and an unchanged homogenized
    signature reuses the solved DP table outright.  Plans from the two
    paths are bit-identical (same arithmetic, same tie-breaking).

    ``objective`` (an :class:`~repro.api.specs.ObjectiveSpec`) makes the
    DP multi-objective-aware on both paths: a finite
    ``max_memory_bytes`` prunes stage candidates whose peak per-device
    footprint exceeds the budget (computed from the same cached segment
    geometry, so the vectorized path stays hot), and a positive
    ``latency`` weight replaces the lexicographic (period, latency)
    comparison with the weighted scalarization.  An objective that does
    not shape the DP (the pure-throughput default) is normalized to
    ``None``, keeping the legacy paths — and their bit-identity pins —
    untouched.
    """

    def __init__(
        self,
        g: Graph,
        pieces: Sequence[Piece],
        cluster: Cluster,
        input_size: tuple[int, int],
        t_lim: float = float("inf"),
        cost_table: CostTable | None = None,
        cache: PlannerCache | None = None,
        objective=None,
    ):
        self.g = g
        self.pieces = list(pieces)
        self.cluster = cluster
        self.input_size = input_size
        self.t_lim = t_lim
        self.cost_table = cost_table
        self.cache = cache
        self.objective = (objective if objective is not None
                          and objective.shapes_dp else None)
        if cache is not None:
            cache.ensure(PlannerCache.chain_signature(g, self.pieces,
                                                      input_size))
        self.full = g.forward_sizes(input_size)
        self._stage_cache: dict[tuple[int, int, int], StageCost] = {}
        # memo[(i, j, p)] = (period, latency, split) where split is either
        # None (single stage) or (s, m)
        self.memo: dict[tuple[int, int, int], tuple[float, float, object]] = {}

    # -- Ts(i, j, m): one stage over pieces i..j with m devices ---------
    def _nodes(self, i: int, j: int) -> frozenset:
        if self.cache is not None:
            nodes = self.cache.nodes.get((i, j))
            if nodes is None:
                nodes = frozenset().union(*(p.nodes
                                            for p in self.pieces[i:j + 1]))
                self.cache.nodes[(i, j)] = nodes
            return nodes
        return frozenset().union(*(p.nodes for p in self.pieces[i:j + 1]))

    def _segment(self, i: int, j: int, m: int):
        """Device-independent geometry of one stage state (cached)."""
        key = (i, j, m)
        if self.cache is not None:
            seg = self.cache.segments.get(key)
            if seg is not None:
                self.cache.hits += 1
                return seg
        seg = segment_cost(self.g, self._nodes(i, j), self.full,
                           self.input_size, [1.0 / m] * m)
        if self.cache is not None:
            self.cache.segments[key] = seg
            self.cache.misses += 1
        return seg

    def stage(self, i: int, j: int, m: int) -> StageCost:
        key = (i, j, m)
        hit = self._stage_cache.get(key)
        if hit is None:
            seg = self._segment(i, j, m)
            devs = self.cluster.devices[:m]
            ratio = (self.cost_table.ratio(seg.nodes)
                     if self.cost_table is not None else 1.0)
            hit = stage_cost_from_segment(seg, devs, self.cluster, ratio)
            self._stage_cache[key] = hit
        return hit

    def _stage_mem(self, i: int, j: int, m: int) -> float:
        """Peak per-device memory of one stage state: segment params +
        the largest halo-extended live-feature footprint.  Pure geometry
        (device-independent), so it persists in the PlannerCache."""
        key = (i, j, m)
        if self.cache is not None:
            v = self.cache.mem.get(key)
            if v is not None:
                return v
        seg = self._segment(i, j, m)
        v = seg.param_bytes + (max(seg.feature_bytes)
                               if seg.feature_bytes else 0.0)
        if self.cache is not None:
            self.cache.mem[key] = v
        return v

    def _mem_ok(self, i: int, j: int, m: int) -> bool:
        if self.objective is None:
            return True
        return self._stage_mem(i, j, m) <= self.objective.max_memory_bytes

    def _obj_key(self, per: float, lat: float) -> tuple:
        """Comparison key under the scalarized objective (ties broken
        exactly like the pure-throughput solver: period, then latency)."""
        o = self.objective
        return (o.throughput * per + o.latency * lat, per, lat)

    def solve(self, i: int, j: int, p: int) -> tuple[float, float]:
        """Returns (period, latency) for pieces i..j with p devices."""
        if self.objective is not None:
            return self._solve_obj(i, j, p)
        key = (i, j, p)
        if key in self.memo:
            per, lat, _ = self.memo[key]
            return per, lat
        # option A: a single stage with all p devices (feasible only if
        # its latency fits the budget; infinite period marks infeasible)
        sc = self.stage(i, j, p)
        if sc.total <= self.t_lim:
            best = (sc.total, sc.total, None)
        else:
            best = (float("inf"), sc.total, None)
        if p > 1 and j > i:
            for s in range(i, j):
                for m in range(1, p):
                    tail = self.stage(s + 1, j, m).total
                    if tail > best[0]:
                        # period = max(head, tail) >= tail: cannot improve
                        continue
                    head_p, head_l = self.solve(i, s, p - m)
                    lat = head_l + tail
                    if lat > self.t_lim:
                        continue
                    per = max(head_p, tail)
                    if per < best[0] or (per == best[0] and lat < best[1]):
                        best = (per, lat, (s, m))
        self.memo[key] = best
        return best[0], best[1]

    def _solve_obj(self, i: int, j: int, p: int) -> tuple[float, float]:
        """Objective-aware scalar solver: memory-pruned stage
        candidates, scalarized comparison.  Mirrors the vectorized
        path's selection order exactly (option A first, then earliest
        (s, m) in s-major/m-minor order)."""
        inf = float("inf")
        key = (i, j, p)
        if key in self.memo:
            per, lat, _ = self.memo[key]
            return per, lat
        sc = self.stage(i, j, p)
        if sc.total <= self.t_lim and self._mem_ok(i, j, p):
            best = (sc.total, sc.total, None)
        else:
            best = (inf, sc.total, None)
        best_key = (self._obj_key(*best[:2]) if best[0] < inf
                    else (inf, inf, inf))
        if p > 1 and j > i:
            for s in range(i, j):
                for m in range(1, p):
                    if not self._mem_ok(s + 1, j, m):
                        continue
                    tail = self.stage(s + 1, j, m).total
                    head_p, head_l = self._solve_obj(i, s, p - m)
                    lat = head_l + tail
                    if lat > self.t_lim:
                        continue
                    per = max(head_p, tail)
                    if per == inf:       # infeasible head: not a candidate
                        continue
                    cand_key = self._obj_key(per, lat)
                    if cand_key < best_key:
                        best = (per, lat, (s, m))
                        best_key = cand_key
        self.memo[key] = best
        return best[0], best[1]

    def build(self) -> PipelinePlan:
        if self.cache is not None:
            usig = self._uniform_sig()
            if usig is not None:
                return self._build_fast(usig)
        return self._build_scalar()

    def _build_scalar(self) -> PipelinePlan:
        t0 = time.perf_counter()
        L, D = len(self.pieces), len(self.cluster)
        per, lat = self.solve(0, L - 1, D)
        if per == float("inf"):
            # T_lim infeasible: fall back to the unconstrained optimum
            # and flag it (paper: the limit is a soft preference)
            fallback = PipelineDP(self.g, self.pieces, self.cluster,
                                  self.input_size,
                                  cost_table=self.cost_table,
                                  cache=self.cache,
                                  objective=(self.objective.relaxed()
                                             if self.objective is not None
                                             else None)).build()
            fallback.feasible = False
            fallback.wall_time_s += time.perf_counter() - t0
            return fallback
        stages: list[StagePlan] = []

        def walk(i: int, j: int, p: int):
            _, _, split = self.memo[(i, j, p)]
            if split is None:
                sc = self.stage(i, j, p)
                nodes = frozenset().union(*(x.nodes for x in self.pieces[i:j + 1]))
                stages.append(StagePlan(i, j, list(self.cluster.devices[:p]),
                                        nodes, sc, [1.0 / p] * p))
            else:
                s, m = split
                walk(i, s, p - m)
                sc = self.stage(s + 1, j, m)
                nodes = frozenset().union(*(x.nodes for x in self.pieces[s + 1:j + 1]))
                stages.append(StagePlan(s + 1, j, list(self.cluster.devices[:m]),
                                        nodes, sc, [1.0 / m] * m))

        walk(0, L - 1, D)
        # assign *distinct* device slices to stages (the DP only cares
        # about counts; Algorithm 3 re-maps real heterogeneous devices)
        off = 0
        for st in stages:
            st.devices = list(self.cluster.devices[off:off + st.n_devices])
            off += st.n_devices
        return PipelinePlan(stages, per, lat, time.perf_counter() - t0)

    # -- incremental / vectorized hot path ------------------------------
    def _uniform_sig(self) -> tuple | None:
        """(capacity, alpha, bandwidth) when all devices are
        indistinguishable and the link is flat — the invariant the
        vectorized solver exploits (always true for ``homogenized()``
        clusters, i.e. the Algorithm 2 input).  ``None`` otherwise."""
        if self.cluster.pair_bandwidth:
            return None
        d0 = self.cluster.devices[0]
        for d in self.cluster.devices[1:]:
            if d.capacity != d0.capacity or d.alpha != d0.alpha:
                return None
        return (d0.capacity, d0.alpha, self.cluster.bandwidth)

    def _ratio_sig(self):
        ct = self.cost_table
        if ct is None:
            return None
        return (ct.default, tuple(sorted((tuple(sorted(k)), v)
                                         for k, v in ct.ratios.items())))

    def _max_flops(self, a: int, j: int, m: int) -> float:
        key = (a, j, m)
        v = self.cache.max_flops.get(key)
        if v is None:
            v = max(self._segment(a, j, m).per_device_flops)
            self.cache.max_flops[key] = v
        return v

    def _comm_scalar(self, a: int, j: int, m: int, bw: float) -> float:
        # left-to-right scalar sum, exactly as stage_cost_from_segment,
        # so the cached value is bit-identical to the fresh one (numpy
        # pairwise reduction would not be)
        key = (a, j, m, bw)
        v = self.cache.comm.get(key)
        if v is None:
            seg = self._segment(a, j, m)
            v = 0.0
            for k in range(1, m):
                v = v + (seg.in_bytes[k] + seg.out_bytes[k]) / bw
            self.cache.comm[key] = v
        return v

    def _solve_fast(self, L: int, D: int, cap: float, alpha: float,
                    bw: float) -> tuple:
        """Bottom-up vectorized Eq. 15.  Only ``i == 0`` head states are
        reachable from ``solve(0, L-1, D)``, so the table is 2-D over
        (j, p); tails Ts(s+1, j, m) are priced in batch from cached
        segment geometry.  Tie-breaking replicates the scalar solver:
        lexicographic (period, latency), single-stage option first, then
        earliest (s, m) in s-major/m-minor order.  Under an objective,
        memory-violating stage states are masked to inf (so both option
        A and tails drop out through the ordinary feasibility machinery)
        and the selection key becomes the weighted scalarization with
        the same (period, latency, first-index) tie-breaking."""
        inf = float("inf")
        obj = self.objective
        mem_lim = (obj.max_memory_bytes
                   if obj is not None and np.isfinite(obj.max_memory_bytes)
                   else None)
        # TT[a, j, m] = stage total for pieces a..j on m devices.
        # a == 0 serves option A (m up to D); a >= 1 serves tails (m < D).
        TT = np.full((L, L, D + 1), inf)
        for j in range(L):
            for a in range(j + 1):
                mmax = D if a == 0 else D - 1
                if mmax < 1:
                    continue
                ratio = (self.cost_table.ratio(self._nodes(a, j))
                         if self.cost_table is not None else 1.0)
                max_f = np.array([self._max_flops(a, j, m)
                                  for m in range(1, mmax + 1)])
                comm = np.array([self._comm_scalar(a, j, m, bw)
                                 for m in range(1, mmax + 1)])
                # elementwise ops in the same order as Device.t_comp()*ratio
                # (max over identical devices commutes with the positive
                # scaling, so max_flops stands in for max(per-device comp))
                TT[a, j, 1:mmax + 1] = ((alpha * max_f) / cap) * ratio + comm
                if mem_lim is not None:
                    for m in range(1, mmax + 1):
                        if self._stage_mem(a, j, m) > mem_lim:
                            TT[a, j, m] = inf

        t_lim = self.t_lim
        P = np.full((L, D + 1), inf)
        Lat = np.full((L, D + 1), inf)
        S = np.full((L, D + 1), -1, dtype=np.int64)
        M = np.zeros((L, D + 1), dtype=np.int64)
        for p in range(1, D + 1):
            for j in range(L):
                # option A: single stage over all p devices
                per_a = TT[0, j, p]
                if per_a <= t_lim:
                    best_per, best_lat = per_a, per_a
                else:
                    best_per, best_lat = inf, per_a
                bs, bm = -1, 0
                if p > 1 and j > 0:
                    # candidate grid: rows s in [0, j), cols c -> m = c+1
                    heads_per = P[0:j, 1:p][:, ::-1]     # P[s, p-m]
                    heads_lat = Lat[0:j, 1:p][:, ::-1]
                    tails = TT[1:j + 1, j, 1:p]          # Ts(s+1, j, m)
                    cand_per = np.maximum(heads_per, tails)
                    cand_lat = heads_lat + tails
                    valid = cand_lat <= t_lim
                    if valid.any() and obj is None:
                        per_m = np.where(valid, cand_per, inf)
                        lat_m = np.where(valid, cand_lat, inf)
                        min_per = per_m.min()
                        min_lat = np.where(per_m == min_per, lat_m, inf).min()
                        if (min_per < best_per
                                or (min_per == best_per
                                    and min_lat < best_lat)):
                            first = int(np.argmax((per_m == min_per)
                                                  & (lat_m == min_lat)))
                            s_idx, c_idx = divmod(first, p - 1)
                            best_per, best_lat = min_per, min_lat
                            bs, bm = s_idx, c_idx + 1
                    elif valid.any():
                        # scalarized selection: min weighted score, ties
                        # broken per -> lat -> first (s, m) index, exactly
                        # like _solve_obj.  Infeasible candidates carry
                        # inf (a zero weight would turn 0*inf into NaN,
                        # and inf <= t_lim holds for an unbounded t_lim),
                        # so mask them out of the score entirely.
                        w_t, w_l = obj.throughput, obj.latency
                        valid &= np.isfinite(cand_per)
                        per_m = np.where(valid, cand_per, inf)
                        lat_m = np.where(valid, cand_lat, inf)
                        score_m = np.where(
                            valid,
                            w_t * np.where(valid, cand_per, 0.0)
                            + w_l * np.where(valid, cand_lat, 0.0),
                            inf)
                        min_score = score_m.min()
                        if min_score < inf:
                            sel = score_m == min_score
                            min_per = np.where(sel, per_m, inf).min()
                            sel &= per_m == min_per
                            min_lat = np.where(sel, lat_m, inf).min()
                            sel &= lat_m == min_lat
                            if best_per < inf:
                                best_key = (w_t * best_per + w_l * best_lat,
                                            best_per, best_lat)
                            else:
                                best_key = (inf, inf, inf)
                            if (min_score, min_per, min_lat) < best_key:
                                first = int(np.argmax(sel))
                                s_idx, c_idx = divmod(first, p - 1)
                                best_per, best_lat = min_per, min_lat
                                bs, bm = s_idx, c_idx + 1
                P[j, p] = best_per
                Lat[j, p] = best_lat
                S[j, p] = bs
                M[j, p] = bm
        return P, Lat, S, M

    def _build_fast(self, usig: tuple) -> PipelinePlan:
        t0 = time.perf_counter()
        L, D = len(self.pieces), len(self.cluster)
        cap, alpha, bw = usig
        key = (L, D, cap, alpha, bw, self.t_lim, self._ratio_sig(),
               None if self.objective is None
               else self.objective.dp_signature())
        sol = self.cache.solutions.get(key)
        if sol is None:
            sol = self._solve_fast(L, D, cap, alpha, bw)
            self.cache.solutions[key] = sol
        else:
            self.cache.solution_hits += 1
        P, Lat, S, M = sol
        per, lat = float(P[L - 1, D]), float(Lat[L - 1, D])
        if per == float("inf"):
            fallback = PipelineDP(self.g, self.pieces, self.cluster,
                                  self.input_size,
                                  cost_table=self.cost_table,
                                  cache=self.cache,
                                  objective=(self.objective.relaxed()
                                             if self.objective is not None
                                             else None)).build()
            fallback.feasible = False
            fallback.wall_time_s += time.perf_counter() - t0
            return fallback
        stages: list[StagePlan] = []

        def walk(j: int, p: int):
            s, m = int(S[j, p]), int(M[j, p])
            if s < 0:
                sc = self.stage(0, j, p)
                stages.append(StagePlan(0, j, list(self.cluster.devices[:p]),
                                        sc.seg.nodes, sc, [1.0 / p] * p))
            else:
                walk(s, p - m)
                sc = self.stage(s + 1, j, m)
                stages.append(StagePlan(s + 1, j,
                                        list(self.cluster.devices[:m]),
                                        sc.seg.nodes, sc, [1.0 / m] * m))

        walk(L - 1, D)
        off = 0
        for st in stages:
            st.devices = list(self.cluster.devices[off:off + st.n_devices])
            off += st.n_devices
        return PipelinePlan(stages, per, lat, time.perf_counter() - t0)


def plan_pipeline(
    g: Graph,
    pieces: Sequence[Piece],
    cluster: Cluster,
    input_size: tuple[int, int],
    t_lim: float = float("inf"),
    cost_table: CostTable | None = None,
    cache: PlannerCache | None = None,
    objective=None,
) -> PipelinePlan:
    return PipelineDP(g, pieces, cluster, input_size, t_lim,
                      cost_table=cost_table, cache=cache,
                      objective=objective).build()
