"""Synthetic data pipeline: token streams, image frames, request loads."""

from .pipeline import (TokenStream, ImageStream, RequestStream,
                       synthetic_token_batch)

__all__ = ["TokenStream", "ImageStream", "RequestStream",
           "synthetic_token_batch"]
