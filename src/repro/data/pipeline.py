"""Synthetic data pipeline.

Deterministic, seeded generators for: LM token batches (Zipf-ish
unigram + Markov bigram structure so loss can actually go down), image
frame streams for the CNN serving path, and a Poisson request stream
for the pipelined server.  Everything is host-side numpy, double
buffered into device arrays by the training loop.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterator

import numpy as np
import jax.numpy as jnp


def synthetic_token_batch(rng: np.random.Generator, batch: int, seq: int,
                          vocab: int, n_patterns: int = 64):
    """Tokens with learnable bigram structure: each sampled pattern id
    deterministically maps token t -> (a*t + b) % vocab for a stretch."""
    toks = np.empty((batch, seq + 1), np.int32)
    for i in range(batch):
        pat = rng.integers(0, n_patterns)
        a = 3 + 2 * (pat % 13)
        b = 1 + (pat // 13)
        start = rng.integers(0, vocab)
        seqv = np.empty(seq + 1, np.int64)
        seqv[0] = start
        for t in range(1, seq + 1):
            seqv[t] = (a * seqv[t - 1] + b) % vocab
        toks[i] = seqv.astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


@dataclass
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        while True:
            yield synthetic_token_batch(rng, self.batch, self.seq,
                                        self.vocab)

    def batches(self, n: int):
        it = iter(self)
        return [next(it) for _ in range(n)]


@dataclass
class ImageStream:
    """Frame source for CNN pipeline serving (the paper's camera)."""

    width: int
    height: int
    channels: int = 3
    seed: int = 0

    def frames(self, n: int, batch: int = 1):
        rng = np.random.default_rng(self.seed)
        return [jnp.asarray(rng.standard_normal(
            (batch, self.height, self.width, self.channels), np.float32))
            for _ in range(n)]


@dataclass
class Request:
    rid: int
    arrival: float
    payload: object


@dataclass
class RequestStream:
    """Poisson arrivals for the batched serving driver."""

    rate_per_s: float
    seed: int = 0

    def generate(self, n: int, make_payload) -> list[Request]:
        rng = np.random.default_rng(self.seed)
        t = 0.0
        out = []
        for i in range(n):
            t += rng.exponential(1.0 / self.rate_per_s)
            out.append(Request(i, t, make_payload(rng, i)))
        return out
