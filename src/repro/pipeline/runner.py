"""Pipeline runner: execute a full PICO plan over a stream of frames.

Two execution modes:

* :class:`PipelineRunner` — functional mode: stages run in plan order for
  each frame (single host, bit-exact; used by tests/examples and to
  validate plans produced by the optimizer).
* :func:`microbatch_pipeline` — GPipe-style pipelined execution with
  ``shard_map`` + ``lax.ppermute`` over a dedicated mesh axis: the form
  PICO takes on a real TPU mesh, where each stage lives on its own
  slice of the ``stage`` (or ``pod``) axis and microbatches stream
  through (DESIGN.md §5).  Works on any mesh whose ``stage`` axis size
  equals the number of pipeline stages.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map

from ..core.pipeline_dp import PipelinePlan
from .stage import StageExecutor, executors_from_plan


@dataclass
class PipelineRunner:
    model: "CNNDef"                  # noqa: F821 (models.cnn.builder)
    plan: PipelinePlan
    backend: str | None = None       # conv lowering; None -> model default
    mode: str = "compiled"           # "compiled" | "eager" stage execution
    exec_spec: object = None         # ExecSpec; supersedes backend/mode

    def __post_init__(self):
        if self.exec_spec is not None:
            # donate is deliberately NOT taken from the spec: stages here
            # share `produced` boundary tensors across the whole plan, so
            # donation would let XLA clobber buffers later stages read
            self.backend = self.exec_spec.backend
            self.mode = self.exec_spec.mode
        self.stages = executors_from_plan(self.model, self.plan.stages,
                                          backend=self.backend,
                                          mode=self.mode)

    def __call__(self, params, image: jax.Array) -> dict[str, jax.Array]:
        produced: dict[str, jax.Array] = {}
        for ex in self.stages:
            outs = ex(params, produced, image)
            produced.update(outs)
        sinks = self.model.graph.sinks()
        return {s: produced[s] for s in sinks}

    def run_stream(self, params, frames: Sequence[jax.Array]
                   ) -> list[dict[str, jax.Array]]:
        return [self(params, f) for f in frames]

    def run_frames(self, params, frames: jax.Array) -> dict[str, jax.Array]:
        """Micro-batched stream: ``frames`` is a (F, N, H, W, C) stack;
        each stage scans over the frame axis in one compiled dispatch
        (``lax.scan``), so the Python overhead is per *stage*, not per
        frame x stage x tile.  Returns sinks stacked along F."""
        produced: dict[str, jax.Array] = {}
        for ex in self.stages:
            outs = ex.run_frames(params, produced, frames)
            produced.update(outs)
        sinks = self.model.graph.sinks()
        return {s: produced[s] for s in sinks}


# ---------------------------------------------------------------------------
# GPipe-style microbatch pipeline over a mesh axis
# ---------------------------------------------------------------------------

def microbatch_pipeline(
    stage_fn: Callable[[int, jax.Array, jax.Array], jax.Array],
    stage_params: jax.Array,
    x_microbatches: jax.Array,
    mesh: Mesh,
    axis: str = "stage",
):
    """Run ``n_stages`` chained functions as a pipeline over mesh ``axis``.

    ``stage_fn(stage_id, params_slice, x)`` applies one stage to one
    microbatch; all stages must share the activation shape (pad the
    channel/feature dim to the max if needed).  ``stage_params`` is
    stacked along axis 0 (one slice per stage) and sharded over ``axis``;
    ``x_microbatches`` has shape (n_micro, ...) and is replicated.

    Classic GPipe schedule with n_stages + n_micro - 1 ticks; the
    inter-stage hand-off is a single ``lax.ppermute`` per tick — on a
    multi-pod mesh this is the only cross-pod communication, which is
    exactly PICO's thesis (stage boundaries are the narrow waist).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_microbatches.shape[0]

    def per_stage(params_sl, xs):
        # params_sl: (1, ...) slice of stacked params; xs: (n_micro, ...)
        sid = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params_sl)
        n_ticks = n_stages + n_micro - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any)
            feed = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            cur = jnp.where(sid == 0, feed, buf)
            y = stage_fn(sid, p, cur)
            # shift y to the next stage; last stage's y is the output of
            # microbatch (t - n_stages + 1)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            nxt = jax.lax.ppermute(y, axis, perm)
            out_idx = t - (n_stages - 1)
            valid = jnp.logical_and(out_idx >= 0, out_idx < n_micro)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, n_micro - 1), axis=0),
                lambda o: o,
                outs)
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # only the LAST stage's `outs` holds the final results; broadcast
        # via a masked psum so every shard returns the same value.
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    spec_p = P(axis)
    spec_x = P()
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(spec_p, spec_x), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, x_microbatches)
