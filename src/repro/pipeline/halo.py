"""Overlap-aware feature split / stitch (paper §5.3 'Feature split and stitch').

Given a stage's fused segment and the per-device output fractions, this
module computes the exact per-device sink ranges and the halo-extended
source input ranges, and provides the split/stitch array ops.  Splitting
is positional (width axis), so stitching is a plain concatenation — the
tiles never overlap on the *output* side.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from ..core.graph import Graph, proportional_widths


@dataclass
class TilePlan:
    """Exact ranges for one device's tile of a fused segment."""

    device_index: int
    sink_ranges: dict[str, tuple[int, int]]   # output range per sink
    out_ranges: dict[str, tuple[int, int]]    # req_out per node
    in_ranges: dict[str, tuple[int, int]]     # req_in per node

    @property
    def empty(self) -> bool:
        return all(a >= b for a, b in self.sink_ranges.values())

    def signature(self) -> tuple:
        """Hashable form of the exact ranges (executable-cache key part)."""
        return (self.device_index,
                tuple(sorted(self.sink_ranges.items())),
                tuple(sorted(self.out_ranges.items())),
                tuple(sorted(self.in_ranges.items())))


def tile_signature(plans: Sequence["TilePlan"]) -> tuple:
    """Hashable fingerprint of a whole stage's tiling."""
    return tuple(tp.signature() for tp in plans)


def plan_tiles(
    g: Graph,
    nodes: frozenset[str] | set[str],
    full_sizes: Mapping[str, tuple[int, int]],
    input_size: tuple[int, int],
    fractions: Sequence[float],
) -> list[TilePlan]:
    """Partition every sink's output width proportionally to ``fractions``
    and back-propagate exact ranges for each device."""
    nodes = frozenset(nodes)
    sinks = g.sinks(nodes)
    m = len(fractions)
    widths = {s: proportional_widths(full_sizes[s][0], fractions) if m > 1
              else [full_sizes[s][0]] for s in sinks}
    plans: list[TilePlan] = []
    for k in range(m):
        sink_ranges = {}
        for s in sinks:
            a = sum(widths[s][:k])
            sink_ranges[s] = (a, a + widths[s][k])
        if all(a >= b for a, b in sink_ranges.values()):
            plans.append(TilePlan(k, sink_ranges, {}, {}))
            continue
        req_out, req_in = g.required_ranges(nodes, sink_ranges,
                                            full_sizes, input_size)
        plans.append(TilePlan(k, sink_ranges, req_out, req_in))
    return plans


def split_inputs(
    plans: Sequence[TilePlan],
    needs: Sequence[tuple[str, str | None]],
    boundary: Mapping[tuple[str, str | None], jax.Array],
) -> list[dict[tuple[str, str | None], jax.Array]]:
    """Slice each boundary tensor into per-device halo tiles.

    ``needs`` lists (node, outside_pred) pairs (see
    ``CNNDef.boundary_needs``); ``boundary[(n, p)]`` must cover the full
    width of predecessor p's output (NHWC).  The slice for node n is its
    req_in range.
    """
    out: list[dict[tuple[str, str | None], jax.Array]] = []
    for tp in plans:
        if tp.empty:
            out.append({})
            continue
        tiles = {}
        for (n, p) in needs:
            a, b = tp.in_ranges[n]
            tiles[(n, p)] = boundary[(n, p)][:, :, a:b, :]
        out.append(tiles)
    return out


def stitch_outputs(
    plans: Sequence[TilePlan],
    sinks: Sequence[str],
    tiles: Sequence[Mapping[str, jax.Array]],
) -> dict[str, jax.Array]:
    """Concatenate per-device sink tiles back to full tensors.

    Each device's returned tile covers req_out[sink]; the stitcher crops
    it down to the device's *assigned* sink range before concatenating,
    so overlapping halo is discarded exactly once.
    """
    out: dict[str, jax.Array] = {}
    for s in sinks:
        parts = []
        for tp, t in zip(plans, tiles):
            if tp.empty or s not in t:
                continue
            a, b = tp.sink_ranges[s]
            if a >= b:
                continue
            ra, _ = tp.out_ranges[s]
            x = t[s]
            parts.append(x[:, :, a - ra: b - ra, :])
        out[s] = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=2)
    return out
