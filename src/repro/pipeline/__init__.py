"""Executable pipeline runtime: halo split/stitch, stage executor, runner."""

from .halo import (TilePlan, plan_tiles, split_inputs, stitch_outputs,
                   tile_signature)
from .stage import StageExecutor, executors_from_plan
from .runner import PipelineRunner, microbatch_pipeline

__all__ = ["TilePlan", "plan_tiles", "split_inputs", "stitch_outputs",
           "tile_signature", "StageExecutor", "executors_from_plan",
           "PipelineRunner", "microbatch_pipeline"]
