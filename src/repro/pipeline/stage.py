"""Stage executor: run one pipeline stage's fused segment on device tiles.

The default executor iterates tiles sequentially (single-host testing —
bit-exact with the monolithic forward).  ``jit_stage`` builds a jitted
callable per stage for the serving runtime.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from ..core.graph import Graph
from ..core.pipeline_dp import StagePlan
from ..models.cnn.builder import CNNDef
from .halo import TilePlan, plan_tiles, split_inputs, stitch_outputs


@dataclass
class StageExecutor:
    """Executable form of one StagePlan for a CNNDef."""

    model: CNNDef
    nodes: frozenset[str]
    fractions: list[float]
    name: str = "stage"

    def __post_init__(self):
        g = self.model.graph
        self.sinks = g.sinks(self.nodes)
        self.plans: list[TilePlan] = plan_tiles(
            g, self.nodes, self.model.full_sizes, self.model.input_size,
            self.fractions)
        # (node, outside_pred) pairs fed across the stage boundary
        self.needs = self.model.boundary_needs(self.nodes)

    def boundary_inputs(self, produced: Mapping[str, jax.Array],
                        image: jax.Array | None
                        ) -> dict[tuple[str, str | None], jax.Array]:
        """Full-width boundary tensors for every (node, pred) need."""
        return {(n, p): (image if p is None else produced[p])
                for (n, p) in self.needs}

    def __call__(self, params, produced: Mapping[str, jax.Array],
                 image: jax.Array | None = None) -> dict[str, jax.Array]:
        boundary = self.boundary_inputs(produced, image)
        tiles_in = split_inputs(self.plans, self.needs, boundary)
        tiles_out = []
        for tp, tin in zip(self.plans, tiles_in):
            if tp.empty:
                tiles_out.append({})
                continue
            res = self.model.run_segment(params, self.nodes, tin,
                                         ranges=(tp.out_ranges, tp.in_ranges))
            tiles_out.append(res)
        return stitch_outputs(self.plans, self.sinks, tiles_out)


def executors_from_plan(model: CNNDef, stages: Sequence[StagePlan]
                        ) -> list[StageExecutor]:
    return [StageExecutor(model, st.nodes, list(st.fractions),
                          name=f"stage{si}")
            for si, st in enumerate(stages)]
