"""Stage executor: run one pipeline stage's fused segment on device tiles.

The default mode compiles the whole stage — all device tiles — into a
single jitted executable through :mod:`repro.exec` (fetched from the
executable cache, so identical stages across re-plans share one
lowering).  ``mode="eager"`` keeps the seed's per-tile Python loop as
the bit-exactness oracle and for one-shot runs where compilation would
not amortize.  ``run_frames`` micro-batches a stack of frames through
``lax.scan`` in one dispatch.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from ..core.pipeline_dp import StagePlan
from .halo import TilePlan, plan_tiles, split_inputs, stitch_outputs


@dataclass
class StageExecutor:
    """Executable form of one StagePlan for a CNNDef."""

    model: "CNNDef"                  # noqa: F821 (models.cnn.builder)
    nodes: frozenset[str]
    fractions: list[float]
    name: str = "stage"
    backend: str | None = None       # None -> model.backend -> registry default
    mode: str = "compiled"           # "compiled" | "eager"
    donate: bool = False             # donate boundary buffers to XLA — only
    #                                  safe when the caller won't reuse them
    profile: bool = False            # jax.profiler annotation per call
    fuse: bool = True                # lower conv->pool chains as one fused
    #                                  kernel call (compiled mode, backends
    #                                  with a fused lowering only)

    def __post_init__(self):
        g = self.model.graph
        self.nodes = frozenset(self.nodes)
        self.sinks = g.sinks(self.nodes)
        self.plans: list[TilePlan] = plan_tiles(
            g, self.nodes, self.model.full_sizes, self.model.input_size,
            self.fractions)
        # (node, outside_pred) pairs fed across the stage boundary
        self.needs = self.model.boundary_needs(self.nodes)
        if self.backend is None:
            from ..exec import backends as _backends
            self.backend = self.model.backend or _backends.DEFAULT_BACKEND
        if self.mode not in ("compiled", "eager"):
            raise ValueError(f"unknown mode {self.mode!r}")
        # per-call-invariant part of the executable-cache key, computed
        # once so the per-frame lookup only hashes boundary shapes
        from ..exec.cache import static_stage_key
        self._static_key = static_stage_key(self.model, self.nodes,
                                            self.plans, self.needs)

    def boundary_inputs(self, produced: Mapping[str, jax.Array],
                        image: jax.Array | None
                        ) -> dict[tuple[str, str | None], jax.Array]:
        """Full-width boundary tensors for every (node, pred) need."""
        return {(n, p): (image if p is None else produced[p])
                for (n, p) in self.needs}

    def __call__(self, params, produced: Mapping[str, jax.Array],
                 image: jax.Array | None = None) -> dict[str, jax.Array]:
        boundary = self.boundary_inputs(produced, image)
        with self._profiler_bracket():
            if self.mode == "eager":
                return self._run_eager(params, boundary)
            return self._executable(boundary)(params, boundary)

    def run_frames(self, params, produced: Mapping[str, jax.Array],
                   images: jax.Array | None = None) -> dict[str, jax.Array]:
        """Frame-stack form of ``__call__``: every boundary tensor (and
        ``images``) carries a leading frame axis; sinks come back stacked
        the same way.  Compiled mode scans the stack in one dispatch;
        eager mode loops frames through the oracle path and stacks."""
        boundary = self.boundary_inputs(produced, images)
        with self._profiler_bracket():
            if self.mode == "eager":
                n = next(iter(boundary.values())).shape[0]
                per = [self._run_eager(params, {k: v[f] for k, v in
                                                boundary.items()})
                       for f in range(n)]
                return {s: jnp.stack([o[s] for o in per])
                        for s in self.sinks}
            return self._executable(boundary).run_frames(params, boundary)

    # ------------------------------------------------------------------

    def _profiler_bracket(self):
        """Opt-in ``jax.profiler`` named bracket (ExecSpec.profile) so
        per-stage work shows up labelled in XLA device profiles; the
        no-profile path costs one method call."""
        if not self.profile:
            from contextlib import nullcontext
            return nullcontext()
        return jax.profiler.TraceAnnotation(self.name)

    def _executable(self, boundary):
        from ..exec.cache import compiled_stage
        return compiled_stage(self.model, self.nodes, self.plans,
                              self.needs, self.sinks, backend=self.backend,
                              relu=True, donate=self.donate,
                              boundary=boundary, static_key=self._static_key,
                              fuse=self.fuse)

    def _run_eager(self, params, boundary) -> dict[str, jax.Array]:
        """The seed path: eager Python loop over device tiles."""
        tiles_in = split_inputs(self.plans, self.needs, boundary)
        tiles_out = []
        for tp, tin in zip(self.plans, tiles_in):
            if tp.empty:
                tiles_out.append({})
                continue
            res = self.model.run_segment(params, self.nodes, tin,
                                         ranges=(tp.out_ranges, tp.in_ranges),
                                         backend=self.backend)
            tiles_out.append(res)
        return stitch_outputs(self.plans, self.sinks, tiles_out)


def executors_from_plan(model: "CNNDef", stages: Sequence[StagePlan],  # noqa: F821
                        backend: str | None = None, mode: str = "compiled",
                        donate: bool = False,
                        spec=None) -> list[StageExecutor]:
    """Build one executor per stage.  ``spec``
    (:class:`~repro.api.specs.ExecSpec`) supersedes the individual
    ``backend``/``mode`` knobs when given — but never ``donate``:
    stages of one plan share boundary tensors, so donation here would
    let XLA clobber buffers a later stage still reads (single-stage
    callers opt in via the explicit ``donate=`` argument)."""
    profile = False
    fuse = True
    if spec is not None:
        backend, mode = spec.backend, spec.mode
        profile = getattr(spec, "profile", False)
        fuse = getattr(spec, "fuse", True)
    return [StageExecutor(model, st.nodes, list(st.fractions),
                          name=f"stage{si}", backend=backend, mode=mode,
                          donate=donate, profile=profile, fuse=fuse)
            for si, st in enumerate(stages)]
