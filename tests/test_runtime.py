"""Event-driven runtime: simulator agreement, churn, re-planning.

The tolerance contract: under the ideal config (no jitter, no noise,
free hand-off) the event executor implements exactly the pipeline
recurrence of Eq. 12, so measured period/latency/utilization must match
``core.simulate`` — the tests assert the acceptance bar of 10% but the
expected error is ~0.
"""

import jax
import numpy as np
import pytest

from repro.core import Cluster, Device, make_pi_cluster, plan
from repro.models.cnn import zoo
from repro.runtime import (DeviceJoin, DeviceLeave, FreqScale, LinkDegrade,
                           PipelineRuntime, RuntimeConfig, validate)

CLUSTERS = {
    "homo4": make_pi_cluster([1.0] * 4),
    "hetero4": make_pi_cluster([1.5, 1.2, 1.0, 0.8]),
}

ZOO3 = [
    ("squeezenet", dict(input_size=(96, 96), scale=0.1)),
    ("mobilenetv3", dict(input_size=(96, 96), scale=0.25)),
    ("resnet34", dict(input_size=(96, 96), scale=0.1)),
]


@pytest.mark.parametrize("name,kw", ZOO3)
@pytest.mark.parametrize("cname", list(CLUSTERS))
def test_runtime_matches_simulator(name, kw, cname):
    m = zoo.build(name, **kw)
    cluster = CLUSTERS[cname]
    rep = validate(m.graph, cluster, m.input_size, frames=32, tol=0.10)
    assert rep.ok, str(rep)
    # ideal config should in fact be near-exact, not just within 10%
    assert rep.period_rel_err < 1e-6
    assert rep.latency_rel_err < 1e-6
    assert rep.utilization_abs_err < 1e-6


def _small_model():
    return zoo.squeezenet(input_size=(96, 96), scale=0.1)


def test_device_drop_replans_and_recovers():
    m = _small_model()
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    pico = plan(m.graph, cluster, m.input_size)
    drop = max(cluster.devices, key=lambda d: d.capacity)
    drop_t = pico.period * 20
    rt = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                         churn=[DeviceLeave(drop_t, drop.name)])
    rep = rt.run(120)
    assert rep.completed == 120
    assert len(rep.replans) >= 1
    assert rep.replans[0].reason == "leave"
    assert rep.replans[0].n_devices == 3
    # post-churn throughput recovers >= 80% of a fresh 3-device plan
    mig_end = rep.replans[-1].time + rep.replans[-1].migration_s
    post = rep.windowed_throughput(mig_end, rep.makespan)
    survivors = Cluster([d for d in cluster.devices if d.name != drop.name],
                        bandwidth=cluster.bandwidth)
    ref = plan(m.graph, survivors, m.input_size)
    assert post >= 0.8 / ref.period
    # ... and >= 80% of the pre-churn throughput (acceptance criterion),
    # despite losing the fastest third of the cluster's capacity
    pre = rep.windowed_throughput(0.0, drop_t)
    assert post >= 0.8 * pre
    # the dead device did no work after the drop
    dead = next(d for d in rep.devices if d.device == drop.name)
    live_frames = max(d.frames for d in rep.devices)
    assert dead.frames < live_frames


def test_freq_scale_drift_detected_and_calibrated():
    m = _small_model()
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    pico = plan(m.graph, cluster, m.input_size)
    victim = pico.pipeline.stages[0].devices[0].name
    rt = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                         churn=[FreqScale(pico.period * 10, victim, 0.5)])
    rep = rt.run(120)
    assert rep.completed == 120
    assert any(r.reason == "drift" for r in rep.replans)
    # the monitor measured the 2x slowdown (EWMA converges toward 2.0)
    assert rt.monitor.device_ratio(victim) > 1.5
    calibrated = rt.monitor.calibrated_cluster(cluster)
    cal = next(d for d in calibrated.devices if d.name == victim)
    orig = next(d for d in cluster.devices if d.name == victim)
    assert cal.alpha > 1.5 * orig.alpha


def test_link_degradation_slows_pipeline():
    m = _small_model()
    cluster = make_pi_cluster([1.0] * 4)
    pico = plan(m.graph, cluster, m.input_size)
    # realistic WLAN hand-off links: degradation multiplies transfer time
    cfg = lambda: RuntimeConfig(inter_stage_bandwidth=50e6 / 8)
    base = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                           config=cfg()).run(48)
    slow = PipelineRuntime(
        m.graph, cluster, m.input_size, pico=pico, config=cfg(),
        churn=[LinkDegrade(0.0, 10.0)]).run(48)
    assert slow.completed == base.completed == 48
    assert slow.makespan > base.makespan
    # and the ideal hand-off is faster than any real link
    ideal = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico).run(48)
    assert ideal.makespan < base.makespan


def test_device_join_never_hurts():
    m = _small_model()
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    pico = plan(m.graph, cluster, m.input_size)
    joiner = Device("pi-extra@0.6GHz", capacity=0.6 * 2e9)
    rt = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                         churn=[DeviceJoin(pico.period * 20, joiner)])
    rep = rt.run(120)
    assert rep.completed == 120
    assert len(rep.replans) == 1 and rep.replans[0].reason == "join"
    # the re-planner keeps the incumbent when the fresh plan loses, so
    # the new modeled period can never exceed the old one
    assert rep.replans[0].new_period <= rep.replans[0].old_period + 1e-12


def test_runtime_real_compute_bit_exact():
    m = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.0, 0.8])
    params = m.init(jax.random.PRNGKey(0))
    xs = [jax.random.normal(jax.random.PRNGKey(i), (1, 64, 64, 3))
          for i in range(3)]
    rt = PipelineRuntime(model=m, params=params, cluster=cluster)
    rep = rt.run(inputs=xs)
    assert rep.completed == 3
    for i, x in enumerate(xs):
        ref = m.forward(params, x)
        for k in ref:
            np.testing.assert_allclose(np.asarray(rep.outputs[i][k]),
                                       np.asarray(ref[k]),
                                       rtol=1e-5, atol=1e-5)


def test_runtime_deterministic_under_noise():
    m = _small_model()
    cluster = make_pi_cluster([1.2, 1.0, 0.8])
    pico = plan(m.graph, cluster, m.input_size)
    cfg = dict(compute_noise=0.1, link_jitter_s=1e-4,
               inter_stage_bandwidth=50e6 / 8)
    r1 = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                         config=RuntimeConfig(seed=7, **cfg)).run(40)
    r2 = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                         config=RuntimeConfig(seed=7, **cfg)).run(40)
    r3 = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                         config=RuntimeConfig(seed=8, **cfg)).run(40)
    assert r1.completions == r2.completions
    assert r1.completions != r3.completions
    # noise/jitter make the run slower than the noiseless model
    ideal = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico).run(40)
    assert r1.makespan > ideal.makespan


def test_memory_budget_violations_recorded():
    m = _small_model()
    cluster = make_pi_cluster([1.0, 1.0])
    pico = plan(m.graph, cluster, m.input_size)
    rt = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                         config=RuntimeConfig(mem_budget_bytes=1.0))
    rep = rt.run(8)
    assert rep.completed == 8
    assert sum(d.mem_violations for d in rep.devices) > 0
    assert all(d.memory_peak_bytes > 0 for d in rep.devices if d.frames)


def test_streaming_server_end_to_end():
    from repro.data.pipeline import RequestStream
    from repro.serving import StreamingPipelineServer

    m = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.0, 0.8])
    srv = StreamingPipelineServer(m, cluster).load()
    reqs = RequestStream(rate_per_s=200.0).generate(
        4, lambda rng, i: jax.random.normal(jax.random.PRNGKey(i),
                                            (1, 64, 64, 3)))
    outs, stats = srv.serve(reqs)
    assert stats.served == 4
    assert len(stats.per_request) == 4
    assert all(lat >= 0 for lat in stats.per_request)
    sinks = m.graph.sinks()
    assert all(set(o) == set(sinks) for o in outs)
