"""Bench-regression gate: metric parsing and the regression check,
including the required injected-25%-regression failure."""

import json

import pytest

from benchmarks.run import parse_metrics
from tools.bench_gate import check, main


BASELINE = {
    "threshold": 0.2,
    "metrics": {
        "exec/vgg16_stage_compiled.speedup": {"value": 2.5,
                                              "direction": "higher"},
        "serving_mt.throughput_ratio": {"value": 2.0, "direction": "higher"},
        "serving_mt.dropped_inflight": {"value": 0.0, "direction": "lower"},
    },
}


def _measured(**overrides):
    m = {"exec/vgg16_stage_compiled.speedup": 2.5,
         "serving_mt.throughput_ratio": 2.0,
         "serving_mt.dropped_inflight": 0.0}
    m.update(overrides)
    return {"metrics": m}


def test_gate_passes_at_baseline():
    assert check(_measured(), BASELINE) == []


def test_gate_tolerates_small_regression_and_improvement():
    ok = _measured(**{"exec/vgg16_stage_compiled.speedup": 2.1,
                      "serving_mt.throughput_ratio": 3.5})
    assert check(ok, BASELINE) == []


def test_gate_fails_on_25pct_regression():
    bad = _measured(**{"serving_mt.throughput_ratio": 2.0 * 0.75})
    failures = check(bad, BASELINE)
    assert len(failures) == 1
    assert "serving_mt.throughput_ratio" in failures[0]


def test_gate_fails_lower_is_better_increase():
    bad = _measured(**{"serving_mt.dropped_inflight": 3.0})
    failures = check(bad, BASELINE)
    assert any("dropped_inflight" in f for f in failures)


def test_gate_fails_on_missing_metric():
    measured = {"metrics": {"serving_mt.throughput_ratio": 2.0,
                            "serving_mt.dropped_inflight": 0.0}}
    failures = check(measured, BASELINE)
    assert any("missing" in f for f in failures)


def test_gate_threshold_override():
    slightly_off = _measured(**{"serving_mt.throughput_ratio": 1.9})
    assert check(slightly_off, BASELINE) == []
    assert check(slightly_off, BASELINE, threshold=0.01) != []


def test_gate_hard_floor_overrides_relative_slack():
    base = {"threshold": 0.2,
            "metrics": {"serving_mt.churn_recovery":
                        {"value": 1.13, "direction": "higher",
                         "min": 0.95}}}
    # 0.96 is a >15% regression but above the floor and within 20%
    assert check({"metrics": {"serving_mt.churn_recovery": 0.96}},
                 base) == []
    # 0.91 survives the relative threshold (1.13 * 0.8 = 0.904) but
    # violates the hard acceptance bar
    failures = check({"metrics": {"serving_mt.churn_recovery": 0.91}}, base)
    assert any("hard floor" in f for f in failures)


def test_gate_hard_ceiling_on_counts():
    base = {"metrics": {"serving_mt.dropped_inflight":
                        {"value": 0.0, "direction": "lower", "max": 0.0}}}
    assert check({"metrics": {"serving_mt.dropped_inflight": 0.0}},
                 base) == []
    failures = check({"metrics": {"serving_mt.dropped_inflight": 1.0}},
                     base)
    assert failures


def test_gate_rejects_bad_direction():
    with pytest.raises(ValueError):
        check(_measured(), {"metrics": {"x": {"value": 1,
                                              "direction": "sideways"}}})


def test_main_exit_codes(tmp_path):
    meas = tmp_path / "m.json"
    base = tmp_path / "b.json"
    base.write_text(json.dumps(BASELINE))
    meas.write_text(json.dumps(_measured()))
    assert main([str(meas), "--baseline", str(base)]) == 0
    # inject a 25% regression on a gated ratio -> exit 1
    meas.write_text(json.dumps(
        _measured(**{"exec/vgg16_stage_compiled.speedup": 2.5 * 0.75})))
    assert main([str(meas), "--baseline", str(base)]) == 1


def test_parse_metrics_flattens_rows():
    rows = ["exec/vgg16_stage_compiled,123.4,speedup=2.31;cache_hits=5",
            "serving_mt.throughput_ratio,99.0,1.948",
            "table4,10.0,pieces=7;note=fused"]
    m = parse_metrics(rows)
    assert m["exec/vgg16_stage_compiled.us"] == pytest.approx(123.4)
    assert m["exec/vgg16_stage_compiled.speedup"] == pytest.approx(2.31)
    assert m["exec/vgg16_stage_compiled.cache_hits"] == 5
    assert m["serving_mt.throughput_ratio"] == pytest.approx(1.948)
    assert m["table4.pieces"] == 7
    assert "table4.note" not in m         # non-numeric derived fields skipped
