"""Multi-objective planner: ObjectiveSpec validation, Pareto front
properties (mutual non-domination, contains the throughput optimum),
artifact round-trips, objective-aware DP bit-identity between the
scalar and vectorized solvers, and registry-key separation."""

import json

import pytest

from repro.api import PlanSpec
from repro.api.specs import OBJECTIVE_PRESETS, ObjectiveSpec, spec_from_dict
from repro.core import make_pi_cluster, plan_front, plan_metrics
from repro.core.pareto import ParetoFront, dominates
from repro.core.pipeline_dp import PlannerCache
from repro.core.planner import plan_with_spec
from repro.fleet import PlanRegistry
from repro.models.cnn import zoo
from repro.obs.metrics import MetricsRegistry

_MODELS = [
    zoo.vgg16(input_size=(64, 64), scale=0.25),
    zoo.squeezenet(input_size=(64, 64), scale=0.25),
    zoo.resnet34(input_size=(64, 64), scale=0.1),
]


def _cluster():
    return make_pi_cluster([1.5, 1.2, 1.0, 0.8])


def _stage_sig(p):
    return tuple((s.first_piece, s.last_piece, s.n_devices,
                  tuple(s.fractions)) for s in p.pipeline.stages)


# ---------------------------------------------------------------------------
# ObjectiveSpec
# ---------------------------------------------------------------------------

def test_objective_spec_validation():
    ObjectiveSpec()                                      # default is valid
    for bad in (dict(throughput=-1.0), dict(latency=float("inf")),
                dict(energy=float("nan")),
                dict(throughput=0, latency=0, energy=0, memory=0),
                dict(max_latency_s=0.0), dict(max_memory_bytes=-1.0)):
        with pytest.raises(ValueError):
            ObjectiveSpec(**bad)
    with pytest.raises(ValueError):
        ObjectiveSpec.named("speed")
    with pytest.raises(ValueError):
        PlanSpec(objective="battery")        # must be a spec, not a name


def test_objective_spec_views_and_round_trip():
    assert ObjectiveSpec().is_throughput_only
    assert not ObjectiveSpec().shapes_dp
    assert ObjectiveSpec(latency=1.0).shapes_dp
    assert ObjectiveSpec(max_memory_bytes=1e6).shapes_dp
    # energy weight alone does not shape the DP (whole-plan quantity)
    assert not ObjectiveSpec(energy=1.0).shapes_dp
    for name, preset in OBJECTIVE_PRESETS.items():
        assert preset.label() == name
        assert ObjectiveSpec.named(name) == preset
        again = spec_from_dict(json.loads(preset.to_json()))
        assert again == preset
    relaxed = ObjectiveSpec(latency=1.0, max_memory_bytes=1e6).relaxed()
    assert relaxed.latency == 1.0
    assert relaxed.max_memory_bytes == float("inf")


def test_plan_spec_objective_payload_is_additive():
    """A None objective is omitted: pre-objective payloads (and every
    registry key derived from them) stay byte-identical."""
    assert "objective" not in PlanSpec().to_dict()
    assert "objective" not in json.loads(PlanSpec().to_json())
    ps = PlanSpec(objective=OBJECTIVE_PRESETS["battery"])
    again = spec_from_dict(json.loads(ps.to_json()))
    assert again == ps and again.objective == OBJECTIVE_PRESETS["battery"]


# ---------------------------------------------------------------------------
# default-objective bit-identity pin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", _MODELS, ids=lambda m: m.name)
def test_throughput_objective_is_bit_identical_to_default(model):
    cl = _cluster()
    base = plan_with_spec(model.graph, cl, model.input_size)
    obj = plan_with_spec(model.graph, cl, model.input_size,
                         PlanSpec(objective=ObjectiveSpec()))
    assert (base.period, base.latency) == (obj.period, obj.latency)
    assert _stage_sig(base) == _stage_sig(obj)
    assert base.objective is None
    assert obj.objective == "throughput"


@pytest.mark.parametrize("model", _MODELS, ids=lambda m: m.name)
def test_objective_dp_scalar_equals_vectorized(model):
    """The objective-aware DP keeps the scalar/vectorized bit-identity
    pin: same period, latency, and stage shapes on both paths."""
    cl = _cluster()
    base_mem = plan_metrics(
        plan_with_spec(model.graph, cl, model.input_size).pipeline
    ).memory_bytes
    for obj in (ObjectiveSpec(throughput=1.0, latency=2.0),
                ObjectiveSpec(max_memory_bytes=base_mem * 0.9),
                ObjectiveSpec(throughput=0.0, latency=1.0),
                ObjectiveSpec(throughput=1.0, latency=0.5,
                              max_memory_bytes=base_mem * 0.95)):
        spec = PlanSpec(objective=obj)
        scalar = plan_with_spec(model.graph, cl, model.input_size, spec)
        fast = plan_with_spec(model.graph, cl, model.input_size, spec,
                              planner_cache=PlannerCache())
        assert (scalar.period, scalar.latency) == (fast.period, fast.latency)
        assert _stage_sig(scalar) == _stage_sig(fast)


def test_memory_constraint_is_enforced_or_relaxed():
    model, cl = _MODELS[0], _cluster()
    base = plan_with_spec(model.graph, cl, model.input_size)
    budget = plan_metrics(base.pipeline).memory_bytes * 0.9
    tight = plan_with_spec(model.graph, cl, model.input_size,
                           PlanSpec(objective=ObjectiveSpec(
                               max_memory_bytes=budget)))
    assert tight.pipeline.feasible
    assert plan_metrics(tight.pipeline).memory_bytes <= budget
    # impossible budget: best-effort fallback, relaxed constraints
    hopeless = plan_with_spec(model.graph, cl, model.input_size,
                              PlanSpec(objective=ObjectiveSpec(
                                  max_memory_bytes=1.0)))
    assert hopeless.period > 0


# ---------------------------------------------------------------------------
# Pareto front (property-style over the zoo)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", _MODELS, ids=lambda m: m.name)
def test_front_mutually_non_dominated_and_contains_optimum(model):
    cl = _cluster()
    front = plan_front(model, cl)
    assert len(front) >= 2
    for i, p in enumerate(front.points):
        for j, q in enumerate(front.points):
            if i != j:
                assert not dominates(p.metrics, q.metrics)
    # the front contains the single-objective optimum: a point at least
    # as good as the pure-throughput plan on EVERY axis (the plan
    # itself, or — when extra devices buy no throughput, e.g. a comm-
    # bound model — one that strictly dominates it)
    base = plan_with_spec(model.graph, cl, model.input_size)
    bm = plan_metrics(base.pipeline)
    opt = front.throughput_optimum
    assert opt.period <= base.period
    assert any(all(x <= y for x, y in zip(p.metrics.as_tuple(),
                                          bm.as_tuple()))
               for p in front.points)
    # when the full-cluster plan itself survives the dominance filter,
    # it is served bit-identically to the single-objective planner
    survived = [p for p in front.points
                if p.n_devices == len(cl) and p.t_lim == float("inf")]
    for p in survived:
        assert (p.period, p.latency) == (base.period, base.latency)
        assert _stage_sig(p.plan) == _stage_sig(base)
    assert front.points[0].period == opt.period   # best throughput first


def test_front_select_honors_weights_and_constraints():
    front = plan_front(_MODELS[0], _cluster())
    energies = [p.energy_j for p in front]
    mems = [p.memory_bytes for p in front]
    # a pure single-metric objective picks that metric's minimum
    assert front.select(ObjectiveSpec(throughput=0, energy=1.0)
                        ).energy_j == min(energies)
    assert front.select(ObjectiveSpec(throughput=0, memory=1.0)
                        ).memory_bytes == min(mems)
    assert front.select(None) is front.throughput_optimum
    assert front.select("throughput") is front.throughput_optimum
    # constraints filter; impossible ones raise a clear error
    tight = front.select(ObjectiveSpec(max_energy_j=min(energies) * 1.0001))
    assert tight.energy_j == min(energies)
    with pytest.raises(ValueError, match="no front point"):
        front.select(ObjectiveSpec(max_memory_bytes=1.0))
    with pytest.raises(ValueError):
        ParetoFront([]).select("battery")


def test_front_artifact_round_trip_bit_identical():
    front = plan_front(_MODELS[1], _cluster(),
                       PlanSpec(objective=OBJECTIVE_PRESETS["balanced"]))
    s = front.to_json()
    back = ParetoFront.from_json(s)
    assert back.to_json() == s               # bit-identical re-encode
    assert len(back) == len(front)
    assert back.spec == front.spec
    for a, b in zip(front.points, back.points):
        assert a.metrics == b.metrics
        assert (a.n_devices, a.t_lim) == (b.n_devices, b.t_lim)
        assert _stage_sig(a.plan) == _stage_sig(b.plan)
    # newer-version artifacts are rejected, not misread
    doc = json.loads(s)
    doc["version"] = 99
    with pytest.raises(ValueError, match="newer"):
        ParetoFront.from_json(json.dumps(doc))


def test_front_deployment_carries_objective_provenance(tmp_path):
    from repro.api import DeploySpec, Deployment
    model, cl = _MODELS[1], _cluster()
    front = plan_front(model, cl)
    dep = front.deployment(model, cl, deploy_spec=DeploySpec(
        objective="battery"))
    assert dep.pico.objective == "battery"
    sel = front.select("battery")
    assert (dep.pico.period, dep.pico.latency) == (sel.period, sel.latency)
    # provenance survives the deployment artifact round-trip
    path = tmp_path / "dep.json"
    dep.save(path)
    loaded = Deployment.load(path)
    assert loaded.pico.objective == "battery"
    with pytest.raises(ValueError):
        DeploySpec(objective="speed")
    # pre-objective plan payloads still load (field is additive)
    from repro.api import artifacts
    d = artifacts.plan_to_dict(dep.pico)
    d.pop("objective")
    assert artifacts.plan_from_dict(d).objective is None


def test_plan_front_sweep_stays_on_hot_path():
    """All candidates share one PlannerCache: the sweep reuses segment
    geometry instead of recomputing it per configuration."""
    cache = PlannerCache()
    front = plan_front(_MODELS[1], _cluster(), planner_cache=cache)
    assert len(front) >= 2
    assert cache.hits > 0
    assert len(cache.solutions) > 1          # one DP table per config


# ---------------------------------------------------------------------------
# registry-key separation per objective
# ---------------------------------------------------------------------------

def test_registry_keys_distinguish_objectives():
    reg = PlanRegistry(metrics=MetricsRegistry())
    model, cl = _MODELS[1], _cluster()
    plain = reg.get_or_plan(model, cl, PlanSpec())
    assert plain.source == "scratch"
    # same model/cluster under an objective: a different key (miss),
    # and the served plan carries the objective label
    battery = reg.get_or_plan(
        model, cl, PlanSpec(objective=OBJECTIVE_PRESETS["battery"]))
    assert reg.misses == 2
    assert battery.objective == "battery"
    # both entries hit independently afterwards
    assert reg.get_or_plan(model, cl, PlanSpec()).source == "registry"
    assert reg.get_or_plan(
        model, cl,
        PlanSpec(objective=OBJECTIVE_PRESETS["battery"])).source == "registry"
    assert reg.hits == 2
    # a default-objective spec keys identically to the legacy spec
    # payload (omitted-when-None): loading old registries stays exact
    assert PlanSpec().to_json() == \
        PlanSpec(objective=None).to_json()
