"""repro.obs: span round-trips, Perfetto validity, metrics snapshots,
and the end-to-end wiring through Deployment / runtime / conv fallbacks
(ISSUE 6 acceptance: fig13 VGG16 with ``DeploySpec(trace=True)``)."""

import json
import math
import time

import pytest

import repro
from repro.obs import (
    HOST_TRACK, METRICS_SCHEMA_VERSION, NULL_REGISTRY, NULL_TRACER,
    Histogram, MetricsRegistry, Tracer, flatten, from_chrome_trace,
    open_snapshot, quantile, span_tree, validate_chrome_trace,
)
from repro.obs import trace as obs_trace
from repro.runtime.monitor import Monitor
from repro.serving.server import ServeStats


# --------------------------------------------------------------- tracing


def _sample_tracer() -> Tracer:
    tr = Tracer()
    tr.emit("plan", 0.0, 0.002, n_devices=4)
    tr.emit("frame", 0.0, 0.03, track="pipeline", frame=0)
    tr.emit("stage.compute", 0.0, 0.01, track="pi0", stage=0, frame=0,
            modeled_s=0.009, observed_s=0.01)
    tr.emit("stage.comm", 0.01, 0.002, track="link:0", stage=0)
    tr.emit("stage.compute", 0.012, 0.012, track="pi1", stage=1, frame=0)
    tr.instant("sched.admit", 0.0, track="pipeline", frames=[0])
    return tr


def test_trace_roundtrip_identical_span_tree(tmp_path):
    tr = _sample_tracer()
    path = tr.save(tmp_path / "t.json")
    doc = json.loads(open(path).read())
    assert validate_chrome_trace(doc) == []
    back = from_chrome_trace(doc)
    assert back == tr.spans                       # exact, incl. float ts
    assert span_tree(back) == span_tree(tr.spans)


def test_chrome_trace_device_rows():
    doc = _sample_tracer().to_chrome_trace()
    names = {ev["args"]["name"] for ev in doc["traceEvents"]
             if ev.get("ph") == "M" and ev.get("name") == "process_name"}
    assert {"pi0", "pi1", "link:0", "pipeline", HOST_TRACK} <= names
    pids = {ev["pid"] for ev in doc["traceEvents"] if ev.get("ph") == "X"}
    assert len(pids) == len(_sample_tracer().tracks())


def test_validate_rejects_garbage():
    assert validate_chrome_trace({"no": "events"})
    bad = _sample_tracer().to_chrome_trace()
    bad["traceEvents"][0] = {"ph": "X"}           # missing name/ts/pid
    assert validate_chrome_trace(bad)
    with pytest.raises(ValueError):
        from_chrome_trace(bad)


def test_null_tracer_is_inert():
    assert not NULL_TRACER
    NULL_TRACER.emit("frame", 0.0, 1.0)
    NULL_TRACER.instant("sched.admit", 0.0)
    with NULL_TRACER.wall_span("plan"):
        pass
    assert NULL_TRACER.spans == ()


def test_scoped_activation_restores_previous():
    tr = Tracer()
    assert obs_trace.current() is NULL_TRACER
    with obs_trace.scoped(tr):
        assert obs_trace.current() is tr
        with obs_trace.scoped(None):              # None coerces to the null
            assert obs_trace.current() is NULL_TRACER
        assert obs_trace.current() is tr
    assert obs_trace.current() is NULL_TRACER


# --------------------------------------------------------- quantiles


def test_nearest_rank_quantile_tiny_windows():
    assert quantile([], 50) == 0.0
    assert quantile([7.0], 50) == quantile([7.0], 99) == 7.0
    # n=2: p50 -> rank ceil(1.0)=1 -> smaller sample; p95/p99 -> larger
    assert quantile([3.0, 9.0], 50) == 3.0
    assert quantile([3.0, 9.0], 95) == 9.0
    vals = [float(i) for i in range(1, 101)]
    assert quantile(vals, 50) == 50.0
    assert quantile(vals, 99) == 99.0


@pytest.mark.parametrize("n", [1, 2, 3, 10])
def test_servestats_histogram_percentile_parity(n):
    lat = [0.01 * (i + 1) for i in range(n)]
    st = ServeStats()
    h = Histogram("serve.latency_s")
    for x in lat:
        st.record(x)
        h.observe(x)
    for q in (50.0, 95.0, 99.0):
        assert st.latency_percentile(q) == h.percentile(q)
    assert (st.latency_percentile(50) <= st.latency_percentile(95)
            <= st.latency_percentile(99))


# ----------------------------------------------------------- metrics


def test_registry_snapshot_flatten_roundtrip():
    reg = MetricsRegistry()
    reg.counter("runtime.replans", reason="drift").inc(2)
    reg.gauge("monitor.ratio", device="pi0").set(1.3)
    reg.gauge("weird").set(math.inf)
    for x in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("frame.latency_s").observe(x)
    snap = reg.snapshot(meta={"run": "test"})
    assert snap["artifact"] == "metrics"
    assert snap["version"] == METRICS_SCHEMA_VERSION
    json.dumps(snap)                              # strict-JSON encodable
    flat = flatten(snap)
    assert flat["runtime.replans{reason=drift}"] == 2.0
    assert flat["monitor.ratio{device=pi0}"] == 1.3
    assert flat["weird"] == math.inf
    assert flat["frame.latency_s.count"] == 4.0
    assert flat["frame.latency_s.p50"] == 2.0
    assert flat["frame.latency_s.max"] == 4.0


def test_snapshot_rejects_newer_version():
    snap = MetricsRegistry().snapshot()
    snap["version"] = METRICS_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="newer"):
        open_snapshot(snap)
    with pytest.raises(ValueError):
        open_snapshot({"artifact": "plan", "version": 1, "payload": {}})


def test_registry_merge_semantics():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c").inc(1)
    b.counter("c").inc(2)
    a.gauge("g").set(1.0)
    b.gauge("g").set(5.0)
    a.histogram("h").observe(1.0)
    b.histogram("h").observe(3.0)
    a.merge(b)
    assert a.value("c") == 3.0                    # counters add
    assert a.value("g") == 5.0                    # gauges overwrite
    flat = flatten(a.snapshot())
    assert flat["h.count"] == 2.0 and flat["h.max"] == 3.0


def test_null_registry_is_inert():
    assert not NULL_REGISTRY
    NULL_REGISTRY.counter("x").inc()
    NULL_REGISTRY.gauge("y").set(1.0)
    NULL_REGISTRY.histogram("z").observe(1.0)


# ----------------------------------------------------------- monitor


def test_monitor_zero_modeled_seconds():
    m = Monitor(metrics=MetricsRegistry())
    m.record(0, "pi0", 0.0, 0.01)
    assert m.samples == 1
    assert m.device_ratio("pi0") == 1.0           # no ratio from 0 model
    assert m.drifted_devices() == []
    assert m.stage_time[0].n == 1
    assert m.metrics.value("monitor.samples") == 1.0


def test_monitor_first_sample_ewma_exact():
    m = Monitor()
    m.record(0, "pi0", 1.0, 2.0)
    assert m.device_ratio("pi0") == 2.0           # not blended with init 1.0
    m.record(0, "pi0", 1.0, 2.0)
    assert m.device_ratio("pi0") == 2.0


def test_monitor_drift_boundary_is_strict():
    m = Monitor(drift_threshold=0.25)
    m.record(0, "at", 1.0, 1.25)                  # |ewma-1| == threshold
    m.record(0, "over", 1.0, 1.2500001)
    assert m.drifted_devices() == ["over"]


# ------------------------------------------------------ conv fallback


def test_conv_fallback_is_structured():
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.conv2d.ops import conv2d, fallback_count
    from repro.obs.metrics import default_registry

    # strided convs now run on the Pallas kernel; the one remaining
    # fallback is an input spatially smaller than the filter
    x = jnp.ones((1, 2, 2, 4), jnp.float32)
    w = jnp.ones((3, 3, 4, 8), jnp.float32)
    before = fallback_count()
    tr = Tracer()
    with obs_trace.scoped(tr), pytest.warns(RuntimeWarning):
        import warnings
        warnings.simplefilter("always")           # defeat the once-cache
        conv2d(x, w, stride=(1, 1))
    assert fallback_count() == before + 1
    flat = flatten(default_registry().snapshot())
    labelled = [k for k in flat
                if k.startswith("conv.fallback{") and "reason=shape" in k
                and "x_shape=(1, 2, 2, 4)" in k]
    assert labelled, sorted(k for k in flat if k.startswith("conv.fallback"))
    assert [s.name for s in tr.spans] == ["conv.fallback"]
    assert tr.spans[0].attr("reason") == "shape"


# ------------------------------------- end-to-end: fig13 VGG16 deployment


@pytest.fixture(scope="module")
def traced_deployment():
    from repro.core import make_pi_cluster
    from repro.models.cnn import zoo
    model = zoo.vgg16(input_size=(64, 64), scale=0.125)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8], bandwidth_mbps=50.0)
    dep = repro.compile(model, cluster)
    rt = dep.runtime(repro.DeploySpec(trace=True), real_compute=False)
    rt.run(n_frames=8)
    return dep, rt


def test_fig13_trace_acceptance(traced_deployment, tmp_path):
    dep, rt = traced_deployment
    n_stages = len(dep.pico.pipeline.stages)
    n_frames = 8
    path = dep.save_trace(tmp_path / "fig13.json")
    doc = json.load(open(path))
    assert validate_chrome_trace(doc) == []
    # one process row per device actor
    rows = {ev["args"]["name"] for ev in doc["traceEvents"]
            if ev.get("ph") == "M" and ev.get("name") == "process_name"}
    for d in dep.cluster.devices:
        assert d.name in rows
    # >= 1 span per stage per frame
    spans = from_chrome_trace(doc)
    compute = [s for s in spans if s.name == "stage.compute"]
    assert len(compute) >= n_stages * n_frames
    for s_idx in range(n_stages):
        assert sum(1 for s in compute if s.attr("stage") == s_idx) >= n_frames
    assert sum(1 for s in spans if s.name == "frame") == n_frames
    # compile-time spans (plan) land on the deployment tracer too
    assert any(s.name == "plan" for s in spans)


def test_deployment_metrics_snapshot(traced_deployment):
    dep, rt = traced_deployment
    snap = dep.metrics_snapshot()
    assert snap["version"] == METRICS_SCHEMA_VERSION
    assert snap["payload"]["meta"]["model"]
    flat = flatten(snap)
    assert flat["runtime.frames_completed"] == 8.0
    assert flat["frame.latency_s.count"] == 8.0
    assert flat["frame.latency_s.p50"] <= flat["frame.latency_s.p99"]
    assert "exec.cache.hits" in flat              # default-registry merge


def test_trace_cli_summary_and_validation(traced_deployment, tmp_path, capsys):
    from repro.tools.trace import bubble_fraction, main
    dep, rt = traced_deployment
    path = str(dep.save_trace(tmp_path / "cli.json"))
    assert main([path, "--validate"]) == 0
    assert main([path]) == 0
    out = capsys.readouterr().out
    assert "per-device compute" in out and "bubble fraction" in out
    assert 0.0 <= bubble_fraction(dep.tracer.spans) < 1.0
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
    assert main([str(bad), "--validate"]) == 1


def test_untraced_runtime_overhead_under_2pct():
    """With tracing off the runtime must pay only a falsy branch per
    event: an untraced run may not be measurably slower than a traced
    one (best-of-N wall clock, interleaved to decorrelate noise)."""
    from repro.core import make_pi_cluster
    from repro.models.cnn import zoo
    model = zoo.vgg16(input_size=(64, 64), scale=0.125)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8], bandwidth_mbps=50.0)
    dep = repro.compile(model, cluster)

    def run(trace: bool) -> float:
        rt = dep.runtime(repro.DeploySpec(trace=trace), real_compute=False)
        t0 = time.perf_counter()
        rt.run(n_frames=64)
        return time.perf_counter() - t0

    run(False), run(True)                         # warm both paths
    off, on = [], []
    for _ in range(5):
        off.append(run(False))
        on.append(run(True))
    assert min(off) <= min(on) * 1.02, (off, on)


def test_untraced_runtime_uses_null_singletons():
    from repro.core import make_pi_cluster
    from repro.models.cnn import zoo
    model = zoo.vgg16(input_size=(64, 64), scale=0.125)
    dep = repro.compile(model, make_pi_cluster([1.0, 1.0]))
    rt = dep.runtime(repro.DeploySpec(trace=False, metrics=False),
                     real_compute=False)
    assert rt.tracer is NULL_TRACER
    assert rt.metrics is NULL_REGISTRY


# ---------------------------------------------------- bench-gate bridge


def test_bench_gate_reads_snapshot():
    from tools.bench_gate import check, flatten_snapshot, metrics_view
    reg = MetricsRegistry()
    reg.counter("runtime.frames_dropped").inc(0)
    reg.gauge("serving_mt.throughput_ratio").set(2.4)
    for x in (0.01, 0.02, 0.03):
        reg.histogram("frame.latency_s").observe(x)
    snap = reg.snapshot()
    # the gate's dependency-free flatten agrees with repro.obs.flatten
    assert flatten_snapshot(snap) == flatten(snap)
    baseline = {"metrics": {
        "serving_mt.throughput_ratio": {"value": 2.0, "direction": "higher"},
        "frame.latency_s.p95": {"value": 0.03, "direction": "lower"},
    }}
    assert check(snap, baseline) == []            # bare snapshot form
    combined = {"metrics": {"legacy.metric": 1.0}, "snapshot": snap}
    view = metrics_view(combined)
    assert view["legacy.metric"] == 1.0
    assert view["frame.latency_s.count"] == 3.0
    newer = dict(snap, version=METRICS_SCHEMA_VERSION + 1)
    with pytest.raises(ValueError, match="newer"):
        flatten_snapshot(newer)


def test_servestats_publish_idempotent():
    st = ServeStats(period_model_s=0.05, wall_s=1.0)
    st.record(0.01)
    st.record(0.02, missed_deadline=True)
    reg = MetricsRegistry()
    st.publish(reg, tenant="a")
    st.publish(reg, tenant="a")                   # re-publish: no double count
    flat = flatten(reg.snapshot())
    assert flat["serve.served{tenant=a}"] == 2.0
    assert flat["serve.deadline_misses{tenant=a}"] == 1.0
    assert flat["serve.latency_s{tenant=a}.count"] == 2.0
    st.record(0.03)
    st.publish(reg, tenant="a")                   # incremental append
    assert flatten(reg.snapshot())["serve.latency_s{tenant=a}.count"] == 3.0
