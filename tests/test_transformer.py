"""Transformer substrate correctness: every family's forward / prefill /
decode agree; SSD chunked == naive recurrence; SWA ring cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer.config import ArchConfig
from repro.models.transformer import model as M
from repro.models.transformer import layers as L
from repro.training.optim import AdamW
from repro.training.steps import make_train_step

FAMILIES = {
    "dense": ArchConfig("dense", 2, 64, 4, 2, 128, 500, qkv_bias=True),
    "swa": ArchConfig("swa", 2, 64, 4, 2, 128, 500, sliding_window=8),
    "moe": ArchConfig("moe", 2, 64, 4, 4, 64, 500, n_experts=4,
                      moe_top_k=2, capacity_factor=2.0, family="moe"),
    "ssm": ArchConfig("ssm", 2, 64, 0, 0, 0, 500, ssm_state=16,
                      ssm_head_dim=16, layer_pattern="mamba", family="ssm"),
    "hybrid": ArchConfig("hybrid", 4, 64, 4, 2, 128, 500, ssm_state=16,
                         ssm_head_dim=16, layer_pattern="mamba",
                         shared_attn_every=2, family="hybrid"),
    "embeds": ArchConfig("embeds", 2, 64, 4, 2, 128, 500,
                         input_mode="embeds"),
}
B, S = 2, 16


def _batch(cfg, key=1):
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(jax.random.PRNGKey(key), (B, S), 0,
                                  cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    emb = jax.random.normal(jax.random.PRNGKey(key), (B, S, cfg.d_model))
    return {"embeds": emb, "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_forward_prefill_decode_agree(fam):
    cfg = FAMILIES[fam]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = M.forward(cfg, params, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not np.isnan(np.asarray(logits)).any()

    pre_logits, cache = M.prefill(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)

    if cfg.input_mode != "tokens":
        return
    batch1 = {"tokens": batch["tokens"][:, :-1]}
    _, cache1 = M.prefill(cfg, params, batch1)
    if not cfg.sliding_window:
        for k in ("k", "v", "shared_k", "shared_v"):
            if k in cache1:
                pads = [(0, 0)] * cache1[k].ndim
                pads[2] = (0, 1)
                cache1[k] = jnp.pad(cache1[k], pads)
    dec_logits, cache2 = M.decode_step(cfg, params, cache1,
                                       {"token": batch["tokens"][:, -1]})
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)
    assert int(cache2["len"]) == S


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_train_step_finite_and_updates(fam):
    cfg = FAMILIES[fam]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    st = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    p2, st2, loss = step(params, st, _batch(cfg))
    assert np.isfinite(float(loss))
    # params actually changed
    diffs = [float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))]
    assert max(diffs) > 0


def test_unroll_matches_scan():
    cfg = FAMILIES["dense"]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    a = M.forward(cfg, params, batch, remat=False, unroll=False)
    b = M.forward(cfg, params, batch, remat=False, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_ssd_chunked_matches_naive_recurrence():
    """ssd_chunked == step-by-step SSM recurrence."""
    Bz, Sq, H, P, N = 2, 32, 2, 8, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (Bz, Sq, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (Bz, Sq, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (Bz, Sq, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(4), (Bz, Sq, N)) * 0.3
    D = jnp.ones((H,)) * 0.5
    y_chunk, h_chunk = L.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=8)

    # naive recurrence
    h = jnp.zeros((Bz, H, P, N))
    ys = []
    for t in range(Sq):
        decay = jnp.exp(dt[:, t] * A)                      # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], x[:, t])
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, t], h) \
            + x[:, t] * D[None, :, None]
        ys.append(y)
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_swa_matches_full_when_window_covers():
    cfg_full = FAMILIES["dense"]
    cfg_swa = ArchConfig("swa-big", 2, 64, 4, 2, 128, 500, qkv_bias=True,
                         sliding_window=S + 4)
    params = M.init_params(cfg_full, jax.random.PRNGKey(0))
    batch = _batch(cfg_full)
    a = M.forward(cfg_full, params, batch, remat=False)
    b = M.forward(cfg_swa, params, batch, remat=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_blockwise_attention_vs_naive():
    Bz, Sq, K, G, D = 2, 32, 2, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (Bz, Sq, K, G, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (Bz, Sq, K, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (Bz, Sq, K, D))
    out = L.blockwise_causal_attention(q, k, v, q_block=8, kv_block=8)
    # naive
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k) / np.sqrt(D)
    mask = jnp.tril(jnp.ones((Sq, Sq), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    ref = jnp.moveaxis(jnp.einsum("bkgqs,bskd->bkgqd", w, v), 3, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_aux_loss_and_routing():
    cfg = FAMILIES["moe"]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    lp = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    out, aux = L.moe(lp, x, cfg.moe_top_k, cfg.capacity_factor)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # E * sum(me*ce) >= 1 at balance
