"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family (2 layers, d_model <= 512, <= 4 experts) runs one forward/train
step and one decode step on CPU, asserting shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.transformer import model as M
from repro.training.optim import AdamW
from repro.training.steps import make_train_step

B, S = 2, 16


def _reduced(name):
    return configs.get(name).reduced()


def _batch(cfg):
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                  cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    emb = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    return {"embeds": emb, "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_smoke_forward_and_train(name):
    cfg = _reduced(name)
    assert cfg.n_layers <= 2 or cfg.shared_attn_every
    assert cfg.d_model <= 512 and (cfg.n_experts or 0) <= 4
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = M.forward(cfg, params, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not np.isnan(np.asarray(logits)).any(), name

    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    _, _, loss = step(params, opt.init(params), batch)
    assert np.isfinite(float(loss)), name


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_smoke_serve_step(name):
    cfg = _reduced(name)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache = M.init_cache(cfg, B, S)
    if cfg.input_mode == "tokens":
        inputs = {"token": jnp.zeros((B,), jnp.int32)}
    else:
        inputs = {"embed": jnp.zeros((B, cfg.d_model))}
    logits, cache2 = M.decode_step(cfg, params, cache, inputs)
    assert logits.shape == (B, cfg.vocab_padded)
    assert not np.isnan(np.asarray(logits)).any(), name
    assert int(cache2["len"]) == 1


def test_exact_assigned_specs():
    """The full configs carry the exact assignment numbers."""
    spec = {
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    }
    for name, (L, d, h, kv, ff, vocab) in spec.items():
        c = configs.get(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, vocab), name
    assert configs.get("qwen1.5-4b").qkv_bias
    assert configs.get("mixtral-8x7b").sliding_window == 4096
    assert configs.get("mixtral-8x7b").n_experts == 8
    assert configs.get("mixtral-8x7b").moe_top_k == 2
    assert configs.get("granite-moe-3b-a800m").n_experts == 40
    assert configs.get("granite-moe-3b-a800m").moe_top_k == 8
    assert configs.get("mamba2-370m").ssm_state == 128
    assert configs.get("zamba2-2.7b").ssm_state == 64
    assert configs.get("llava-next-34b").input_mode == "embeds"
    assert configs.get("musicgen-medium").input_mode == "embeds"


def test_long_context_variants():
    from repro.configs.shapes import SHAPES, arch_for_shape
    long = SHAPES["long_500k"]
    for name in configs.ARCH_NAMES:
        cfg = arch_for_shape(configs.get(name), long)
        assert cfg.supports_long_context, name
