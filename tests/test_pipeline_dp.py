"""Algorithm 2 + 3 tests: DP optimality vs exhaustive search, T_lim."""

import math

import pytest

from repro.core import (Cluster, Device, PipelineDP, adjust_stages,
                        chain_pieces, make_pi_cluster, plan)
from repro.core.baselines import bfs_optimal
from repro.core.partition import Piece, partition_graph
from repro.models.cnn import zoo


def small_chain():
    m = zoo.vgg16(input_size=(64, 64), scale=0.1, head=False)
    g = m.graph
    order = g.topo_order[:8]
    sub = type(g)()
    for n in order:
        sub.layers[n] = g.layers[n]
    sub.edges = [(u, v) for u, v in g.edges if u in order and v in order]
    sub._invalidate()
    return m, sub


def test_dp_matches_bfs_homogeneous():
    m, g = small_chain()
    pieces = [Piece(ns, 0.0, i) for i, ns in enumerate(chain_pieces(g))]
    cluster = make_pi_cluster([1.0] * 4)
    dp = PipelineDP(g, pieces, cluster, m.input_size)
    plan_dp = dp.build()
    bfs = bfs_optimal(g, pieces, cluster, m.input_size, budget_s=120)
    assert bfs.extra["complete"]
    assert plan_dp.period <= bfs.period * (1 + 1e-9)


def test_t_lim_constrains_latency():
    m, g = small_chain()
    pieces = [Piece(ns, 0.0, i) for i, ns in enumerate(chain_pieces(g))]
    cluster = make_pi_cluster([1.0] * 4)
    free = PipelineDP(g, pieces, cluster, m.input_size).build()
    assert free.feasible
    if len(free.stages) > 1:
        tight = PipelineDP(g, pieces, cluster, m.input_size,
                           t_lim=free.latency * 0.9).build()
        if tight.feasible:
            assert tight.latency <= free.latency * 0.9 + 1e-12
            assert tight.period >= free.period - 1e-12
        # generous limit must stay feasible and match the free optimum
        loose = PipelineDP(g, pieces, cluster, m.input_size,
                           t_lim=free.latency * 2).build()
        assert loose.feasible
        assert loose.period <= free.period + 1e-12


def test_device_slices_disjoint():
    m, g = small_chain()
    pieces = [Piece(ns, 0.0, i) for i, ns in enumerate(chain_pieces(g))]
    cluster = make_pi_cluster([1.0] * 6)
    p = PipelineDP(g, pieces, cluster, m.input_size).build()
    names = [d.name for st in p.stages for d in st.devices]
    assert len(names) == len(set(names))
    assert len(names) <= 6


def test_adjust_stages_uses_all_slots():
    m, g = small_chain()
    pieces = [Piece(ns, 0.0, i) for i, ns in enumerate(chain_pieces(g))]
    hetero = make_pi_cluster([1.5, 1.5, 1.2, 0.8])
    homo = hetero.homogenized()
    hp = PipelineDP(g, pieces, homo, m.input_size).build()
    final = adjust_stages(hp, hetero, g, m.input_size)
    assigned = [d.name for st in final.stages for d in st.devices]
    assert sorted(assigned) == sorted(d.name for d in hetero.devices)
    # faster devices get larger output fractions within a stage
    for st in final.stages:
        if len(st.devices) >= 2:
            caps = [d.capacity for d in st.devices]
            assert all(
                (caps[i] >= caps[j]) == (st.fractions[i] >= st.fractions[j])
                for i in range(len(caps)) for j in range(len(caps)))


def test_full_plan_beats_single_device():
    m = zoo.vgg16(input_size=(96, 96), scale=0.15)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    p = plan(m.graph, cluster, m.input_size)
    single = Cluster([cluster.devices[0]], bandwidth=cluster.bandwidth)
    from repro.core.cost import stage_cost
    full = m.graph.forward_sizes(m.input_size)
    sc = stage_cost(m.graph, frozenset(m.graph.layers), full,
                    m.input_size, single.devices, single)
    assert p.period < sc.total  # pipelining beats one device
