"""Fleet tier: plan registry, router, autoscaler, incremental planner
equivalence (property-style), and PlanSource provenance threading."""

import dataclasses

import pytest

from repro.api import FleetSpec, PlanSpec
from repro.api import artifacts
from repro.api.specs import spec_from_dict
from repro.core import Cluster, make_pi_cluster
from repro.core.pipeline_dp import PlannerCache
from repro.core.planner import PicoPlan, plan_with_spec
from repro.fleet import (Autoscaler, FleetRouter, PlanRegistry, Tenant,
                         cluster_signature, fingerprint_model)
from repro.models.cnn import zoo
from repro.obs.metrics import MetricsRegistry

from _hypothesis_compat import given, settings, st


def _renamed(cluster, prefix):
    return Cluster([dataclasses.replace(d, name=f"{prefix}.{d.name}")
                    for d in cluster.devices], bandwidth=cluster.bandwidth)


def _sig(p: PicoPlan) -> tuple:
    """Exact plan identity — no tolerance anywhere."""
    return (p.period, p.latency, p.pipeline.feasible,
            tuple((sp.first_piece, sp.last_piece,
                   tuple(d.name for d in sp.devices), tuple(sp.fractions),
                   sp.cost.total, sp.cost.t_comp, sp.cost.t_comm)
                  for sp in p.pipeline.stages))


# ---------------------------------------------------------------------------
# incremental PipelineDP == full recompute (property-style)
# ---------------------------------------------------------------------------

_MODELS = [
    zoo.squeezenet(input_size=(64, 64), scale=0.25),
    zoo.mobilenetv3(input_size=(64, 64), scale=0.25),
    zoo.resnet34(input_size=(64, 64), scale=0.1),
]
_BASE_CAPS = [1.5, 1.2, 1.0, 1.0, 0.8, 0.8]


@settings(max_examples=10, deadline=None)
@given(model_i=st.integers(0, len(_MODELS) - 1),
       toggles=st.lists(st.integers(0, len(_BASE_CAPS) - 1),
                        min_size=1, max_size=4))
def test_incremental_equals_scratch_under_churn(model_i, toggles):
    """Random single-device drop/join sequences: the incremental path
    (shared PlannerCache) must produce bit-identical plans to a full
    recompute at every step."""
    model = _MODELS[model_i]
    base = make_pi_cluster(_BASE_CAPS)
    spec = PlanSpec()
    cache = PlannerCache()
    seed = plan_with_spec(model.graph, base, model.input_size, spec,
                          planner_cache=cache)
    assert seed.source == "scratch"
    active = set(range(len(_BASE_CAPS)))
    for i in toggles:
        if i in active and len(active) > 1:
            active.remove(i)       # device drop
        else:
            active.add(i)          # device (re)join
        cluster = base.restricted([base.devices[k] for k in sorted(active)])
        inc = plan_with_spec(model.graph, cluster, model.input_size, spec,
                             partition=seed.partition, planner_cache=cache)
        full = plan_with_spec(model.graph, cluster, model.input_size, spec,
                              partition=seed.partition)
        assert inc.source == "incremental"
        assert full.source == "scratch"
        assert _sig(inc) == _sig(full)


def test_incremental_equals_scratch_one_drop():
    """Non-hypothesis twin of the property test (runs on minimal
    installs): one drop on the heterogeneous 8-device cluster."""
    model = _MODELS[0]
    base = make_pi_cluster([1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8])
    cache = PlannerCache()
    seed = plan_with_spec(model.graph, base, model.input_size,
                          planner_cache=cache)
    smaller = base.restricted(base.devices[1:])
    inc = plan_with_spec(model.graph, smaller, model.input_size,
                         partition=seed.partition, planner_cache=cache)
    full = plan_with_spec(model.graph, smaller, model.input_size,
                          partition=seed.partition)
    assert inc.source == "incremental" and full.source == "scratch"
    assert _sig(inc) == _sig(full)
    assert cache.hits > 0


# ---------------------------------------------------------------------------
# PlanRegistry
# ---------------------------------------------------------------------------

def _cluster4():
    return make_pi_cluster([1.5, 1.2, 1.0, 0.8])


def test_registry_hit_miss_and_isolation():
    reg = PlanRegistry(capacity=8, metrics=MetricsRegistry())
    model = _MODELS[0]
    c = _cluster4()
    first = reg.get_or_plan(model, c)
    assert first.source == "scratch" and reg.misses == 1
    second = reg.get_or_plan(model, c)
    assert second.source == "registry" and reg.hits == 1
    assert _sig(second)[:2] == _sig(first)[:2]
    # hits decode fresh objects: mutating one never corrupts the cache
    second.pipeline.stages[0].fractions[0] = -1.0
    third = reg.get_or_plan(model, c)
    assert third.pipeline.stages[0].fractions[0] != -1.0


def test_registry_name_insensitive_rebind():
    """Identical hardware under different device names is one planning
    problem; the served plan's devices are rebound onto the caller's."""
    reg = PlanRegistry(metrics=MetricsRegistry())
    model = _MODELS[1]
    a, b = _cluster4(), _renamed(_cluster4(), "podB")
    assert cluster_signature(a) == cluster_signature(b)
    pa = reg.get_or_plan(model, a)
    pb = reg.get_or_plan(model, b)
    assert pb.source == "registry"
    assert pb.period == pa.period and pb.latency == pa.latency
    served = {d.name for sp in pb.pipeline.stages for d in sp.devices}
    assert served <= {d.name for d in b.devices}


def test_registry_key_discriminates():
    reg = PlanRegistry(metrics=MetricsRegistry())
    model = _MODELS[0]
    c = _cluster4()
    reg.get_or_plan(model, c, PlanSpec())
    # different spec, different cluster shape, different model: all miss
    assert reg.get(model, c, PlanSpec(t_lim=0.5)) is None
    assert reg.get(model, make_pi_cluster([1.0, 1.0]), PlanSpec()) is None
    assert reg.get(_MODELS[2], c, PlanSpec()) is None
    assert fingerprint_model(_MODELS[0]) != fingerprint_model(_MODELS[2])


def test_registry_lru_eviction():
    reg = PlanRegistry(capacity=2, metrics=MetricsRegistry())
    model = _MODELS[0]
    c1, c2, c3 = (make_pi_cluster([1.0] * n) for n in (2, 3, 4))
    reg.get_or_plan(model, c1)
    reg.get_or_plan(model, c2)
    reg.get_or_plan(model, c1)          # refresh c1
    reg.get_or_plan(model, c3)          # evicts c2 (least recent)
    assert len(reg) == 2
    assert reg.get(model, c1) is not None
    assert reg.get(model, c2) is None


def test_registry_json_round_trip():
    reg = PlanRegistry(capacity=4, metrics=MetricsRegistry())
    model = _MODELS[0]
    c = _cluster4()
    reg.get_or_plan(model, c)
    loaded = PlanRegistry.from_json(reg.to_json())
    assert len(loaded) == 1
    hit = loaded.get(model, c)
    assert hit is not None and hit.source == "registry"


# ---------------------------------------------------------------------------
# FleetRouter + Autoscaler
# ---------------------------------------------------------------------------

def _router(routing="least_loaded", **kw):
    cells = {"a": make_pi_cluster([1.5, 1.2, 1.0, 0.8]),
             "b": _renamed(make_pi_cluster([1.5, 1.2, 1.0, 0.8]), "b")}
    return FleetRouter(cells, spec=FleetSpec(routing=routing, **kw),
                       metrics=MetricsRegistry())


def test_router_least_loaded_follows_ewma():
    r = _router()
    r.observe("a", 0.9)
    r.observe("b", 0.1)
    adm = r.admit(Tenant("t0", _MODELS[0]))
    assert adm.cell == "b"
    # the load picture flips: beta=0.3 smoothing needs a few samples
    for _ in range(4):
        r.observe("b", 0.95)
        r.observe("a", 0.05)
    assert r.cell_load("a") < r.cell_load("b")
    assert r.admit(Tenant("t1", _MODELS[1])).cell == "a"


def test_router_round_robin_and_registry_hits():
    r = _router(routing="round_robin")
    adms = [r.admit(Tenant(f"t{i}", _MODELS[0])) for i in range(4)]
    assert [a.cell for a in adms] == ["a", "b", "a", "b"]
    # cells a and b are identical hardware: after the first scratch
    # plan, every admission is a registry hit (name-insensitive)
    assert [a.plan_source for a in adms] == \
        ["scratch", "registry", "registry", "registry"]


def test_router_round_robin_survives_topology_change():
    """Regression: the cursor used to be an integer index into
    sorted(cells), so add_cell/remove_cell shifted which cell it landed
    on (repeating or skipping cells).  Keyed on the last-served *name*,
    the rotation resumes fairly after any topology change."""
    r = _router(routing="round_robin")
    assert [r.admit(Tenant(f"t{i}", _MODELS[0])).cell
            for i in range(2)] == ["a", "b"]
    # "ab" sorts between the existing cells; the old index-based cursor
    # would now serve "b" twice in a row
    r.add_cell("ab", _renamed(make_pi_cluster([1.5, 1.2, 1.0, 0.8]), "ab"))
    assert [r.admit(Tenant(f"u{i}", _MODELS[0])).cell
            for i in range(4)] == ["a", "ab", "b", "a"]
    # removing the last-served cell: rotation continues from its name
    # ("a" held t0/u0/u3; they re-admit round-robin as ab, b, ab)
    moved = r.remove_cell("a")
    assert [m.cell for m in moved] == ["ab", "b", "ab"]
    assert r.admit(Tenant("v0", _MODELS[0])).cell == "b"


def test_router_zero_capacity_cell_routed_around():
    """A degraded cell (zero total capacity) must never be a routing
    target — and must not crash load accounting with a
    ZeroDivisionError."""
    from repro.core import Device
    dead = Cluster([Device("dead0", 0.0)], bandwidth=50e6 / 8)
    cells = {"a": make_pi_cluster([1.5, 1.2, 1.0, 0.8]), "z": dead}
    r = FleetRouter(cells, spec=FleetSpec(), metrics=MetricsRegistry())
    assert r.cell_load("z") == float("inf")
    for i in range(3):
        assert r.admit(Tenant(f"t{i}", _MODELS[0])).cell == "a"
    # round_robin skips it too
    rr = FleetRouter({"a": make_pi_cluster([1.5, 1.2, 1.0, 0.8]),
                      "b": _renamed(make_pi_cluster([1.5, 1.2, 1.0, 0.8]),
                                    "b"),
                      "z": dead},
                     spec=FleetSpec(routing="round_robin"),
                     metrics=MetricsRegistry())
    assert [rr.admit(Tenant(f"t{i}", _MODELS[0])).cell
            for i in range(4)] == ["a", "b", "a", "b"]
    # a fleet with no routable cell fails loudly, not with a crash
    only_dead = FleetRouter({"z": dead}, metrics=MetricsRegistry())
    with pytest.raises(ValueError, match="zero capacity"):
        only_dead.admit(Tenant("t9", _MODELS[0]))


def test_router_churn_emits_spans_and_counters():
    """Regression: churn used to re-plan silently while admit emitted
    fleet.route spans and plan-source counters — repartition audits
    could not see churn-driven plans."""
    from repro.obs import Tracer
    from repro.obs import trace as obs_trace
    reg = MetricsRegistry()
    cells = {"a": make_pi_cluster([1.5, 1.2, 1.0, 0.8])}
    r = FleetRouter(cells, spec=FleetSpec(), metrics=reg)
    r.admit(Tenant("t0", _MODELS[0]))
    tr = Tracer()
    with obs_trace.scoped(tr):
        replanned = r.churn("a", cells["a"].restricted(
            cells["a"].devices[:-1]))
    assert replanned["t0"].source == "incremental"
    churn_spans = [s for s in tr.spans if s.name == "fleet.churn"]
    route_spans = [s for s in tr.spans if s.name == "fleet.route"]
    assert len(churn_spans) == 1
    assert churn_spans[0].attr("cell") == "a"
    assert len(route_spans) == 1
    assert route_spans[0].attr("policy") == "churn"
    assert route_spans[0].attr("tenant") == "t0"
    assert reg.value("fleet.replans", source="incremental") == 1.0


def test_router_churn_is_incremental():
    r = _router()
    r.admit(Tenant("t0", _MODELS[0]))
    cell = next(c for c in r.cells.values() if c.tenants)
    smaller = cell.cluster.restricted(cell.cluster.devices[:-1])
    replanned = r.churn(cell.name, smaller)
    assert replanned["t0"].source == "incremental"
    # the twin cell's 4-device shape is already registered: admitting
    # the same model there is a pure registry hit
    adm = r.admit(Tenant("t1", _MODELS[0]))
    assert adm.cell != cell.name
    assert adm.plan_source == "registry"


def test_router_evict_and_remove_cell():
    r = _router(max_clusters=3)
    r.admit(Tenant("t0", _MODELS[0]))
    assert r.evict("t0") is not None
    assert r.evict("t0") is None and not r.plans
    r.observe("a", 0.5)
    r.observe("b", 0.1)
    adm = r.admit(Tenant("t1", _MODELS[0]))
    moved = r.remove_cell(adm.cell)
    assert [m.tenant for m in moved] == ["t1"]
    assert len(r.cells) == 1
    with pytest.raises(ValueError):
        r.remove_cell(next(iter(r.cells)))     # min_clusters=1


def test_autoscaler_watermarks_and_hooks():
    r = _router(max_clusters=4)
    r.observe("a", 0.95)                       # above scale_up_load=0.8
    r.observe("b", 0.05)                       # below scale_down_load=0.25
    supplied = []

    def provision(router, decision):
        name = f"new{len(supplied)}"
        supplied.append(name)
        return name, make_pi_cluster([1.0, 1.0])

    sc = Autoscaler(r, provision=provision,
                    decommission=lambda router, d: True,
                    metrics=MetricsRegistry())
    decisions = {d.cell: d for d in sc.evaluate()}
    assert decisions["a"].action == "scale_up" and decisions["a"].applied
    assert decisions["b"].action == "scale_down" and decisions["b"].applied
    assert supplied == ["new0"] and "new0" in r.cells
    assert "b" not in r.cells


def test_autoscaler_holds_in_band_and_respects_bounds():
    r = _router(max_clusters=2)
    r.observe("a", 0.5)
    r.observe("b", 0.95)
    sc = Autoscaler(r, provision=lambda rt, d: ("x", make_pi_cluster([1.0])),
                    metrics=MetricsRegistry())
    decisions = {d.cell: d for d in sc.evaluate()}
    assert decisions["a"].action == "hold"
    assert decisions["b"].action == "scale_up" and not decisions["b"].applied
    assert decisions["b"].detail == "at max_clusters"


# ---------------------------------------------------------------------------
# PlanSource provenance threading
# ---------------------------------------------------------------------------

def test_plan_source_validation_and_artifact_round_trip():
    plan = plan_with_spec(_MODELS[0].graph, _cluster4(),
                          _MODELS[0].input_size)
    with pytest.raises(ValueError):
        PicoPlan(plan.partition, plan.pipeline, source="cached")
    plan.source = "incremental"
    loaded = artifacts.plan_from_json(artifacts.plan_to_json(plan))
    assert loaded.source == "incremental"
    # pre-provenance artifacts (no "source" field) load as scratch
    d = artifacts.plan_to_dict(plan)
    d.pop("source")
    assert artifacts.plan_from_dict(d).source == "scratch"


def test_scheduler_repartition_audits_plan_sources():
    from repro.runtime import DeviceLeave
    from repro.serving import (OpenLoopGenerator, SchedulerConfig,
                               ServingScheduler, TenantConfig)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 1.0, 0.8, 0.8])
    tenants = [TenantConfig("a", _MODELS[0]), TenantConfig("b", _MODELS[2])]
    sched = ServingScheduler(tenants, cluster,
                             config=SchedulerConfig(
                                 seed=5, migration_bandwidth=1e9))
    wl = {}
    for i, ts in enumerate(sched._tenants.values()):
        rate = 0.6 / ts.share.pico.period
        wl[ts.cfg.name] = OpenLoopGenerator(rate_per_s=rate,
                                            seed=3 + i).generate(40)
    horizon = max(r.arrival for rs in wl.values() for r in rs)
    weakest = min(cluster.devices, key=lambda d: d.capacity)
    rep = sched.serve(wl, churn=[DeviceLeave(0.5 * horizon, weakest.name)])
    leaves = [r for r in rep.repartitions if r.reason == "leave"]
    assert leaves
    for r in leaves:
        assert set(r.plan_sources) == {"a", "b"}
        # surviving tenants re-plan on the warm path, never from scratch
        assert set(r.plan_sources.values()) <= {"incremental", "registry"}


def test_deployment_replan_is_incremental():
    import repro
    dep = repro.compile(_MODELS[0], make_pi_cluster([1.5, 1.2, 1.0, 0.8]))
    assert dep.pico.source == "scratch"
    dep2 = dep.replan(make_pi_cluster([1.5, 1.2, 1.0]))
    assert dep2.pico.source == "incremental"


# ---------------------------------------------------------------------------
# FleetSpec
# ---------------------------------------------------------------------------

def test_fleet_spec_validation_and_round_trip():
    spec = FleetSpec(registry_capacity=8, routing="round_robin",
                     scale_up_load=0.9, scale_down_load=0.1,
                     max_clusters=3)
    again = spec_from_dict(spec.to_dict())
    assert again == spec
    for bad in (dict(registry_capacity=0), dict(routing="random"),
                dict(ewma_beta=0.0), dict(ewma_beta=1.5),
                dict(scale_up_load=0.2, scale_down_load=0.3),
                dict(min_clusters=0), dict(min_clusters=3, max_clusters=2)):
        with pytest.raises(ValueError):
            FleetSpec(**bad)


# ---------------------------------------------------------------------------
# PlanStore: file-backed shared registry
# ---------------------------------------------------------------------------

def test_registry_file_store_shared_across_instances(tmp_path):
    """A plan persisted by one registry instance is a hit for a fresh
    instance pointed at the same directory — cross-process sharing with
    no coordination, and the served plan is exact."""
    from repro.fleet import PlanStore
    model, c = _MODELS[0], _cluster4()
    root = tmp_path / "store"
    r1 = PlanRegistry(store=root, metrics=MetricsRegistry())
    p1 = r1.get_or_plan(model, c)
    assert p1.source == "scratch" and len(r1.store) == 1
    r2 = PlanRegistry(store=PlanStore(root), metrics=MetricsRegistry())
    p2 = r2.get_or_plan(model, c)
    assert p2.source == "registry"
    assert r2.hits == 1 and r2.misses == 0
    assert _sig(p1) == _sig(p2)
    # a different content key stays a miss even with the store attached
    assert r2.get(model, c, PlanSpec(t_lim=0.123)) is None


def test_registry_store_survives_lru_eviction(tmp_path):
    """The store outlives the in-memory LRU horizon: an evicted entry
    is re-served from disk, not re-planned."""
    model = _MODELS[0]
    c1, c2, c3 = (make_pi_cluster([1.0] * n) for n in (2, 3, 4))
    reg = PlanRegistry(capacity=2, store=tmp_path, metrics=MetricsRegistry())
    for c in (c1, c2, c3):                     # c1 evicted from memory
        reg.get_or_plan(model, c)
    assert len(reg) == 2 and len(reg.store) == 3
    hit = reg.get_or_plan(model, c1)
    assert hit.source == "registry"


def test_plan_store_tolerates_corrupt_files(tmp_path):
    """Corrupt/foreign files in a shared directory read as misses —
    one bad writer must not poison every consumer."""
    from repro.fleet import PlanStore
    model, c = _MODELS[0], _cluster4()
    r1 = PlanRegistry(store=tmp_path, metrics=MetricsRegistry())
    r1.get_or_plan(model, c)
    for p in tmp_path.glob("*.json"):
        p.write_text("{ not json")
    (tmp_path / "foreign.json").write_text("{}")
    r2 = PlanRegistry(store=tmp_path, metrics=MetricsRegistry())
    assert r2.get(model, c) is None            # miss, never an error
    p2 = r2.get_or_plan(model, c)              # re-plans, re-publishes
    assert p2.source == "scratch"
    assert PlanStore(tmp_path).get(r2.key(model, c, PlanSpec())) is not None
    assert PlanStore(tmp_path).keys() == [r2.key(model, c, PlanSpec())]


def test_plan_store_atomic_publish_and_delete(tmp_path):
    from repro.fleet import PlanStore
    store = PlanStore(tmp_path)
    key = ("m", "c", "{}", "")
    store.put(key, {"plan": 1})
    assert key in store and store.get(key) == {"plan": 1}
    assert not list(tmp_path.glob("*.tmp"))    # temp files never linger
    store.put(key, {"plan": 2})                # overwrite is atomic too
    assert store.get(key) == {"plan": 2}
    assert store.delete(key) and key not in store
    assert not store.delete(key)


# ---------------------------------------------------------------------------
# FleetRouter.observe_report: real telemetry -> load-EWMA
# ---------------------------------------------------------------------------

def test_router_observe_report_serve_and_dist_shapes():
    r = _router()

    class FakeServe:                            # ServeReport-shaped
        device_busy_s = {"d0": 2.0, "d1": 1.0}
        makespan = 2.0

    class FakeDist:                             # DistReport-shaped
        def utilization(self):
            return 0.4

    first = r.observe_report("a", FakeServe())
    assert first == pytest.approx(0.75)         # 3.0 / (2 * 2.0)
    beta = r.spec.ewma_beta
    second = r.observe_report("a", FakeDist())
    assert second == pytest.approx(beta * 0.4 + (1 - beta) * 0.75)
    assert r.cell_load("a") == pytest.approx(second)

    class Saturated:
        def utilization(self):
            return 7.3                          # clamped before smoothing

    r2 = _router()
    assert r2.observe_report("b", Saturated()) == 1.0

    class Idle:                                 # zero makespan -> zero load
        device_busy_s = {}
        makespan = 0.0

    assert r2.observe_report("a", Idle()) == 0.0
    with pytest.raises(TypeError):
        r.observe_report("a", object())


def test_router_observe_report_steers_routing():
    """Telemetry-driven regression: the cell whose reports show load
    stops winning least_loaded placement."""
    r = _router()

    class Busy:
        def utilization(self):
            return 0.95

    class Quiet:
        def utilization(self):
            return 0.05

    for _ in range(5):
        r.observe_report("a", Busy())
        r.observe_report("b", Quiet())
    assert r.admit(Tenant("t0", _MODELS[0])).cell == "b"
