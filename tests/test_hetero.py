"""Algorithm 3 (`core.hetero.adjust_stages`) on asymmetric clusters."""

import pytest

from repro.core import (Cluster, Device, PipelineDP, make_pi_cluster,
                        partition_graph, plan, recost, simulate)
from repro.core.hetero import adjust_stages
from repro.models.cnn import zoo


def _homo_plan(m, cluster):
    part = partition_graph(m.graph, m.input_size, n_split=len(cluster))
    dp = PipelineDP(m.graph, part.pieces, cluster.homogenized(),
                    m.input_size)
    return dp.build()


@pytest.fixture(scope="module")
def small_model():
    return zoo.squeezenet(input_size=(96, 96), scale=0.1)


@pytest.mark.parametrize("freqs", [
    [2.0, 0.5, 0.5, 0.5],           # one dominant device
    [1.5, 1.4, 0.3, 0.2],           # two tiers
    [1.8, 1.0, 1.0, 0.9, 0.4, 0.3],  # six asymmetric devices
])
def test_adjust_assigns_every_device_once(small_model, freqs):
    m = small_model
    cluster = make_pi_cluster(freqs)
    adj = adjust_stages(_homo_plan(m, cluster), cluster, m.graph,
                        m.input_size)
    names = [d.name for st in adj.stages for d in st.devices]
    assert sorted(names) == sorted(d.name for d in cluster.devices)
    # slot counts survive the re-mapping
    assert sum(st.n_devices for st in adj.stages) == len(cluster)


@pytest.mark.parametrize("freqs", [
    [2.0, 0.5, 0.5, 0.5],
    [1.5, 1.4, 0.3, 0.2],
])
def test_adjust_fractions_proportional_to_capacity(small_model, freqs):
    m = small_model
    cluster = make_pi_cluster(freqs)
    adj = adjust_stages(_homo_plan(m, cluster), cluster, m.graph,
                        m.input_size)
    for st in adj.stages:
        assert abs(sum(st.fractions) - 1.0) < 1e-9
        total = sum(d.capacity for d in st.devices)
        for d, f in zip(st.devices, st.fractions):
            assert f == pytest.approx(d.capacity / total)


def test_adjust_strongest_device_gets_hottest_stage(small_model):
    m = small_model
    cluster = make_pi_cluster([2.0, 0.5, 0.5, 0.5])
    homo = _homo_plan(m, cluster)
    adj = adjust_stages(homo, cluster, m.graph, m.input_size)
    demand = [sum(st.cost.seg.per_device_flops) / max(st.n_devices, 1)
              for st in homo.stages]
    hottest = max(range(len(demand)), key=lambda i: demand[i])
    fastest = max(cluster.devices, key=lambda d: d.capacity)
    assert fastest.name in {d.name for d in adj.stages[hottest].devices}


def test_adjust_period_latency_consistent(small_model):
    m = small_model
    cluster = make_pi_cluster([1.8, 1.0, 1.0, 0.9, 0.4, 0.3])
    adj = adjust_stages(_homo_plan(m, cluster), cluster, m.graph,
                        m.input_size)
    totals = [st.cost.total for st in adj.stages]
    assert adj.period == pytest.approx(max(totals))
    assert adj.latency == pytest.approx(sum(totals))
    # the simulator reproduces the adjusted plan's steady-state period
    rep = simulate(adj, frames=48)
    assert rep.period == pytest.approx(adj.period, rel=1e-9)


def test_adjust_beats_equal_fractions_on_asymmetric_cluster(small_model):
    """Capacity-proportional tiling must not lose to a naive equal
    split of the same stage->device assignment."""
    m = small_model
    cluster = make_pi_cluster([2.0, 0.5, 0.5, 0.5])
    adj = adjust_stages(_homo_plan(m, cluster), cluster, m.graph,
                        m.input_size)
    equal = recost(
        _equalized(adj), cluster, m.graph, m.input_size)
    assert adj.period <= equal.period + 1e-12


def _equalized(plan_):
    from dataclasses import replace
    from repro.core.pipeline_dp import PipelinePlan, StagePlan
    stages = [StagePlan(st.first_piece, st.last_piece, list(st.devices),
                        st.nodes, st.cost,
                        [1.0 / st.n_devices] * st.n_devices)
              for st in plan_.stages]
    return PipelinePlan(stages, plan_.period, plan_.latency)


def test_adjust_raises_when_cluster_smaller_than_plan(small_model):
    """Regression: the seed silently filled unassigned stages with the
    homogenized *placeholder* devices (``devs or list(st.devices)``),
    producing a plan naming fictitious "avgN" devices.  A cluster with
    fewer devices than the plan has slots must fail loudly instead."""
    from repro.core.cost import stage_cost
    from repro.core.pipeline_dp import PipelinePlan, StagePlan
    m = small_model
    big = make_pi_cluster([1.0, 1.0, 1.0, 1.0])
    part = partition_graph(m.graph, m.input_size, n_split=4)
    full = m.graph.forward_sizes(m.input_size)
    homo = big.homogenized()
    # hand-build a 2-stage homogeneous plan (2 slots each) so the test
    # doesn't depend on what the DP happens to produce
    cut = len(part.pieces) // 2
    stages = []
    for i, (lo, hi) in enumerate([(0, cut - 1), (cut, len(part.pieces) - 1)]):
        nodes = frozenset().union(*(p.nodes for p in part.pieces[lo:hi + 1]))
        devs = homo.devices[2 * i: 2 * i + 2]
        sc = stage_cost(m.graph, nodes, full, m.input_size, devs, homo,
                        [0.5, 0.5])
        stages.append(StagePlan(lo, hi, list(devs), nodes, sc, [0.5, 0.5]))
    plan4 = PipelinePlan(stages, max(s.cost.total for s in stages),
                         sum(s.cost.total for s in stages))
    # 4 devices for 4 slots: fine
    adjust_stages(plan4, big, m.graph, m.input_size)
    # 1 device for 4 slots: the greedy fills the hottest stage and would
    # leave the other empty -> must raise, not leak placeholders
    tiny = make_pi_cluster([1.0])
    with pytest.raises(ValueError, match="received no devices"):
        adjust_stages(plan4, tiny, m.graph, m.input_size)


def test_full_plan_on_asymmetric_cluster_end_to_end(small_model):
    m = small_model
    cluster = Cluster([Device("big", 6e9), Device("mid", 2e9),
                       Device("tiny", 4e8)], bandwidth=50e6 / 8)
    p = plan(m.graph, cluster, m.input_size)
    assert p.period > 0 and p.latency >= p.period
    names = [d.name for st in p.pipeline.stages for d in st.devices]
    assert sorted(names) == ["big", "mid", "tiny"]
