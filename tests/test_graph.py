"""Unit + property tests for the graph IR and receptive-field math."""

from _hypothesis_compat import given, settings, st

from repro.core.graph import (Graph, LayerSpec, tile_widths,
                              proportional_widths)


def chain_graph(specs):
    g = Graph()
    prev = None
    for s in specs:
        g.add(s, [prev] if prev else [])
        prev = s.name
    return g


def test_out_in_maps_roundtrip():
    spec = LayerSpec("c", "conv", (3, 3), (2, 2), (0, 0), 4, 8)
    out = spec.out_size((31, 17))
    assert out == ((31 - 3) // 2 + 1, (17 - 3) // 2 + 1)
    needed = spec.in_size_for(out, (31, 17))
    assert needed[0] <= 31 and needed[1] <= 17
    # exact inverse when stride divides
    spec1 = LayerSpec("c1", "conv", (3, 3), (1, 1), (0, 0), 4, 8)
    assert spec1.in_size_for(spec1.out_size((30, 30)), (30, 30)) == (30, 30)


def test_padded_out_size():
    spec = LayerSpec("c", "conv", (3, 3), (1, 1), (1, 1), 4, 8)
    assert spec.out_size((32, 32)) == (32, 32)  # SAME


def test_global_rf():
    spec = LayerSpec("f", "fc", in_channels=10, out_channels=5)
    assert spec.global_rf
    assert spec.in_size_for((1, 1), (17, 13)) == (17, 13)


def test_forward_sizes_and_width():
    g = Graph()
    g.add(LayerSpec("a", "conv", (3, 3), (1, 1), (0, 0), 3, 8))
    g.add(LayerSpec("b1", "conv", (1, 1), (1, 1), (0, 0), 8, 8), ["a"])
    g.add(LayerSpec("b2", "conv", (3, 3), (1, 1), (1, 1), 8, 8), ["a"])
    g.add(LayerSpec("cat", "concat", in_channels=16, out_channels=16),
          ["b1", "b2"])
    fs = g.forward_sizes((16, 16))
    assert fs["a"] == (14, 14)
    assert fs["b1"] == (14, 14) and fs["b2"] == (14, 14)
    assert fs["cat"] == (14, 14)
    assert g.width() == 2
    assert g.sources() == ["a"]
    assert g.sinks() == ["cat"]


def test_sinks_definition3():
    # mid-segment vertex with an outside consumer is a sink (Def. 3)
    g = Graph()
    g.add(LayerSpec("a", "conv", (1, 1), (1, 1), (0, 0), 3, 4))
    g.add(LayerSpec("b", "conv", (1, 1), (1, 1), (0, 0), 4, 4), ["a"])
    g.add(LayerSpec("c", "add", in_channels=4, out_channels=4), ["a", "b"])
    assert set(g.sinks({"a", "b"})) == {"a", "b"}


def test_required_ranges_exactness_chain():
    g = chain_graph([
        LayerSpec("c1", "conv", (3, 3), (1, 1), (1, 1), 3, 4),
        LayerSpec("p1", "pool", (2, 2), (2, 2), (0, 0), 4, 4),
        LayerSpec("c2", "conv", (5, 5), (1, 1), (2, 2), 4, 8),
    ])
    fs = g.forward_sizes((32, 32))
    ro, ri = g.required_ranges(set(g.layers), {"c2": (4, 10)}, fs, (32, 32))
    assert ro["c2"] == (4, 10)
    # c2 input (padded coords): [4*1-2, 9*1+5-2) = [2, 12)
    assert ri["c2"] == (2, 12)
    assert ro["p1"] == (2, 12)
    assert ri["p1"] == (4, 24)
    assert ro["c1"] == (4, 24)


def test_tile_widths():
    assert tile_widths(10, 3) == [4, 3, 3]
    assert tile_widths(2, 5) == [1, 1]
    assert sum(tile_widths(224, 7)) == 224


def test_proportional_widths():
    w = proportional_widths(100, [3, 1])
    assert sum(w) == 100 and w[0] > w[1]
    assert proportional_widths(2, [1.0, 1.0, 1.0]).count(1) == 2


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.tuples(st.integers(1, 5), st.integers(1, 2),
                       st.integers(0, 2)), min_size=1, max_size=6),
    st.integers(20, 60),
    st.integers(1, 4),
)
def test_ranges_cover_demand_property(layers, width, parts):
    """Property: for any chain and tile split, per-tile required ranges
    are within bounds and the union of assigned sink tiles covers the
    sink output exactly."""
    specs = []
    cin = 3
    for i, (k, s, p) in enumerate(layers):
        specs.append(LayerSpec(f"l{i}", "conv", (k, k), (s, s), (p, p),
                               cin, 4))
        cin = 4
    g = chain_graph(specs)
    fs = g.forward_sizes((width, width))
    sink = g.sinks()[0]
    W = fs[sink][0]
    if W < parts:
        return
    widths = tile_widths(W, parts)
    start = 0
    covered = []
    for w in widths:
        ro, ri = g.required_ranges(set(g.layers),
                                   {sink: (start, start + w)}, fs,
                                   (width, width))
        assert ro[sink] == (start, start + w)
        for n, (a, b) in ri.items():
            assert 0 <= a <= b
        covered.append((start, start + w))
        start += w
    assert covered[0][0] == 0 and covered[-1][1] == W
