"""`repro.exec`: backend registry, segment compiler, executable cache,
scan micro-batching, and cost calibration feeding the planner."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as rexec
from repro.core import CostTable, make_pi_cluster, plan, recost, replan
from repro.models.cnn import zoo
from repro.models.cnn.builder import GB
from repro.pipeline import PipelineRunner
from repro.pipeline.stage import StageExecutor


@pytest.fixture(autouse=True)
def _fresh_cache():
    rexec.clear_cache()
    yield
    rexec.clear_cache()


def _small_model():
    b = GB("small", (24, 24))
    x = b.conv(None, 8, 3, p=1)
    x = b.conv(x, 8, 3, p=1)
    x = b.pool(x, 2, 2)
    x = b.conv(x, 16, 3, p=1)
    return b.done()


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

def test_registry_contents_and_unknown_backend():
    assert set(rexec.available_backends()) >= {"xla", "pallas"}
    with pytest.raises(ValueError, match="unknown exec backend"):
        rexec.get_backend("cudnn")


def test_custom_backend_is_dispatched():
    calls = []

    def traced(spec, p, x, pad_w):
        calls.append(spec.name)
        return rexec.get_backend("xla")(spec, p, x, pad_w)

    rexec.register_backend("traced", traced)
    try:
        m = _small_model()
        m.backend = "traced"
        params = m.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 24, 3))
        ref = m.forward(params, x, backend="xla")
        out = m.forward(params, x)
        for k in ref:
            np.testing.assert_array_equal(np.asarray(out[k]),
                                          np.asarray(ref[k]))
        assert len(calls) == 3            # every conv went through it
    finally:
        rexec.backends._REGISTRY.pop("traced", None)


def test_backend_resolution_order():
    m = _small_model()
    m.backend = "pallas"
    ex = StageExecutor(m, frozenset(m.graph.layers), [0.5, 0.5])
    assert ex.backend == "pallas"         # model default wins over registry
    ex2 = StageExecutor(m, frozenset(m.graph.layers), [0.5, 0.5],
                        backend="xla")
    assert ex2.backend == "xla"           # explicit arg wins over model


# ---------------------------------------------------------------------------
# executable cache
# ---------------------------------------------------------------------------

def test_cache_hit_on_identical_stage_and_rebuilt_model():
    m = _small_model()
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 24, 3))
    ex = StageExecutor(m, frozenset(m.graph.layers), [0.5, 0.5])
    ex(params, {}, x)
    s = rexec.cache_stats()
    assert (s.misses, s.hits) == (1, 0)
    ex(params, {}, x)                     # same executor: hit
    # a *rebuilt* identical model + fresh executor: still a hit (the key
    # is the segment signature, not object identity)
    m2 = _small_model()
    StageExecutor(m2, frozenset(m2.graph.layers), [0.5, 0.5])(params, {}, x)
    s = rexec.cache_stats()
    assert (s.misses, s.hits) == (1, 2)


def test_cache_miss_on_shape_or_tiling_change():
    m = _small_model()
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 24, 3))
    nodes = frozenset(m.graph.layers)
    StageExecutor(m, nodes, [0.5, 0.5])(params, {}, x)
    StageExecutor(m, nodes, [0.75, 0.25])(params, {}, x)   # tiling differs
    StageExecutor(m, nodes, [0.5, 0.5])(
        params, {}, jax.random.normal(jax.random.PRNGKey(2), (2, 24, 24, 3)))
    s = rexec.cache_stats()
    assert s.misses == 3 and s.hits == 0


def test_cache_eviction_bound():
    rexec.set_cache_size(2)
    try:
        m = _small_model()
        params = m.init(jax.random.PRNGKey(0))
        nodes = frozenset(m.graph.layers)
        for n in (1, 2, 3):
            x = jax.random.normal(jax.random.PRNGKey(1), (n, 24, 24, 3))
            StageExecutor(m, nodes, [0.5, 0.5])(params, {}, x)
        s = rexec.cache_stats()
        assert s.entries == 2 and s.evictions == 1
    finally:
        rexec.set_cache_size(256)


# ---------------------------------------------------------------------------
# compiler: scan micro-batching + donation flag
# ---------------------------------------------------------------------------

def test_run_frames_matches_per_frame_calls():
    m = zoo.squeezenet(input_size=(48, 48), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.0])
    p = plan(m.graph, cluster, m.input_size)
    params = m.init(jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(1), (5, 1, 48, 48, 3))
    runner = PipelineRunner(m, p.pipeline)
    stacked = runner.run_frames(params, frames)
    for f in range(5):
        one = runner(params, frames[f])
        for k, v in one.items():
            np.testing.assert_array_equal(np.asarray(stacked[k][f]),
                                          np.asarray(v))
    # the eager oracle honors run_frames too (loops + stacks)
    eager_stacked = PipelineRunner(m, p.pipeline,
                                   mode="eager").run_frames(params, frames)
    for k in stacked:
        np.testing.assert_array_equal(np.asarray(stacked[k]),
                                      np.asarray(eager_stacked[k]))


def test_compile_stage_direct_and_donation_cpu_noop():
    m = _small_model()
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 24, 3))
    cs = rexec.compile_stage(m, frozenset(m.graph.layers), [0.5, 0.5],
                             donate=True)
    if jax.default_backend() == "cpu":
        assert cs.donate is False         # CPU can't alias; flag is dropped
    out = cs(params, {k: x for k in cs.needs})
    ref = m.forward(params, x)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# calibration -> CostTable -> planner
# ---------------------------------------------------------------------------

def test_cost_table_lookup_and_fallback():
    key = frozenset({"conv1"})
    t = CostTable({key: 2.0})
    assert t.ratio({"conv1"}) == 2.0
    assert t.ratio({"convX"}) == 2.0      # mean fallback
    t2 = CostTable({key: 2.0}, default=1.5)
    assert t2.ratio({"convX"}) == 1.5
    assert CostTable().ratio({"a"}) == 1.0


def test_cost_table_scales_plan_costs():
    m = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    base = plan(m.graph, cluster, m.input_size)
    double = CostTable(default=2.0)
    rc = recost(base.pipeline, cluster, m.graph, m.input_size,
                cost_table=double)
    for st, st2 in zip(base.pipeline.stages, rc.stages):
        assert st2.cost.t_comp == pytest.approx(2.0 * st.cost.t_comp)
        assert st2.cost.t_comm == pytest.approx(st.cost.t_comm)


def test_calibrate_plan_produces_usable_table():
    m = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    params = m.init(jax.random.PRNGKey(0))
    p = plan(m.graph, cluster, m.input_size)
    rep = rexec.calibrate_plan(m, params, p.pipeline.stages, iters=1)
    assert rep.host_flops > 0
    assert len(rep.stages) == len(p.pipeline.stages)
    for s in rep.stages:
        assert s.measured_s > 0
    table = rep.table()
    p2 = replan(m.graph, cluster, m.input_size, prev=p, cost_table=table)
    assert p2.period > 0
    # measured ratios shift the modeled period away from pure analytic
    assert p2.period != pytest.approx(p.period)
