"""Multi-device tests run in subprocesses (XLA device count must be set
before jax initializes, so these cannot share the main test process)."""

import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_py(code: str, devices: int = 8, timeout: int = 600):
    env = {
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
        "PYTHONPATH": str(ROOT / "src"),
        "PATH": "/usr/bin:/bin:/usr/local/bin",
        "HOME": "/tmp",
    }
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert res.returncode == 0, res.stderr[-4000:]
    return res.stdout


def test_microbatch_pipeline_exact():
    """GPipe-style shard_map pipeline == sequential composition."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.pipeline.runner import microbatch_pipeline
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4,), ("stage",))
        ws = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 16)) * 0.1
        xs = jax.random.normal(jax.random.PRNGKey(1), (6, 2, 16))
        fn = lambda sid, w, x: jnp.tanh(x @ w)
        out = microbatch_pipeline(fn, ws, xs, mesh, axis="stage")
        ref = xs
        for i in range(4):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_single_combo():
    """The real dry-run path compiles on a small host mesh."""
    out = run_py("""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=512"
        import tempfile
        from pathlib import Path
        from repro.launch.dryrun import run_combo
        with tempfile.TemporaryDirectory() as d:
            rec = run_combo("llama3.2-1b", "decode_32k", False,
                            Path(d), force=True)
        assert rec["ok"], rec.get("error")
        assert rec["roofline"]["flops"] > 0
        print("OK", rec["roofline"]["dominant"])
    """, devices=512, timeout=900)
    assert "OK" in out


def test_sharded_train_step():
    """train_step runs (not just lowers) on an 8-device host mesh with
    the production sharding rules."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.models.transformer import model as M
        from repro.training.optim import AdamW
        from repro.training.steps import make_train_step
        from repro.launch.sharding import param_pspecs, batch_pspecs
        from repro.launch.mesh import make_mesh, make_test_mesh

        cfg = configs.get("llama3.2-1b").reduced(n_layers=2, d_model=128)
        mesh = make_mesh((2, 4), ("data", "model"))
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        state = opt.init(params)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        pspec = param_pspecs(cfg, params, mesh)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                              is_leaf=lambda x: isinstance(x, P))
        params = jax.device_put(params, pshard)
        with mesh:
            step = jax.jit(make_train_step(cfg, opt))
            p2, s2, loss = step(params, state, batch)
        assert np.isfinite(float(loss))
        # matches the unsharded single-device step
        params_cpu = jax.device_get(params)
        step1 = jax.jit(make_train_step(cfg, opt))
        _, _, loss1 = step1(params_cpu, opt.init(params_cpu), batch)
        np.testing.assert_allclose(float(loss), float(loss1), rtol=1e-4)
        print("OK", float(loss))
    """)
    assert "OK" in out


def test_ring_attention_exact():
    """Sequence-parallel ring attention == blockwise reference."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.transformer.ring_attention import ring_attention
        from repro.models.transformer.layers import \\
            blockwise_causal_attention
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        for (b, s, k, g, d, w) in [(2, 64, 2, 2, 16, 0),
                                   (1, 128, 1, 4, 32, 0),
                                   (2, 64, 2, 1, 16, 24)]:
            q = jax.random.normal(jax.random.PRNGKey(0), (b, s, k, g, d))
            kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, k, d))
            vv = jax.random.normal(jax.random.PRNGKey(2), (b, s, k, d))
            out = ring_attention(q, kk, vv, mesh, axis="model",
                                 sliding_window=w)
            ref = blockwise_causal_attention(q, kk, vv, sliding_window=w,
                                             q_block=16, kv_block=16)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=2e-4, atol=2e-4)
        print("OK")
    """)
    assert "OK" in out
