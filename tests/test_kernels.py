"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv2d.ops import (conv2d, conv2d_fused, fallback_count,
                                      reset_fallbacks)
from repro.kernels.conv2d.ref import conv2d_fused_ref, conv2d_ref
from repro.kernels.attention.ops import decode_attention
from repro.kernels.attention.ref import decode_attention_ref
from repro.kernels.ssd.ops import ssd_chunk
from repro.kernels.ssd.ref import ssd_chunk_ref
from repro.models.transformer import layers as L

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 8, 8, 8, 16, 3, 3),
    (2, 12, 10, 16, 32, 1, 1),
    (1, 9, 9, 32, 8, 5, 5),
    (2, 16, 16, 128, 128, 3, 3),
    (1, 10, 8, 8, 16, 7, 1),
    (1, 8, 10, 8, 8, 1, 7),
])
def test_conv2d_sweep(shape, dtype):
    n, h, w, ci, co, kh, kw = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (n, h, w, ci), dtype)
    wt = (jax.random.normal(jax.random.PRNGKey(1), (kh, kw, ci, co),
                            dtype) / np.sqrt(kh * kw * ci)).astype(dtype)
    out = conv2d(x, wt, interpret=True)
    ref = conv2d_ref(x, wt)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("stride", [(2, 2), (3, 2), 2])
@pytest.mark.parametrize("shape", [
    (1, 9, 9, 8, 16, 3, 3),
    (2, 12, 11, 16, 8, 3, 3),
    (1, 15, 15, 3, 10, 7, 7),    # zoo-style 7x7 stem
    (1, 14, 14, 13, 11, 1, 1),   # 1x1 projection, channel tails
])
def test_conv2d_strided_sweep(shape, stride):
    """Strided convs run the Pallas kernel directly — no fallback."""
    n, h, w, ci, co, kh, kw = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (n, h, w, ci), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (kh, kw, ci, co),
                           jnp.float32) / np.sqrt(kh * kw * ci)
    reset_fallbacks()
    out = conv2d(x, wt, stride=stride, interpret=True)
    st = (stride, stride) if isinstance(stride, int) else stride
    ref = conv2d_ref(x, wt, st)
    assert fallback_count() == 0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **TOL[jnp.float32])


@pytest.mark.parametrize("blocks", [(8, 8), (16, 32), (128, 128)])
@pytest.mark.parametrize("shape", [
    (1, 8, 8, 5, 7, 3, 3),       # tails on both axes
    (2, 10, 10, 13, 26, 3, 3),
    (1, 9, 9, 130, 3, 1, 1),     # tail past one 128 block
])
def test_conv2d_channel_tail_blocks(shape, blocks):
    """Non-MXU-aligned channel counts run under any block size: the
    wrapper zero-pads the tail block instead of degrading the tile."""
    n, h, w, ci, co, kh, kw = shape
    bci, bco = blocks
    x = jax.random.normal(jax.random.PRNGKey(0), (n, h, w, ci), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (kh, kw, ci, co),
                           jnp.float32) / np.sqrt(kh * kw * ci)
    out = conv2d(x, wt, block_ci=bci, block_co=bco, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(conv2d_ref(x, wt)),
                               **TOL[jnp.float32])


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("pool", [None, (2, 2)])
@pytest.mark.parametrize("shape", [
    (1, 12, 12, 6, 6, 3, 3, (1, 1)),
    (1, 13, 13, 5, 7, 3, 3, (1, 1)),    # odd conv output + pool floor
    (2, 17, 15, 8, 8, 3, 3, (2, 2)),    # strided conv + pool
])
def test_conv2d_fused_epilogue(shape, relu, pool):
    """Fused bias+relu(+pool) inside the kernel == composed oracle."""
    n, h, w, ci, co, kh, kw, stride = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (n, h, w, ci), jnp.float32)
    wt = jax.random.normal(jax.random.PRNGKey(1), (kh, kw, ci, co),
                           jnp.float32) / np.sqrt(kh * kw * ci)
    b = jax.random.normal(jax.random.PRNGKey(2), (co,), jnp.float32)
    out = conv2d_fused(x, wt, b, stride=stride, relu=relu, pool=pool,
                       interpret=True)
    ref = conv2d_fused_ref(x, wt, b, stride=stride, relu=relu, pool=pool)
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               **TOL[jnp.float32])


def test_conv2d_stride_normalization_and_validation():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 8, 4))
    wt = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 4)) * 0.1
    np.testing.assert_array_equal(
        np.asarray(conv2d(x, wt, stride=2, interpret=True)),
        np.asarray(conv2d(x, wt, stride=(2, 2), interpret=True)))
    with pytest.raises(ValueError, match="stride"):
        conv2d(x, wt, stride=0, interpret=True)


def test_reset_fallbacks_scopes_accounting_per_run():
    """reset_fallbacks() zeroes the counter AND the warn-once set, so a
    scoped run both counts from zero and re-warns."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 2, 4))
    wt = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 4))  # H < KH
    reset_fallbacks()
    with pytest.warns(RuntimeWarning):
        conv2d(x, wt, interpret=True)
    assert fallback_count() == 1
    reset_fallbacks()
    assert fallback_count() == 0
    with pytest.warns(RuntimeWarning):   # warn-once set was cleared too
        conv2d(x, wt, interpret=True)
    assert fallback_count() == 1
    reset_fallbacks()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (2, 2, 4, 16, 64, 64),
    (1, 8, 1, 32, 128, 100),
    (2, 1, 8, 64, 256, 7),
    (3, 4, 2, 8, 32, 32),
    (1, 2, 2, 128, 512, 511),
])
def test_decode_attention_sweep(shape, dtype):
    b, k, g, d, s, vl = shape
    q = jax.random.normal(jax.random.PRNGKey(0), (b, k, g, d), dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, k, d), dtype)
    vv = jax.random.normal(jax.random.PRNGKey(2), (b, s, k, d), dtype)
    out = decode_attention(q, kk, vv, jnp.int32(vl), interpret=True)
    ref = decode_attention_ref(q, kk, vv, jnp.int32(vl))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("shape", [
    (2, 16, 2, 16, 8),
    (1, 64, 4, 32, 16),
    (3, 32, 1, 8, 128),
    (2, 128, 2, 64, 64),
])
def test_ssd_chunk_sweep(shape, dtype):
    bc, q, h, p, n = shape
    x = (jax.random.normal(jax.random.PRNGKey(0), (bc, q, h, p)) * 0.5
         ).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (bc, q, h))).astype(dtype)
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3
                 ).astype(dtype)
    Bm = (jax.random.normal(jax.random.PRNGKey(3), (bc, q, n)) * 0.3
          ).astype(dtype)
    Cm = (jax.random.normal(jax.random.PRNGKey(4), (bc, q, n)) * 0.3
          ).astype(dtype)
    y, st = ssd_chunk(x, dt, A, Bm, Cm, interpret=True)
    yr, sr = ssd_chunk_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(st, np.float32),
                               np.asarray(sr, np.float32), **TOL[dtype])


def test_ssd_kernel_composes_with_interchunk_scan():
    """kernel intra-chunk + jnp inter-chunk == ssd_chunked reference."""
    Bz, Sq, H, P, N, Q = 1, 32, 2, 8, 16, 8
    x = jax.random.normal(jax.random.PRNGKey(0), (Bz, Sq, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                           (Bz, Sq, H)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.PRNGKey(3), (Bz, Sq, N)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(4), (Bz, Sq, N)) * 0.3
    D = jnp.zeros((H,))
    y_ref, h_ref = L.ssd_chunked(x, dt, A, Bm, Cm, D, chunk=Q)

    nc = Sq // Q
    xr = x.reshape(Bz * nc, Q, H, P)
    dtr = dt.reshape(Bz * nc, Q, H)
    Br = Bm.reshape(Bz * nc, Q, N)
    Cr = Cm.reshape(Bz * nc, Q, N)
    y_in, st = ssd_chunk(xr, dtr, A, Br, Cr, interpret=True)
    y_in = y_in.reshape(Bz, nc, Q, H, P)
    st = st.reshape(Bz, nc, H, P, N)
    # inter-chunk recurrence in jnp
    a = (dt * A).reshape(Bz, nc, Q, H)
    cum = jnp.cumsum(a, axis=2)
    cd = jnp.exp(cum[:, :, -1, :])
    h = jnp.zeros((Bz, H, P, N))
    y_tot = []
    for c in range(nc):
        y_inter = jnp.einsum("bqn,bhpn->bqhp", Cr.reshape(
            Bz, nc, Q, N)[:, c], h) * jnp.exp(cum[:, c])[..., None]
        y_tot.append(y_in[:, c] + y_inter)
        h = h * cd[:, c][..., None, None] + st[:, c]
    y = jnp.concatenate(y_tot, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [
    (1, 64, 2, 2, 16, 0),
    (2, 128, 1, 4, 32, 0),
    (1, 256, 2, 1, 64, 0),
    (1, 128, 2, 2, 16, 32),   # sliding window
])
def test_flash_prefill_sweep(shape):
    from repro.kernels.attention.flash_prefill import flash_prefill
    b, s, k, g, d, w = shape
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, k, g, d))
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, k, d))
    vv = jax.random.normal(jax.random.PRNGKey(2), (b, s, k, d))
    out = flash_prefill(q, kk, vv, sliding_window=w, interpret=True)
    ref = L.blockwise_causal_attention(q, kk, vv, sliding_window=w,
                                       q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (4, 16, 32, 64),     # E, C, D, F
    (8, 128, 64, 128),
    (3, 8, 512, 16),
    (40, 4, 24, 8),      # granite-like expert count
])
def test_moe_gemm_sweep(shape, dtype):
    from repro.kernels.moe_gemm.ops import moe_gemm
    from repro.kernels.moe_gemm.ref import moe_gemm_ref
    e, c, d, f = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (e, c, d), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (e, d, f), dtype)
         / np.sqrt(d)).astype(dtype)
    out = moe_gemm(x, w, interpret=True)
    ref = moe_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_conv_kernel_integrates_with_cnn_zoo():
    """The Pallas conv kernel drops into the executable zoo and the
    pipelined stage executor unchanged (system <-> kernel integration).
    The backend is selected explicitly per model/executor — no module
    global (the seed's `set_conv_backend` is deprecated)."""
    from repro.models.cnn import zoo
    from repro.pipeline.stage import StageExecutor
    m = zoo.vgg16(input_size=(40, 40), scale=0.1, head=False)
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 40, 40, 3))
    ref = m.forward(params, x)
    out = m.forward(params, x, backend="pallas")
    ex = StageExecutor(m, frozenset(m.graph.layers), [0.5, 0.5],
                       backend="pallas")
    tiled = ex(params, {}, x)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(tiled[k]),
                                   np.asarray(ref[k]),
                                   rtol=2e-5, atol=2e-5)
