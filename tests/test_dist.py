"""repro.dist: wire codec, transports, the launcher's loss accounting,
churn drill, and oracle validation (distributed == single-process,
bit-for-bit)."""

import json
import struct
import threading

import numpy as np
import pytest

import repro
from repro.api.specs import DistSpec
from repro.core import make_pi_cluster
from repro.dist import (Message, TCPListener, TCPTransport, decode, encode,
                        make_frames, memory_pair, validate)
from repro.dist.validate import reference_outputs
from repro.fleet import FleetRouter
from repro.api import FleetSpec
from repro.models.cnn import zoo
from repro.obs.metrics import MetricsRegistry
from repro.runtime.churn import DeviceLeave


def _cluster():
    return make_pi_cluster([1.5, 1.2, 1.0], bandwidth_mbps=50.0)


@pytest.fixture(scope="module")
def sq_dep():
    model = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    return repro.compile(model, _cluster())


# ---------------------------------------------------------------------------
# DistSpec
# ---------------------------------------------------------------------------

def test_dist_spec_json_round_trip():
    spec = DistSpec(transport="tcp", workers="process", heartbeat_s=0.1,
                    micro_batch=3, chunk_bytes=4096, seed=7, trace=False)
    assert DistSpec.from_json(spec.to_json()) == spec
    # Deployment-style nested payload decode
    assert DistSpec.from_dict(json.loads(spec.to_json())) == spec


def test_dist_spec_validation():
    with pytest.raises(ValueError):
        DistSpec(transport="carrier-pigeon")
    with pytest.raises(ValueError):
        DistSpec(workers="fiber")
    with pytest.raises(ValueError):            # spawn shares no memory
        DistSpec(workers="process", transport="memory")
    with pytest.raises(ValueError):
        DistSpec(heartbeat_s=0.0)
    with pytest.raises(ValueError):            # timeout must exceed beacon
        DistSpec(heartbeat_s=1.0, peer_timeout_s=0.5)
    with pytest.raises(ValueError):
        DistSpec(micro_batch=0)
    with pytest.raises(ValueError):
        DistSpec(chunk_bytes=16)


# ---------------------------------------------------------------------------
# wire codec (satellite: zero-length tensors, large framed payloads)
# ---------------------------------------------------------------------------

def _round_trip(msg):
    wire = encode(msg)               # u64 length prefix | framed body
    (n,) = struct.unpack_from("<Q", wire)
    assert n == len(wire) - 8
    return decode(wire[8:])


def test_codec_round_trip_exact():
    msg = Message("frame", [3, 4],
                  {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
                   "b": np.array([[True, False]]),
                   "__image__": np.zeros((1, 2, 2, 3), np.float32)},
                  {"warmup": False, "note": "x"})
    got = _round_trip(msg)
    assert got.kind == "frame" and got.fids == [3, 4]
    assert got.meta == msg.meta
    for k, v in msg.tensors.items():
        assert got.tensors[k].dtype == v.dtype
        assert np.array_equal(got.tensors[k], v)


def test_codec_zero_length_tensor():
    msg = Message("result", [0], {"empty": np.zeros((0, 5), np.float32),
                                  "scalar": np.float32(2.5).reshape(())})
    got = _round_trip(msg)
    assert got.tensors["empty"].shape == (0, 5)
    assert got.tensors["scalar"].shape == ()
    assert float(got.tensors["scalar"]) == 2.5


def test_codec_no_tensors():
    got = _round_trip(Message("heartbeat", meta={"worker": "w0"}))
    assert got.kind == "heartbeat" and got.tensors == {}


def test_codec_rejects_garbage():
    with pytest.raises(ValueError):
        decode(b"\x00" * 32)


def test_memory_transport_carries_encoded_bytes():
    s, r = memory_pair("t", chunk_bytes=64, metrics=MetricsRegistry())
    payload = np.arange(1000, dtype=np.float32)
    s.send(Message("frame", [1], {"x": payload}))
    got = r.recv(timeout=1.0)
    assert np.array_equal(got.tensors["x"], payload)
    assert s.bytes_sent == r.bytes_recv > payload.nbytes
    assert s.sends == 1 and r.recvs == 1
    assert r.recv(timeout=0.05) is None        # timeout -> None, not error
    s.close()
    with pytest.raises(ConnectionError):       # peer closed -> recv raises
        r.recv(timeout=1.0)


def test_tcp_transport_large_chunked_payload():
    """>64 MB framed tensor moves intact through chunked TCP sends."""
    big = np.random.default_rng(0).integers(
        0, 255, size=(17, 1024, 1024), dtype=np.uint8)   # 17 MB * 4 shapes
    big = np.stack([big] * 4)                            # 68 MB
    assert big.nbytes > (1 << 26)
    lst = TCPListener()
    out = {}

    def rx():
        r = lst.accept(link="big", chunk_bytes=1 << 20, timeout=30.0)
        out["msg"] = r.recv(timeout=60.0)
        r.close()

    t = threading.Thread(target=rx, daemon=True)
    t.start()
    s = TCPTransport.connect(lst.addr, link="big", chunk_bytes=1 << 20,
                             timeout=30.0)
    s.send(Message("frame", [0], {"big": big}))
    t.join(timeout=120.0)
    s.close()
    lst.close()
    got = out["msg"].tensors["big"]
    assert got.dtype == np.uint8 and np.array_equal(got, big)


def test_tcp_recv_timeout_preserves_framing():
    """A timed-out recv must not corrupt the stream: the same frame is
    still delivered whole by the next call."""
    lst = TCPListener()
    conn = {}
    t = threading.Thread(
        target=lambda: conn.setdefault(
            "r", lst.accept(link="x", timeout=10.0)),
        daemon=True)
    t.start()
    s = TCPTransport.connect(lst.addr, link="x", timeout=10.0)
    t.join(timeout=10.0)
    r = conn["r"]
    assert r.recv(timeout=0.05) is None        # nothing sent yet
    s.send(Message("frame", [9], {"v": np.ones(4, np.float32)}))
    got = r.recv(timeout=5.0)
    assert got.fids == [9] and np.array_equal(got.tensors["v"],
                                              np.ones(4, np.float32))
    s.close()
    r.close()
    lst.close()


# ---------------------------------------------------------------------------
# launcher: oracle validation across zoo models / transports
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,scale", [
    ("squeezenet", 0.1), ("mobilenetv3", 0.25), ("resnet34", 0.1)])
def test_validate_zoo_models_bit_identical(name, scale):
    model = zoo.build(name, input_size=(64, 64), scale=scale)
    dep = repro.compile(model, _cluster())
    v = validate(dep, DistSpec(), frames=4)
    assert v.ok, v.describe()
    assert v.bit_identical and v.max_abs_diff == 0.0
    assert v.dropped == 0
    assert v.ratios and all(r > 0 for r in v.ratios.values())


def test_validate_tcp_micro_batch(sq_dep):
    v = validate(sq_dep, DistSpec(transport="tcp", micro_batch=2), frames=4)
    assert v.ok, v.describe()


def test_tcp_and_memory_byte_identical():
    """Same frames through both transports on two zoo models: outputs
    byte-identical (one shared wire codec, one compiled path)."""
    for name, scale in (("squeezenet", 0.1), ("mobilenetv3", 0.25)):
        model = zoo.build(name, input_size=(64, 64), scale=scale)
        dep = repro.compile(model, _cluster())
        xs = make_frames(model, 3)
        mem = dep.fleet(DistSpec(transport="memory")).run(xs)
        tcp = dep.fleet(DistSpec(transport="tcp")).run(xs)
        assert not mem.dropped and not tcp.dropped
        for fid in range(len(xs)):
            for sink, arr in mem.outputs[fid].items():
                assert arr.tobytes() == tcp.outputs[fid][sink].tobytes()


def test_report_accounting_and_telemetry(sq_dep):
    metrics = MetricsRegistry()
    launcher = sq_dep.fleet(DistSpec(), metrics=metrics)
    rep = launcher.run(make_frames(sq_dep.model, 4))
    assert rep.submitted == 4 and rep.completed == 4 and not rep.dropped
    assert rep.n_stages == len(sq_dep.pico.pipeline.stages)
    # per-worker stats made it back over the control links
    assert set(rep.worker_stats) == {f"w{i}" for i in range(rep.n_stages)}
    for st in rep.worker_stats.values():
        assert st["frames"] == 4 and st["compute_s"] > 0
        assert st["dead"] is None
    assert 0.0 < rep.utilization() <= 1.0
    # link byte/latency accounting reached the metrics registry
    snap = metrics.snapshot()["payload"]
    assert any(c["name"] == "dist.link.bytes_sent"
               for c in snap["counters"])
    # ...and the report feeds the fleet's load-EWMA directly
    router = FleetRouter({"cell": _cluster()}, spec=FleetSpec(),
                         metrics=MetricsRegistry())
    assert router.observe_report("cell", rep) == pytest.approx(
        rep.utilization())


# ---------------------------------------------------------------------------
# shutdown: zero silent loss
# ---------------------------------------------------------------------------

def test_clean_shutdown_drains_all_inflight(sq_dep):
    """Frames submitted but not yet collected all complete during the
    drain — the stop rides behind them on FIFO links."""
    launcher = sq_dep.fleet(DistSpec(max_inflight=16))
    launcher.start()
    xs = make_frames(sq_dep.model, 5)
    for f in xs:
        launcher.submit(f)
    rep = launcher.shutdown()          # immediate: everything in flight
    assert rep.submitted == 5
    assert rep.completed == 5 and not rep.dropped
    assert rep.completed + len(rep.dropped) == rep.submitted
    ref = reference_outputs(sq_dep, xs)
    for fid, want in enumerate(ref):
        for sink, arr in want.items():
            assert np.array_equal(rep.outputs[fid][sink], arr)
    assert launcher.shutdown() is rep  # idempotent


def test_shutdown_abort_drops_with_reason(sq_dep):
    launcher = sq_dep.fleet(DistSpec(max_inflight=16))
    launcher.start()
    for f in make_frames(sq_dep.model, 3):
        launcher.submit(f)
    rep = launcher.shutdown(abort=True)
    assert rep.completed + len(rep.dropped) == rep.submitted == 3
    for _, reason in rep.dropped:
        assert "abort" in reason


# ---------------------------------------------------------------------------
# churn drill: killed worker -> DeviceLeave + drops + recovery
# ---------------------------------------------------------------------------

def test_killed_worker_churn_and_recovery(sq_dep):
    """A silently-killed worker surfaces as DeviceLeave churn, strands
    its in-flight frames as dropped-with-reason, and a re-plan on the
    survivors recovers every frame bit-identically."""
    cluster = _cluster()
    spec = DistSpec(heartbeat_s=0.05, peer_timeout_s=0.6)
    xs = make_frames(sq_dep.model, 6)
    ref = reference_outputs(sq_dep, xs)

    launcher = sq_dep.fleet(spec)
    launcher.start()
    victim = min(1, len(launcher.workers) - 1)
    launcher.kill_worker(victim)
    rep = launcher.run(xs)

    assert rep.churn_events, "dead worker must surface churn events"
    assert all(isinstance(e, DeviceLeave) for e in rep.churn_events)
    dead_devices = {e.device_name for e in rep.churn_events}
    assert dead_devices == set(launcher.workers[victim].devices)
    assert rep.completed + len(rep.dropped) == rep.submitted
    assert rep.dropped, "frames stranded behind the dead stage must drop"
    for _, reason in rep.dropped:
        assert "dead" in reason or "heartbeat" in reason

    # drain-and-repartition: re-plan on the survivors, resubmit the gap
    alive = [d for d in cluster.devices if d.name not in dead_devices]
    dep2 = sq_dep.replan(cluster.restricted(alive))
    missing = sorted(set(range(len(xs))) - set(rep.outputs))
    rep2 = dep2.fleet(spec).run([xs[i] for i in missing])
    assert not rep2.dropped and rep2.completed == len(missing)
    merged = dict(rep.outputs)
    for k, fid in enumerate(missing):
        merged[fid] = rep2.outputs[k]
    for fid, want in enumerate(ref):
        for sink, arr in want.items():
            assert np.array_equal(merged[fid][sink], arr)


def test_worker_spans_merge_into_launcher_trace(sq_dep):
    from repro.obs.trace import Tracer
    tracer = Tracer()
    launcher = sq_dep.fleet(DistSpec(), tracer=tracer)
    launcher.run(make_frames(sq_dep.model, 2))
    tracks = {s.track for s in tracer.spans}
    names = {s.name for s in tracer.spans}
    assert "dist:launcher" in tracks
    assert {f"dist:w{i}" for i in range(len(launcher.workers))} <= tracks
    assert "dist.launch" in names and "stage.compute" in names
