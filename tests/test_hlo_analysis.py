"""Loop-aware HLO census: verify dot-FLOPs x trip-count accounting on a
module with known cost (this underpins the whole roofline table)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, parse_hlo


def _compiled_text(fn, *avals):
    return jax.jit(fn).lower(*avals).compile().as_text()


def test_scan_of_matmuls_counted_with_trips():
    L, M = 12, 64

    def fn(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    text = _compiled_text(
        fn, jax.ShapeDtypeStruct((L, M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32))
    census = analyze_hlo(text)
    expect = 2 * L * M * M * M      # L matmuls of (M,M)@(M,M)
    assert abs(census.flops - expect) / expect < 0.05, \
        (census.flops, expect)


def test_unrolled_matches_scan_census():
    L, M = 6, 32

    def fn_scan(ws, x):
        def body(x, w):
            return x @ w, None
        return jax.lax.scan(body, x, ws)[0]

    def fn_unrolled(ws, x):
        for i in range(L):
            x = x @ ws[i]
        return x

    avals = (jax.ShapeDtypeStruct((L, M, M), jnp.float32),
             jax.ShapeDtypeStruct((M, M), jnp.float32))
    c_scan = analyze_hlo(_compiled_text(fn_scan, *avals))
    c_unrl = analyze_hlo(_compiled_text(fn_unrolled, *avals))
    assert abs(c_scan.flops - c_unrl.flops) / c_unrl.flops < 0.05


def test_nested_scan_trip_products():
    Lo, Li, M = 4, 5, 16

    def fn(ws, x):
        def outer(x, wrow):
            def inner(x, w):
                return x @ w, None
            return jax.lax.scan(inner, x, wrow)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    text = _compiled_text(
        fn, jax.ShapeDtypeStruct((Lo, Li, M, M), jnp.float32),
        jax.ShapeDtypeStruct((M, M), jnp.float32))
    census = analyze_hlo(text)
    expect = 2 * Lo * Li * M ** 3
    assert abs(census.flops - expect) / expect < 0.05


def test_parse_finds_entry_and_computations():
    def fn(x):
        return jnp.sum(x * 2)

    text = _compiled_text(fn, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    comps, entry = parse_hlo(text)
    assert entry in comps
    assert comps[entry].ops
