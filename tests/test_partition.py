"""Algorithm 1 tests: ending pieces, chain constraint, Fig. 6, D&C."""

import pytest

from repro.core import partition_graph, partition_graph_dnc, piece_redundancy
from repro.core.graph import Graph, LayerSpec
from repro.models.cnn import zoo


def fig6_graph():
    """The paper's Fig. 6: 1x7 conv followed by 7x1 conv."""
    g = Graph()
    g.add(LayerSpec("a", "conv", (7, 1), (1, 1), (0, 0), 16, 16))
    g.add(LayerSpec("b", "conv", (1, 7), (1, 1), (0, 0), 16, 16), ["a"])
    return g


def test_fig6_redundancy():
    g = fig6_graph()
    fs = g.forward_sizes((64, 64))
    fused = piece_redundancy(g, frozenset({"a", "b"}), fs, (64, 64), 4)
    alone_a = piece_redundancy(g, frozenset({"a"}), fs, (64, 64), 4)
    alone_b = piece_redundancy(g, frozenset({"b"}), fs, (64, 64), 4)
    assert fused > 0
    assert alone_a == 0 and alone_b == 0


def test_fig6_partition_splits():
    g = fig6_graph()
    res = partition_graph(g, (64, 64), n_split=4)
    assert len(res.pieces) == 2       # optimal: cut between the two convs
    assert res.objective == 0


def _check_chain_structure(g, pieces):
    """Pieces must form a chain: edges only between consecutive pieces."""
    idx = {}
    for i, p in enumerate(pieces):
        for n in p.nodes:
            idx[n] = i
    for u, v in g.edges:
        assert 0 <= idx[v] - idx[u] <= 1, (u, v, idx[u], idx[v])


def _check_cover(g, pieces):
    all_nodes = set()
    for p in pieces:
        assert not (all_nodes & p.nodes), "pieces overlap"
        all_nodes |= p.nodes
    assert all_nodes == set(g.layers)


@pytest.mark.parametrize("name,kw", [
    ("vgg16", dict(input_size=(96, 96), scale=0.1)),
    ("resnet34", dict(input_size=(96, 96), scale=0.1)),
    ("squeezenet", dict(input_size=(96, 96), scale=0.1)),
    ("inceptionv3", dict(input_size=(96, 96), scale=0.1)),
])
def test_partition_validity(name, kw):
    m = zoo.build(name, **kw)
    res = partition_graph(m.graph, m.input_size, n_split=4)
    _check_cover(m.graph, res.pieces)
    _check_chain_structure(m.graph, res.pieces)
    # every piece respects the diameter bound (5) unless it's a fallback
    for p in res.pieces:
        assert m.graph.subset_diameter(p.nodes) <= 5


def test_chain_partition_zero_redundancy():
    """For a chain, the DP reaches zero worst-piece redundancy (ties may
    merge zero-redundancy neighbours, so piece count can be < n)."""
    m = zoo.vgg16(input_size=(96, 96), scale=0.1)
    res = partition_graph(m.graph, m.input_size, n_split=4)
    assert res.objective == 0
    _check_cover(m.graph, res.pieces)
    _check_chain_structure(m.graph, res.pieces)


def test_dnc_matches_direct_on_chain():
    m = zoo.vgg16(input_size=(96, 96), scale=0.1)
    direct = partition_graph(m.graph, m.input_size, n_split=4)
    dnc = partition_graph_dnc(m.graph, m.input_size, n_split=4, chunk=8)
    _check_cover(m.graph, dnc.pieces)
    _check_chain_structure(m.graph, dnc.pieces)
    assert dnc.objective <= direct.objective * 1.5 + 1e-9


def test_dnc_on_wide_graph():
    m = zoo.nasnet_cells(n_cells=6, input_size=(96, 96), scale=0.1,
                         width=6)
    res = partition_graph_dnc(m.graph, m.input_size, n_split=4, chunk=30)
    _check_cover(m.graph, res.pieces)
    # D&C guarantees topological piece order (the stage executor handles
    # multi-hop boundary inputs); strict chain adjacency may be violated
    # across chunk cut lines — paper §6.2.3 accepts this approximation.
    idx = {}
    for i, pc in enumerate(res.pieces):
        for n in pc.nodes:
            idx[n] = i
    for u, v in m.graph.edges:
        assert idx[v] >= idx[u], (u, v)
