"""`pipeline/halo.py:plan_tiles` edge cases: zero-fraction devices,
fractions that don't sum to 1, single-device stages."""

import jax
import numpy as np
import pytest

from repro.core.graph import proportional_widths
from repro.models.cnn import zoo
from repro.models.cnn.builder import GB
from repro.pipeline.halo import plan_tiles, tile_signature
from repro.pipeline.stage import StageExecutor


def _chain(w=24):
    b = GB("chain", (w, w))
    x = b.conv(None, 4, 3, p=1)
    x = b.conv(x, 4, 3, p=1)
    x = b.pool(x, 2, 2)
    return b.done()


def _exec_and_check(m, fractions, x_key=1):
    params = m.init(jax.random.PRNGKey(0))
    w, h = m.input_size
    x = jax.random.normal(jax.random.PRNGKey(x_key), (1, h, w, 3))
    ref = m.forward(params, x)
    ex = StageExecutor(m, frozenset(m.graph.layers), list(fractions))
    out = ex(params, {}, x)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)
    return ex


def test_zero_fraction_device_gets_empty_tile():
    m = _chain()
    ex = _exec_and_check(m, [0.5, 0.0, 0.5])
    assert ex.plans[1].empty
    assert not ex.plans[0].empty and not ex.plans[2].empty
    # the empty tile carries no ranges and contributes no output width
    for s, (a, b) in ex.plans[1].sink_ranges.items():
        assert a >= b


def test_zero_weight_proportional_widths():
    assert proportional_widths(12, [1.0, 0.0, 1.0]) == [6, 0, 6]
    assert proportional_widths(3, [0.0, 1.0]) == [0, 3]
    with pytest.raises(ValueError):
        proportional_widths(8, [0.0, 0.0])


def test_fractions_not_summing_to_one_are_normalized():
    m = _chain()
    # sums to 0.5 and to 3.0: widths must still cover the full feature
    for fr in ([0.25, 0.25], [2.0, 1.0]):
        ex = _exec_and_check(m, fr)
        for s in ex.sinks:
            covered = sorted(tp.sink_ranges[s] for tp in ex.plans)
            assert covered[0][0] == 0
            assert covered[-1][1] == m.full_sizes[s][0]
            for (a0, b0), (a1, b1) in zip(covered, covered[1:]):
                assert b0 == a1          # contiguous, no overlap, no gap


def test_single_device_stage_is_monolithic():
    m = _chain()
    plans = plan_tiles(m.graph, frozenset(m.graph.layers), m.full_sizes,
                       m.input_size, [1.0])
    assert len(plans) == 1
    tp = plans[0]
    assert not tp.empty
    for s, (a, b) in tp.sink_ranges.items():
        assert (a, b) == (0, m.full_sizes[s][0])
    _exec_and_check(m, [1.0])


def test_more_devices_than_columns():
    """A sink narrower than the device group: surplus devices idle."""
    b = GB("narrow", (8, 8))
    x = b.conv(None, 4, 3, p=1)
    x = b.pool(x, 2, 2)   # 4 columns
    x = b.pool(x, 2, 2)   # 2 columns
    m = b.done()
    ex = _exec_and_check(m, [0.4, 0.3, 0.2, 0.1])
    empties = [tp.empty for tp in ex.plans]
    assert sum(empties) == 2          # only 2 columns to hand out
    assert empties == [False, False, True, True]  # largest fractions win


def test_tile_signature_stable_and_distinct():
    m = _chain()
    nodes = frozenset(m.graph.layers)
    a = plan_tiles(m.graph, nodes, m.full_sizes, m.input_size, [0.5, 0.5])
    b = plan_tiles(m.graph, nodes, m.full_sizes, m.input_size, [0.5, 0.5])
    c = plan_tiles(m.graph, nodes, m.full_sizes, m.input_size, [0.75, 0.25])
    assert tile_signature(a) == tile_signature(b)
    assert tile_signature(a) != tile_signature(c)
    hash(tile_signature(a))   # usable as a cache key


def test_zero_fraction_on_real_zoo_model():
    m = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    _exec_and_check(m, [0.5, 0.0, 0.3, 0.2])
