"""Multi-tenant serving scheduler: cluster partitioning, end-to-end
serving with admission/deadlines/batching, churn-driven re-partitioning
and the time-sliced baseline."""

import jax
import numpy as np
import pytest

from repro.core import make_pi_cluster, partition_cluster, plan
from repro.data.pipeline import Request
from repro.models.cnn import zoo
from repro.runtime import (DeviceLeave, PipelineRuntime, RuntimeConfig)
from repro.serving import (OpenLoopGenerator, SchedulerConfig, ServingScheduler,
                           TenantConfig, TenantJoin, TenantLeave,
                           serve_time_sliced)


def _sq(size=(96, 96), scale=0.1):
    return zoo.squeezenet(input_size=size, scale=scale)


def _models3():
    return [_sq(), zoo.mobilenetv3(input_size=(96, 96), scale=0.25),
            zoo.resnet34(input_size=(96, 96), scale=0.1)]


# ---------------------------------------------------------------------------
# partition_cluster
# ---------------------------------------------------------------------------

def test_partition_covers_devices_exactly_once():
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 1.0, 0.8])
    part = partition_cluster(_models3(), cluster)
    names = [d.name for s in part.shares for d in s.cluster.devices]
    assert sorted(names) == sorted(d.name for d in cluster.devices)
    assert all(len(s.cluster.devices) >= 1 for s in part.shares)
    # every sub-cluster got a valid plan using all its devices
    for s in part.shares:
        used = [d.name for st in s.pico.pipeline.stages for d in st.devices]
        assert sorted(used) == sorted(d.name for d in s.cluster.devices)


def test_partition_weight_monotonicity():
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 1.0, 0.8, 0.8])
    m = [_sq(), _sq()]
    heavy = partition_cluster(m, cluster, weights=[4.0, 1.0])
    assert heavy.shares[0].capacity > heavy.shares[1].capacity
    equal = partition_cluster(m, cluster, weights=[1.0, 1.0])
    ratio_heavy = heavy.shares[0].capacity / heavy.shares[1].capacity
    ratio_equal = equal.shares[0].capacity / equal.shares[1].capacity
    assert ratio_heavy > ratio_equal


def test_partition_needs_a_device_per_model():
    cluster = make_pi_cluster([1.0, 1.0])
    with pytest.raises(ValueError):
        partition_cluster(_models3(), cluster)
    with pytest.raises(ValueError):
        partition_cluster([_sq()], cluster, weights=[0.0])


def test_partition_replan_reuses_piece_chain():
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    m = [_sq(), _sq()]
    first = partition_cluster(m, cluster)
    again = partition_cluster(m, cluster,
                              prev=[s.pico for s in first.shares])
    for a, b in zip(first.shares, again.shares):
        assert [p.nodes for p in a.pico.partition.pieces] \
            == [p.nodes for p in b.pico.partition.pieces]
        assert b.pico.period == pytest.approx(a.pico.period)


# ---------------------------------------------------------------------------
# runtime micro-batching (the scheduler's execution substrate)
# ---------------------------------------------------------------------------

def test_runtime_microbatch_numerics_match_forward():
    m = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    cluster = make_pi_cluster([1.5, 1.0, 0.8])
    params = m.init(jax.random.PRNGKey(0))
    xs = [jax.random.normal(jax.random.PRNGKey(i), (1, 64, 64, 3))
          for i in range(5)]
    rt = PipelineRuntime(model=m, params=params, cluster=cluster,
                         config=RuntimeConfig(max_batch=3))
    rep = rt.run(inputs=xs)
    assert rep.completed == 5
    for i, x in enumerate(xs):
        ref = m.forward(params, x)
        for k in ref:
            np.testing.assert_allclose(np.asarray(rep.outputs[i][k]),
                                       np.asarray(ref[k]),
                                       rtol=2e-5, atol=2e-5)


def test_runtime_batching_amortizes_link_latency():
    m = _sq()
    cluster = make_pi_cluster([1.2, 1.0, 0.8])
    pico = plan(m.graph, cluster, m.input_size)
    cfg = dict(inter_stage_bandwidth=50e6 / 8, link_latency_s=2e-3)
    solo = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                           config=RuntimeConfig(max_batch=1, **cfg)).run(24)
    batched = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico,
                              config=RuntimeConfig(max_batch=6, **cfg)).run(24)
    assert batched.completed == solo.completed == 24
    # per-batch link latency is paid once per batch instead of per frame
    assert batched.makespan < solo.makespan


def test_runtime_deadline_drops_queued_frames():
    m = _sq()
    cluster = make_pi_cluster([1.0])
    pico = plan(m.graph, cluster, m.input_size)
    rt = PipelineRuntime(m.graph, cluster, m.input_size, pico=pico)
    rt.begin_stream()
    from repro.runtime.executor import Frame
    # a burst of simultaneous frames on a single device: later ones
    # expire in the queue before their turn
    for i in range(8):
        rt.admit(Frame(i, arrival=0.0, deadline=2.5 * pico.period))
    while rt.step() is not None:
        pass
    rep = rt.report()
    assert rep.dropped > 0
    assert rep.completed + rep.dropped == 8
    assert rep.completed >= 1


# ---------------------------------------------------------------------------
# ServingScheduler end-to-end
# ---------------------------------------------------------------------------

def _workload_for(sched, n, load, seed0=0, duration_s=None):
    """Per-tenant Poisson streams at ``load`` x sub-pipeline capacity:
    ``n`` requests each, or duration-matched counts when ``duration_s``
    is given (so all tenants' traffic overlaps)."""
    out = {}
    for i, ts in enumerate(sched._tenants.values()):
        rate = load / ts.share.pico.period
        gen = OpenLoopGenerator(rate_per_s=rate, seed=seed0 + i)
        n_i = n if duration_s is None else max(8, int(rate * duration_s))
        out[ts.cfg.name] = gen.generate(n_i)
    return out


def test_scheduler_serves_all_tenants_timing_mode():
    cluster = make_pi_cluster([1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8])
    tenants = [TenantConfig(f"t{i}", m) for i, m in enumerate(_models3())]
    sched = ServingScheduler(tenants, cluster)
    rep = sched.serve(_workload_for(sched, 40, load=0.8))
    assert rep.served == 120
    assert rep.dropped_inflight == 0
    for name, s in rep.tenants.items():
        assert s.served == 40
        assert s.rejected == 0 and s.expired == 0
        assert s.p50_latency_s <= s.p95_latency_s <= s.p99_latency_s
        assert all(lat >= 0 for lat in s.per_request)
    # devices did real (virtual) work and utilization is sane
    assert any(b > 0 for b in rep.device_busy_s.values())
    assert all(0 <= rep.utilization(d) <= 1 + 1e-9
               for d in rep.device_busy_s)


def test_scheduler_real_compute_matches_forward():
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8])
    m1 = zoo.squeezenet(input_size=(64, 64), scale=0.1)
    m2 = zoo.vgg16(input_size=(64, 64), scale=0.1, head=False)
    tenants = [TenantConfig("a", m1, max_batch=3),
               TenantConfig("b", m2, max_batch=2)]
    sched = ServingScheduler(tenants, cluster).load()

    def payload(i):
        return jax.random.normal(jax.random.PRNGKey(i), (1, 64, 64, 3))

    wl = {"a": [Request(i, i * 1e-3, payload(i)) for i in range(5)],
          "b": [Request(i, i * 1e-3, payload(100 + i)) for i in range(3)]}
    rep = sched.serve(wl)
    assert rep.served == 8 and rep.dropped_inflight == 0
    for name, m, n, off in (("a", m1, 5, 0), ("b", m2, 3, 100)):
        params = sched._tenants[name].params
        for i in range(n):
            ref = m.forward(params, payload(off + i))
            out = rep.outputs[name][i]
            for k in ref:
                np.testing.assert_allclose(np.asarray(out[k]),
                                           np.asarray(ref[k]),
                                           rtol=2e-5, atol=2e-5)


def test_scheduler_admission_and_deadlines():
    cluster = make_pi_cluster([1.0, 0.8])
    tenants = [TenantConfig("x", _sq(), slo_s=2e-3, max_queue=4,
                            max_batch=2)]
    sched = ServingScheduler(tenants, cluster)
    period = sched._tenants["x"].share.pico.period
    wl = {"x": OpenLoopGenerator(rate_per_s=3.0 / period,
                                 seed=2).generate(80)}
    rep = sched.serve(wl)
    s = rep.tenants["x"]
    assert s.rejected > 0                 # queue bound enforced
    assert s.served + s.rejected + s.expired == 80
    assert rep.dropped_inflight == 0      # overload drops queued, not flying
    assert 0.0 < s.deadline_miss_rate <= 1.0


def test_scheduler_device_churn_recovers_without_drops():
    cluster = make_pi_cluster([1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8])
    tenants = [TenantConfig("a", _sq()),
               TenantConfig("b", zoo.resnet34(input_size=(96, 96),
                                              scale=0.1))]
    sched = ServingScheduler(tenants, cluster,
                             config=SchedulerConfig(
                                 seed=5, migration_bandwidth=1e9))
    wl = _workload_for(sched, 120, load=0.6, seed0=3)
    horizon = max(r.arrival for rs in wl.values() for r in rs)
    rep = sched.serve(wl, churn=[DeviceLeave(0.5 * horizon, "pi7@0.8GHz")])
    assert any(r.reason == "leave" for r in rep.repartitions)
    assert rep.served == 240              # nothing lost across the re-split
    assert rep.dropped_inflight == 0
    leave = next(r for r in rep.repartitions if r.reason == "leave")
    assert all("pi7@0.8GHz" not in devs
               for devs in leave.assignment.values())


def test_scheduler_load_shift_triggers_repartition():
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 1.0, 0.8, 0.8])
    tenants = [TenantConfig("hot", _sq()), TenantConfig("cold", _sq())]
    sched = ServingScheduler(
        tenants, cluster,
        config=SchedulerConfig(control_interval_s=0.05,
                               rebalance_cooldown_s=0.1,
                               migration_bandwidth=1e9))
    period = sched._tenants["hot"].share.pico.period
    # "hot" offers 10x the traffic of "cold": the EWMA shifts the split
    wl = {"hot": OpenLoopGenerator(rate_per_s=1.5 / period,
                                   seed=0).generate(150),
          "cold": OpenLoopGenerator(rate_per_s=0.15 / period,
                                    seed=1).generate(15)}
    rep = sched.serve(wl)
    assert rep.dropped_inflight == 0
    loads = [r for r in rep.repartitions if r.reason == "load"]
    assert loads, "skewed load never re-partitioned the fleet"
    final = loads[-1].assignment
    assert len(final["hot"]) > len(final["cold"])


def test_scheduler_tenant_join_and_leave():
    cluster = make_pi_cluster([1.5, 1.2, 1.0, 0.8, 1.0, 0.8])
    tenants = [TenantConfig("a", _sq()), TenantConfig("b", _sq())]
    sched = ServingScheduler(tenants, cluster)
    p = {n: ts.share.pico.period for n, ts in sched._tenants.items()}
    wl = {"a": OpenLoopGenerator(rate_per_s=0.5 / p["a"],
                                 seed=0).generate(60),
          "b": OpenLoopGenerator(rate_per_s=0.5 / p["b"],
                                 seed=1).generate(60)}
    horizon = max(r.arrival for rs in wl.values() for r in rs)
    churn = [TenantJoin(0.3 * horizon, TenantConfig("c", _sq())),
             TenantLeave(0.6 * horizon, "b")]
    rep = sched.serve(wl, churn=churn)
    reasons = [r.reason for r in rep.repartitions]
    assert "tenant-join" in reasons and "tenant-leave" in reasons
    assert rep.tenants["a"].served == 60  # bystander tenant unaffected
    b = rep.tenants["b"]
    assert b.served + b.rejected + b.expired == 60
    assert rep.dropped_inflight == 0
    # after the join, c owns at least one device
    join = next(r for r in rep.repartitions if r.reason == "tenant-join")
    assert len(join.assignment["c"]) >= 1


def test_scheduler_single_use():
    cluster = make_pi_cluster([1.0, 0.8])
    sched = ServingScheduler([TenantConfig("a", _sq())], cluster)
    sched.serve({"a": []})
    with pytest.raises(RuntimeError):
        sched.serve({"a": []})


def test_multitenant_beats_time_sliced():
    # the benchmark's tenant mix (fig_serving_mt) in a shorter run:
    # saturated duration-matched streams, partitioned vs time-sliced
    cluster = make_pi_cluster([1.5, 1.5, 1.2, 1.2, 1.0, 1.0, 0.8, 0.8])
    models = [zoo.squeezenet(input_size=(96, 96), scale=0.5),
              zoo.mobilenetv3(input_size=(96, 96), scale=0.5),
              zoo.resnet34(input_size=(96, 96), scale=0.25)]
    tenants = [TenantConfig(f"t{i}", m, max_batch=4)
               for i, m in enumerate(models)]
    sched = ServingScheduler(tenants, cluster)
    wl = _workload_for(sched, 0, load=2.0, seed0=11, duration_s=0.8)
    rep = sched.serve(wl)
    base = serve_time_sliced(tenants, cluster, wl)
    total = sum(len(rs) for rs in wl.values())
    assert rep.served == base.served == total
    assert rep.dropped_inflight == 0
    assert rep.throughput_per_min >= 1.5 * base.throughput_per_min
