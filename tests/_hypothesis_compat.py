"""Optional-hypothesis shim.

Property-based tests use hypothesis when available (pinned in
requirements-dev.txt); without it the ``@given`` tests skip cleanly
while the plain unit tests in the same module still run.
"""

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal installs
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stand-in for hypothesis.strategies: every attribute is a no-op."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            # a named def (not a lambda): pytest collects it and
            # reports the property test as skipped, not as a warning
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass
            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub
        return deco

__all__ = ["st", "given", "settings", "HAVE_HYPOTHESIS"]
