"""Autotuner contract: search, shape keys, CostTable persistence, and
the Deployment save/load symmetry (a loaded artifact re-tunes nothing).

All searches here use tiny candidate sets and ``iters=1`` — the point
is the plumbing (winner selection, key stability, artifact round-trip,
process-wide install), not interpret-mode wall times.
"""

import json

import jax
import pytest

import repro
from repro.api import ExecSpec, artifacts
from repro.core import CostTable, make_pi_cluster
from repro.exec.autotune import (DEFAULT_CANDIDATES, autotune_conv,
                                 autotune_model, clear_installed,
                                 conv_shapes, install, installed,
                                 shape_key, tuned_blocks)
from repro.models.cnn import zoo

TINY = ((16, 16), (8, 8))


@pytest.fixture(autouse=True)
def _isolate_installed():
    """Each test starts and ends with an empty tuned registry."""
    clear_installed()
    yield
    clear_installed()


def test_shape_key_is_spatial_size_agnostic():
    a = shape_key((1, 32, 32, 8), (3, 3, 8, 16), (1, 1))
    b = shape_key((1, 7, 9, 8), (3, 3, 8, 16), (1, 1))
    assert a == b
    # but channels, stride, epilogue and backend all distinguish
    assert a != shape_key((1, 32, 32, 9), (3, 3, 9, 16), (1, 1))
    assert a != shape_key((1, 32, 32, 8), (3, 3, 8, 16), (2, 2))
    assert a != shape_key((1, 32, 32, 8), (3, 3, 8, 16), (1, 1), relu=True)
    assert a != shape_key((1, 32, 32, 8), (3, 3, 8, 16), (1, 1),
                          pool=(2, 2))
    assert a != shape_key((1, 32, 32, 8), (3, 3, 8, 16), (1, 1),
                          backend="xla")


def test_autotune_conv_picks_a_candidate():
    res = autotune_conv((1, 10, 10, 5), (3, 3, 5, 7), stride=(1, 1),
                        relu=True, pool=(2, 2), candidates=TINY, iters=1)
    assert (res.block_ci, res.block_co) in TINY
    assert len(res.trials) == len(TINY)
    assert res.best_us > 0
    assert res.best_us == pytest.approx(
        min(t[2] for t in res.trials) * 1e6)
    e = res.entry()
    assert set(e) == {"block_ci", "block_co", "best_us", "backend"}


def test_tuned_blocks_consults_installed_registry():
    k = shape_key((1, 10, 10, 5), (3, 3, 5, 7), (1, 1))
    assert tuned_blocks((1, 10, 10, 5), (3, 3, 5, 7), (1, 1)) == (None, None)
    install({k: {"block_ci": 16, "block_co": 8, "best_us": 1.0,
                 "backend": "pallas"}})
    # any spatial size hits the same entry
    assert tuned_blocks((1, 99, 3, 5), (3, 3, 5, 7), (1, 1)) == (16, 8)
    assert installed()[k]["block_co"] == 8


def test_conv_shapes_fuses_like_the_compiler():
    m = zoo.build("vgg16", input_size=(40, 40), scale=0.1, head=False)
    shapes = conv_shapes(m)
    assert shapes  # dedup by key, so strictly fewer than conv layers
    assert len(shapes) <= sum(
        1 for s in m.graph.layers.values() if s.kind == "conv")
    assert any(d["pool"] for d in shapes)   # vgg conv->pool chains fuse
    assert all(d["relu"] for d in shapes)


def test_autotune_model_skips_warm_table_entries():
    m = zoo.build("squeezenet", input_size=(48, 48), scale=0.1)
    table, results = autotune_model(m, candidates=TINY, iters=1)
    assert results and len(table.kernels) == len(results)
    assert installed() == table.kernels   # winners installed by default
    # a warm table re-tunes nothing — the save/load acceptance property
    table2, results2 = autotune_model(m, table=table, candidates=TINY,
                                      iters=1)
    assert results2 == []
    assert table2.kernels == table.kernels


def test_cost_table_artifact_round_trips_kernels():
    t = CostTable(kernels={
        "conv:pallas:c3x8:k3x3:s1x1:r1:p2x2":
            {"block_ci": 8, "block_co": 16, "best_us": 12.5,
             "backend": "pallas"}})
    s = artifacts.cost_table_to_json(t)
    t2 = artifacts.cost_table_from_json(s)
    assert t2.kernels == t.kernels
    # additive field: tables without tunings serialize without it, and
    # old payloads (no "kernels") still load
    assert "kernels" not in json.loads(
        artifacts.cost_table_to_json(CostTable()))["payload"]
    assert artifacts.cost_table_from_json(
        artifacts.cost_table_to_json(CostTable())).kernels == {}


def test_exec_spec_autotune_validation():
    assert ExecSpec().autotune is False
    with pytest.raises(ValueError):
        ExecSpec(autotune_iters=0)


def test_deployment_autotunes_and_save_load_retunes_nothing(tmp_path):
    m = zoo.build("squeezenet", input_size=(48, 48), scale=0.1)
    cluster = make_pi_cluster([1.0, 0.8])
    es = ExecSpec(backend="pallas", autotune=True, autotune_iters=1)
    # patch in the tiny candidate set: full default search is too slow
    # for a unit test in interpret mode
    import repro.exec.autotune as at
    orig = at.autotune_conv

    calls = []

    def counting(*a, **kw):
        calls.append(a)
        kw["candidates"] = TINY
        kw["iters"] = 1
        return orig(*a, **kw)

    at.autotune_conv = counting
    try:
        dep = repro.compile(m, cluster, exec_spec=es,
                            key=jax.random.PRNGKey(0))
        assert calls, "compile(autotune=True) must run the tuner"
        n_tuned = len(dep.cost_table.kernels)
        assert n_tuned == len(calls)
        assert "autotuned" in dep.describe()
        path = dep.save(tmp_path / "dep.json")

        calls.clear()
        clear_installed()
        dep2 = repro.Deployment.load(path, model=m)
        # load() re-arms the fast path from the artifact: kernels
        # round-trip exactly, install happens on construction, and the
        # tuner never runs again
        assert dep2.cost_table.kernels == dep.cost_table.kernels
        assert installed() == dep2.cost_table.kernels
        assert calls == []
        # a re-compile against the loaded table is also a no-op search
        repro.compile(m, cluster, exec_spec=es,
                      cost_table=dep2.cost_table,
                      key=jax.random.PRNGKey(0))
        assert calls == [], "warm CostTable must re-tune nothing"
        assert len(dep2.cost_table.kernels) == n_tuned
    finally:
        at.autotune_conv = orig


def test_default_candidates_cover_mxu_and_tails():
    assert (128, 128) in DEFAULT_CANDIDATES
    assert (8, 8) in DEFAULT_CANDIDATES
